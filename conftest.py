"""Repo-level pytest config: make ``src`` importable without an install and
auto-tag the kernel test modules.

CI lanes map to markers (see .github/workflows/ci.yml):
  fast lane  → ``-m "not slow"``   (every push, well under 2 minutes)
  full lane  → no filter           (the tier-1 suite)
"""

import pathlib
import sys

import pytest

SRC = pathlib.Path(__file__).resolve().parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def pytest_collection_modifyitems(config, items):
    for item in items:
        # interpret-mode Pallas kernel suites, tagged wholesale
        if item.module.__name__.startswith("test_kernels"):
            item.add_marker(pytest.mark.kernel)
