"""Bench-regression gate: diff a fresh throughput run against the committed
baseline and fail CI on warm per-call regressions.

Usage (what the ``bench-quick`` CI job runs):

    python -m benchmarks.compare --baseline /tmp/bench-baseline.json \
        --fresh BENCH_throughput.json --history-dir .bench-history

Gate: for every backend present in BOTH files' ``engine.backends``, the
fresh jit-warm ``per_call_ms`` must not exceed baseline by more than
``--threshold`` (default 25%). The engine bench always runs at the same
batch (throughput.ENGINE_BATCH) in quick and full mode precisely so this
comparison is apples-to-apples; a batch mismatch aborts rather than gating
on garbage.

Caveat the threshold must absorb: the committed baseline carries the
absolute ms of whatever host produced it. Timings use min-of-N (stable
within ~10% across runs on one host), but a materially slower/faster runner
class shifts every backend together — if CI moves hosts, regenerate the
baseline there (run the quick bench on the new host and commit its JSON)
rather than widening the threshold.

History: ``--history-dir`` appends the fresh JSON (one file per run) and
prints a per-backend trajectory table across the stored runs — to stdout
and, when ``$GITHUB_STEP_SUMMARY`` is set, to the job summary. CI persists
the directory across runs via ``actions/cache``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import shutil
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
GATED_SECTION = ("engine", "backends")
HISTORY_KEEP = 30


def _load(path: pathlib.Path) -> dict:
    with open(path) as f:
        return json.load(f)


def _engine_backends(doc: dict) -> dict:
    sec = doc
    for k in GATED_SECTION:
        sec = sec.get(k, {})
    return sec


def compare(baseline: dict, fresh: dict, threshold: float) -> tuple[list[str], list[str]]:
    """Return (report lines, regression lines). Non-empty regressions = fail."""
    base_be = _engine_backends(baseline)
    fresh_be = _engine_backends(fresh)
    b_batch = baseline.get("engine", {}).get("batch")
    f_batch = fresh.get("engine", {}).get("batch")
    if not base_be or not fresh_be:
        raise SystemExit("compare: engine.backends missing from baseline or fresh run")
    if b_batch != f_batch:
        raise SystemExit(
            f"compare: engine batch mismatch (baseline {b_batch} vs fresh {f_batch}); "
            "refusing to gate on incomparable runs")

    lines, regressions = [], []
    lines.append(f"gate: engine.backends per_call_ms @ batch {f_batch}, "
                 f"threshold +{threshold:.0%}")
    for be in sorted(set(base_be) & set(fresh_be)):
        b = base_be[be]["per_call_ms"]
        f = fresh_be[be]["per_call_ms"]
        ratio = f / b if b > 0 else float("inf")
        verdict = "OK"
        if ratio > 1 + threshold:
            verdict = "REGRESSION"
            regressions.append(
                f"{be}: {b:.2f} ms → {f:.2f} ms ({ratio:.2f}x > {1 + threshold:.2f}x)")
        lines.append(f"  {be:9s} {b:9.2f} ms → {f:9.2f} ms  ({ratio:5.2f}x)  {verdict}")
    missing = sorted(set(base_be) - set(fresh_be))
    if missing:
        regressions.append(f"backends missing from fresh run: {missing}")

    # families are informational (not gated): different PRs may add/resize them
    for fam, fres in sorted(fresh.get("families", {}).items()):
        bres = baseline.get("families", {}).get(fam)
        for be, v in sorted(fres.get("backends", {}).items()):
            prev = (bres or {}).get("backends", {}).get(be, {}).get("per_call_ms")
            delta = f" (was {prev:.2f})" if prev else ""
            lines.append(f"  [info] {fam}/{be}: {v['per_call_ms']:.2f} ms{delta}")
    return lines, regressions


def _append_history(history_dir: pathlib.Path, fresh_path: pathlib.Path) -> list[pathlib.Path]:
    history_dir.mkdir(parents=True, exist_ok=True)
    run_id = os.environ.get("GITHUB_RUN_ID", "local")
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    shutil.copy(fresh_path, history_dir / f"{stamp}-{run_id}.json")
    runs = sorted(history_dir.glob("*.json"))
    for old in runs[:-HISTORY_KEEP]:          # bound the cache size
        old.unlink()
    return sorted(history_dir.glob("*.json"))


def trajectory_table(runs: list[pathlib.Path], limit: int = 10) -> str:
    """Markdown table: one row per stored run, one column per backend."""
    rows = []
    backends: list[str] = []
    for p in runs[-limit:]:
        try:
            doc = _load(p)
        except (OSError, json.JSONDecodeError):
            continue
        be = _engine_backends(doc)
        if not be:
            continue
        backends = sorted(set(backends) | set(be))
        rows.append((p.stem, {k: v.get("per_call_ms") for k, v in be.items()}))
    if not rows:
        return "(no bench history yet)"
    head = "| run | " + " | ".join(f"{b} ms" for b in backends) + " |"
    sep = "|---" * (len(backends) + 1) + "|"
    body = [
        "| " + name + " | " + " | ".join(
            f"{vals.get(b):.2f}" if vals.get(b) is not None else "—"
            for b in backends) + " |"
        for name, vals in rows
    ]
    return "\n".join([head, sep, *body])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=REPO / "BENCH_throughput.json",
                    help="committed baseline JSON (gate reference)")
    ap.add_argument("--fresh", type=pathlib.Path,
                    default=REPO / "BENCH_throughput.json",
                    help="freshly produced bench JSON")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed warm per-call regression (0.25 = +25%%)")
    ap.add_argument("--history-dir", type=pathlib.Path, default=None,
                    help="append the fresh run and print a trajectory table")
    args = ap.parse_args()
    if args.baseline.resolve() == args.fresh.resolve():
        raise SystemExit(
            "compare: --baseline and --fresh resolve to the same file "
            f"({args.baseline}) — comparing a run with itself always passes. "
            "Stash the committed baseline first (e.g. `git show "
            "HEAD:BENCH_throughput.json > /tmp/baseline.json`) or write the "
            "fresh run elsewhere (`benchmarks.run --out fresh.json`).")

    baseline = _load(args.baseline)
    fresh = _load(args.fresh)
    lines, regressions = compare(baseline, fresh, args.threshold)
    report = "\n".join(lines)
    print(report)

    summary_parts = ["## Bench gate", "```", report, "```"]
    if args.history_dir is not None:
        runs = _append_history(args.history_dir, args.fresh)
        table = trajectory_table(runs)
        print("\nbench trajectory (jit-warm per-call ms):\n" + table)
        summary_parts += ["## Bench trajectory (warm per-call ms)", table]

    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n".join(summary_parts) + "\n")

    if regressions:
        print("\nBENCH REGRESSION (>" + f"{args.threshold:.0%} warm per-call):",
              file=sys.stderr)
        for r in regressions:
            print("  " + r, file=sys.stderr)
        sys.exit(1)
    print("\nbench gate: PASS")


if __name__ == "__main__":
    main()
