"""Bench-regression gate: diff a fresh throughput run against the committed
baseline and fail CI on warm per-call regressions.

Usage (what the ``bench-quick`` CI job runs):

    python -m benchmarks.compare --baseline /tmp/bench-baseline.json \
        --fresh BENCH_throughput.json --history-dir .bench-history

Gate: for every backend present in BOTH files' ``engine.backends``, the
fresh jit-warm ``per_call_ms`` must not exceed baseline by more than
``--threshold`` (default 25%). When both files carry ``ref_dense_ms`` (a
fixed dense-matmul reference timed inside the same warm loop), the report
leads with the host-speed shift it implies — the one fact a human needs
when triaging a gate failure on a shared runner. (Gating on the normalized
ratio was tried and rejected: throttling hits the MXU-bound reference and
the gather-bound LUT backends differently, so normalization ADDS noise
rather than cancelling it.) Additionally, ``multi_plan``'s aggregate
multi-model ``flows_s`` carries a COLLAPSE gate: fail only on a
``max(2x, 1+threshold)`` slowdown. Sustained host throughput swings ~2x
between runs on shared runners, so a threshold-level gate on absolute
flows/s would flake; the bugs this line guards (retrace-per-request,
scheduling livelock, accidental serialization) cost 5-10x. Per-model
``served_ms`` is info only. The ``async_serve`` sweep carries two
HOST-INDEPENDENT gates on the fresh run itself — the async/sync paired
throughput ratio must stay ≥ ``ASYNC_RATIO_FLOOR`` and the WFQ
high-priority p50 queue-wait must sit below the low-priority one's —
plus a 2x cross-run collapse gate on absolute async flows/s. The
``overload`` sweep (deadline/SLO serving) carries two further
host-independent fresh-run gates — under 2x overload the high-priority
class's p99 queue-wait must stay below ``OVERLOAD_WAIT_FACTOR`` x the
sweep's deadline (slack-based shedding bounds waits), and goodput at 2x
must hold ≥ ``OVERLOAD_PLATEAU_FLOOR`` x goodput at 1x (the
goodput-within-deadline curve plateaus past saturation instead of
collapsing) — plus the same 2x cross-run collapse gate on goodput at 1x
load. The ``chaos`` sweep (fault recovery) gates the fresh run
host-independently too — recovery to ≥ 90% of the fault-free completion
rate must complete within the sweep's own window after an injected
transient stream crash, and goodput under faults must hold ≥
``CHAOS_GOODPUT_FLOOR`` x the fault-free rate — plus the 2x cross-run
collapse gate on the fault-free rate. The ``sharding`` sweep
(multi-device serving) gates the fresh run's
serve-stream scaling efficiency at 4 simulated devices (≥
``SHARDING_EFF_FLOOR``, normalized by host parallelism so single-core CI
gates on pool overhead rather than impossible speedups), plus the collapse
gate on its K=1 aggregate; a missing sharding section is info, never a
failure. Keys present in only ONE of {baseline, fresh} — a PR adding or
retiring a backend, family, or served model — are reported as info, never
failed: gating the symmetric difference would break every PR that grows the
bench surface. The engine bench always runs at the same batch
(throughput.ENGINE_BATCH) in quick and full mode precisely so the gated
comparison is apples-to-apples; an engine batch mismatch aborts rather than
gating on garbage (a multi_plan batch mismatch merely skips that gate with
a note — the committed baseline may predate a batch change).

Caveat the threshold must absorb: the committed baseline carries the
absolute ms of whatever host produced it. Timings use min-of-N (stable
within ~10% across runs on one host), but a materially slower/faster runner
class shifts every backend together — if CI moves hosts, regenerate the
baseline there (run the quick bench on the new host and commit its JSON)
rather than widening the threshold.

History: ``--history-dir`` appends the fresh JSON (one file per run) and
prints a per-backend trajectory table across the stored runs — to stdout
and, when ``$GITHUB_STEP_SUMMARY`` is set, to the job summary. CI persists
the directory across runs via ``actions/cache``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import shutil
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
GATED_SECTION = ("engine", "backends")
HISTORY_KEEP = 30
# A backend ≥1.3x FASTER than baseline is a deliberate perf win, not noise
# (min-of-N is stable within ~10% on one host): its line is marked RATCHET
# and the report tells the author to commit the fresh JSON, so the gate's
# baseline tightens to the new numbers on merge instead of silently leaving
# 30% of regression headroom above them.
RATCHET_FACTOR = 1.3


def _load(path: pathlib.Path) -> dict:
    with open(path) as f:
        return json.load(f)


def _engine_backends(doc: dict) -> dict:
    sec = doc
    for k in GATED_SECTION:
        sec = sec.get(k, {})
    return sec


def compare(baseline: dict, fresh: dict, threshold: float) -> tuple[list[str], list[str]]:
    """Return (report lines, regression lines). Non-empty regressions = fail."""
    base_be = _engine_backends(baseline)
    fresh_be = _engine_backends(fresh)
    b_batch = baseline.get("engine", {}).get("batch")
    f_batch = fresh.get("engine", {}).get("batch")
    if not base_be or not fresh_be:
        raise SystemExit("compare: engine.backends missing from baseline or fresh run")
    if b_batch != f_batch:
        raise SystemExit(
            f"compare: engine batch mismatch (baseline {b_batch} vs fresh {f_batch}); "
            "refusing to gate on incomparable runs")

    lines, regressions = [], []
    lines.append(f"gate: engine.backends per_call_ms @ batch {f_batch}, "
                 f"threshold +{threshold:.0%}")
    # plan-audit provenance (schema-only, never a gate): a run whose anchor
    # plan carried PGA error findings benchmarks a plan the auditor would
    # refuse to ship — say so LOUDLY, but older artifacts predate the field
    # and pass silently
    for label, doc in (("baseline", baseline), ("fresh", fresh)):
        audit = doc.get("audit")
        if audit and audit.get("error"):
            lines.append(
                f"  [info] *** {label} run was produced by a plan with "
                f"{audit['error']} plan-audit ERROR finding(s) "
                f"(see docs/ANALYSIS.md; rerun `python -m repro.analysis "
                f"plan`) — its numbers describe a plan that fails the "
                f"static audit ***")
    b_ref = baseline.get("engine", {}).get("ref_dense_ms")
    f_ref = fresh.get("engine", {}).get("ref_dense_ms")
    if b_ref and f_ref:
        lines.append(
            f"  host-speed reference (dense matmul, same warm loop): "
            f"{b_ref:.2f} ms → {f_ref:.2f} ms ({f_ref / b_ref:.2f}x) — if the "
            "gate fails and this shifted comparably, suspect the runner, not "
            "the PR")
    ratchets = []
    for be in sorted(set(base_be) & set(fresh_be)):
        b = base_be[be]["per_call_ms"]
        f = fresh_be[be]["per_call_ms"]
        ratio = f / b if b > 0 else float("inf")
        verdict = "OK"
        if ratio > 1 + threshold:
            verdict = "REGRESSION"
            regressions.append(
                f"{be}: {b:.2f} ms → {f:.2f} ms ({ratio:.2f}x > {1 + threshold:.2f}x)")
        elif ratio <= 1 / RATCHET_FACTOR:
            verdict = "RATCHET"
            ratchets.append(f"{be}: {b:.2f} ms → {f:.2f} ms ({b / f:.2f}x faster)")
        lines.append(f"  {be:9s} {b:9.2f} ms → {f:9.2f} ms  ({ratio:5.2f}x)  {verdict}")
    if ratchets:
        lines.append(
            f"ratchet: {len(ratchets)} backend(s) ≥{RATCHET_FACTOR:.1f}x faster "
            "than the committed baseline — commit the fresh "
            "BENCH_throughput.json with this PR so the gate tightens to the "
            "new numbers on merge:")
        for r in ratchets:
            lines.append("  [ratchet] " + r)
    # keys in only one file are INFO, never regressions: failing on the
    # symmetric difference broke every PR that added (or retired) a backend
    for be in sorted(set(base_be) - set(fresh_be)):
        lines.append(f"  [info] backend removed since baseline: {be}")
    for be in sorted(set(fresh_be) - set(base_be)):
        lines.append(f"  [info] backend added since baseline: {be} "
                     f"({fresh_be[be]['per_call_ms']:.2f} ms, ungated this run)")

    # families are informational (not gated): different PRs may add/resize them
    for fam, fres in sorted(fresh.get("families", {}).items()):
        bres = baseline.get("families", {}).get(fam)
        for be, v in sorted(fres.get("backends", {}).items()):
            prev = (bres or {}).get("backends", {}).get(be, {}).get("per_call_ms")
            delta = f" (was {prev:.2f})" if prev else ""
            lines.append(f"  [info] {fam}/{be}: {v['per_call_ms']:.2f} ms{delta}")

    lines, regressions = _compare_multi_plan(baseline, fresh, threshold,
                                             lines, regressions)
    lines, regressions = _compare_async_serve(baseline, fresh, threshold,
                                              lines, regressions)
    lines, regressions = _compare_sharding(baseline, fresh, threshold,
                                           lines, regressions)
    lines, regressions = _compare_overload(baseline, fresh, threshold,
                                           lines, regressions)
    lines, regressions = _compare_chaos(baseline, fresh, threshold,
                                        lines, regressions)
    return lines, regressions


# async/sync paired throughput ratio: acceptance is ≥ 0.9, but the paired
# measure still moves ~10-15% under runner throttling — gate at 0.75 so the
# line catches a real async-path tax (serialization, per-request overhead
# blowup) without flaking on shared hosts.
ASYNC_RATIO_FLOOR = 0.75


def _compare_async_serve(baseline: dict, fresh: dict, threshold: float,
                         lines: list[str], regressions: list[str]):
    """Gate the async serving sweep on the FRESH run's own invariants —
    vs_sync ratio floor and the WFQ wait ordering (both host-speed
    independent, so they hold across runner classes) — plus a cross-run
    collapse gate on absolute async flows/s like multi_plan's."""
    basy, fasy = baseline.get("async_serve"), fresh.get("async_serve")
    if not fasy:
        if basy:
            lines.append("  [info] async_serve section missing from fresh "
                         "run — async gates NOT applied (did the sweep get "
                         "dropped?)")
        return lines, regressions
    if not basy:
        lines.append("  [info] async_serve added since baseline "
                     "(cross-run collapse gate skipped; invariants gated)")
    lines.append(f"gate: async_serve — vs_sync ≥ {ASYNC_RATIO_FLOOR:.2f} "
                 "(paired ratio), WFQ high-priority p50 wait < low")

    ratio = fasy.get("vs_sync")
    if ratio is None:
        lines.append("  [info] async_serve.vs_sync missing — ratio gate "
                     "NOT applied")
    elif ratio < ASYNC_RATIO_FLOOR:
        regressions.append(
            f"async_serve: async/sync throughput ratio {ratio:.2f} < "
            f"{ASYNC_RATIO_FLOOR:.2f} floor (acceptance is ≥ 0.9)")
        lines.append(f"  vs_sync {ratio:9.2f}x  REGRESSION")
    else:
        lines.append(f"  vs_sync {ratio:9.2f}x  "
                     f"(floor {ASYNC_RATIO_FLOOR:.2f})  OK")

    wfq = fasy.get("wfq", {})
    hi, lo = wfq.get("high_p50_wait_ms"), wfq.get("low_p50_wait_ms")
    if hi is None or lo is None:
        lines.append("  [info] async_serve.wfq p50 waits missing — WFQ gate "
                     "NOT applied")
    elif hi >= lo:
        regressions.append(
            f"async_serve/wfq: high-priority p50 queue-wait {hi:.2f} ms ≥ "
            f"low-priority {lo:.2f} ms under a "
            f"{wfq.get('skew', '?')}:1 weight skew — WFQ ordering broken")
        lines.append(f"  wfq p50 wait high {hi:9.2f} ms vs low {lo:9.2f} ms  "
                     "REGRESSION")
    else:
        lines.append(f"  wfq p50 wait high {hi:9.2f} ms < low {lo:9.2f} ms  "
                     f"({wfq.get('skew', '?')}:1 skew)  OK")

    b_agg = (basy or {}).get("async_flows_s")
    f_agg = fasy.get("async_flows_s")
    if b_agg and f_agg is not None:
        _collapse_gate("async_serve", "async aggregate", b_agg, f_agg,
                       threshold, lines, regressions)
    elif basy:
        # never skip silently (same rule as multi_plan): a schema drift
        # that drops the key must be visible in the report
        lines.append("  [info] async_serve flows_s missing from "
                     f"{'baseline' if not b_agg else 'fresh'} run — "
                     "collapse gate NOT applied")
    return lines, regressions


# Multi-device scaling floor (ISSUE 7 acceptance): the serving-level stream
# aggregate's scaling efficiency at 4 simulated devices — speedup vs K=1
# normalized by min(K, host_parallelism) — must hold ≥ 0.6 on the fresh run.
# On a single-core host the normalization makes this "the device pool must
# not cost more than 40% of throughput"; on a parallel host it is a real
# scaling gate. The plan-sharded per-call numbers are info only: shard_map's
# partition/stitch overhead on one core is expected, not a regression.
SHARDING_EFF_FLOOR = 0.6


def _compare_sharding(baseline: dict, fresh: dict, threshold: float,
                      lines: list[str], regressions: list[str]):
    """Gate the multi-device sweep on the FRESH run's normalized scaling
    efficiency at 4 devices, plus a cross-run collapse gate on the K=1
    stream aggregate. A missing section is INFO, never a failure — older
    baselines predate it, and hosts without 4 XLA devices skip the sweep."""
    bsh, fsh = baseline.get("sharding"), fresh.get("sharding")
    if not fsh:
        if bsh:
            lines.append("  [info] sharding section missing from fresh run "
                         "— scaling gates NOT applied (did the sweep get "
                         "dropped?)")
        return lines, regressions
    if not bsh:
        lines.append("  [info] sharding added since baseline (cross-run "
                     "collapse gate skipped; efficiency floor gated)")
    lines.append(f"gate: sharding — serve-stream scaling efficiency @4 "
                 f"devices ≥ {SHARDING_EFF_FLOOR:.2f} "
                 f"(speedup vs K=1, normalized by min(K, host cores))")

    eff = fsh.get("scaling_efficiency_at_4")
    if eff is None:
        lines.append("  [info] sharding.scaling_efficiency_at_4 missing "
                     "(host exposes <4 XLA devices?) — efficiency gate NOT "
                     "applied")
    elif eff < SHARDING_EFF_FLOOR:
        regressions.append(
            f"sharding: scaling efficiency {eff:.2f} at 4 devices < "
            f"{SHARDING_EFF_FLOOR:.2f} floor (host_parallelism "
            f"{fsh.get('host_parallelism', '?')}) — the device streams are "
            "taxing, not scaling, serving throughput")
        lines.append(f"  eff @4dev {eff:9.2f}  "
                     f"(floor {SHARDING_EFF_FLOOR:.2f})  REGRESSION")
    else:
        lines.append(f"  eff @4dev {eff:9.2f}  "
                     f"(floor {SHARDING_EFF_FLOOR:.2f}, host_parallelism "
                     f"{fsh.get('host_parallelism', '?')})  OK")
    for k, entry in sorted(fsh.get("plan_sharded", {}).items(),
                           key=lambda kv: int(kv[0])):
        lines.append(f"  [info] plan-sharded K={k}: "
                     f"{entry.get('per_call_ms', float('nan')):.2f} ms "
                     f"({entry.get('vs_single_x', float('nan')):.2f}x vs "
                     "single; shard_map overhead is expected on 1-core "
                     "hosts, not gated)")

    b1 = (bsh or {}).get("serve_streams", {}).get("1", {}).get("flows_s")
    f1 = fsh.get("serve_streams", {}).get("1", {}).get("flows_s")
    if b1 and f1 is not None:
        _collapse_gate("sharding", "serve K=1", b1, f1,
                       threshold, lines, regressions)
    elif bsh:
        lines.append("  [info] sharding serve K=1 flows_s missing from "
                     f"{'baseline' if not b1 else 'fresh'} run — collapse "
                     "gate NOT applied")
    return lines, regressions


# Overload-sweep invariants (both host-independent, gated on the fresh run
# itself): with slack-based shedding on, the high-priority class's p99
# queue-wait under 2x overload must stay below WAIT_FACTOR x the deadline
# (a dispatched request clears the slack check with wait ≤ deadline, then
# re-stamps at group dispatch — the factor absorbs that one-round skid),
# and goodput at 2x must hold ≥ PLATEAU_FLOOR x goodput at 1x (the curve
# plateaus at capacity; without shedding every request completes late and
# goodput collapses toward 0 — the floor is far below any real plateau and
# far above any real collapse).
OVERLOAD_WAIT_FACTOR = 2.0
OVERLOAD_PLATEAU_FLOOR = 0.5


def _compare_overload(baseline: dict, fresh: dict, threshold: float,
                      lines: list[str], regressions: list[str]):
    """Gate the deadline/SLO overload sweep: fresh-run invariants (bounded
    high-priority p99 wait at 2x, goodput plateau past saturation) plus a
    cross-run collapse gate on goodput at 1x load."""
    bov, fov = baseline.get("overload"), fresh.get("overload")
    if not fov:
        if bov:
            lines.append("  [info] overload section missing from fresh run "
                         "— deadline/SLO gates NOT applied (did the sweep "
                         "get dropped?)")
        return lines, regressions
    if not bov:
        lines.append("  [info] overload added since baseline (cross-run "
                     "collapse gate skipped; invariants gated)")
    lines.append(
        f"gate: overload — hi p99 wait < {OVERLOAD_WAIT_FACTOR:.0f}x "
        f"deadline @ 2x load, goodput(2x) ≥ "
        f"{OVERLOAD_PLATEAU_FLOOR:.2f}x goodput(1x)")
    phases = fov.get("phases", {})
    deadline = fov.get("deadline_ms")
    p1, p2 = phases.get("1.0"), phases.get("2.0")
    if not deadline or not p1 or not p2:
        lines.append("  [info] overload deadline_ms or 1x/2x phases "
                     "missing — invariant gates NOT applied")
    else:
        hi99 = p2.get("hi_p99_wait_ms")
        bound = OVERLOAD_WAIT_FACTOR * deadline
        if hi99 is None:
            lines.append("  [info] overload hi_p99_wait_ms missing from 2x "
                         "phase — wait gate NOT applied")
        elif hi99 >= bound:
            regressions.append(
                f"overload: high-priority p99 queue-wait {hi99:.1f} ms ≥ "
                f"{bound:.0f} ms ({OVERLOAD_WAIT_FACTOR:.0f}x the "
                f"{deadline:.0f} ms deadline) under 2x overload — shedding "
                "is not bounding waits")
            lines.append(f"  hi p99 wait @2x {hi99:9.1f} ms "
                         f"(bound {bound:.0f} ms)  REGRESSION")
        else:
            lines.append(f"  hi p99 wait @2x {hi99:9.1f} ms < {bound:.0f} ms "
                         f"({OVERLOAD_WAIT_FACTOR:.0f}x {deadline:.0f} ms "
                         "deadline)  OK")
        g1, g2 = p1.get("goodput_flows_s"), p2.get("goodput_flows_s")
        if not g1 or g2 is None:
            lines.append("  [info] overload goodput missing from 1x/2x "
                         "phase — plateau gate NOT applied")
        else:
            ratio = g2 / g1
            if ratio < OVERLOAD_PLATEAU_FLOOR:
                regressions.append(
                    f"overload: goodput collapsed past saturation — "
                    f"{g1:.0f} flows/s at 1x load → {g2:.0f} at 2x "
                    f"({ratio:.2f}x < {OVERLOAD_PLATEAU_FLOOR:.2f} plateau "
                    "floor)")
                lines.append(f"  goodput 1x {g1:9.0f} → 2x {g2:9.0f} flows/s "
                             f"({ratio:5.2f}x)  REGRESSION")
            else:
                lines.append(f"  goodput 1x {g1:9.0f} → 2x {g2:9.0f} flows/s "
                             f"({ratio:5.2f}x ≥ {OVERLOAD_PLATEAU_FLOOR:.2f} "
                             "floor)  OK")
    b1 = (bov or {}).get("phases", {}).get("1.0", {}).get("goodput_flows_s")
    f1 = (phases.get("1.0") or {}).get("goodput_flows_s")
    if b1 and f1 is not None:
        _collapse_gate("overload", "goodput @1x", b1, f1,
                       threshold, lines, regressions)
    elif bov:
        lines.append("  [info] overload goodput @1x missing from "
                     f"{'baseline' if not b1 else 'fresh'} run — collapse "
                     "gate NOT applied")
    return lines, regressions


# goodput under an injected transient stream crash must hold at least half
# the fault-free rate over the same paced phase: migration + respawn make a
# crash cost one blip, while the failure modes this guards (lost chunks,
# a wedged drain loop, respawn storms) drag the whole phase toward 0. The
# recovery flag is binary on the fresh run: the post-fault completion rate
# must regain ≥ 90% of fault-free within the sweep's own window.
CHAOS_GOODPUT_FLOOR = 0.5


def _compare_chaos(baseline: dict, fresh: dict, threshold: float,
                   lines: list[str], regressions: list[str]):
    """Gate the fault-recovery sweep: fresh-run invariants (recovery
    completes within the sweep window, goodput under faults holds the
    floor vs fault-free) plus a cross-run collapse gate on the fault-free
    rate."""
    bch, fch = baseline.get("chaos"), fresh.get("chaos")
    if not fch:
        if bch:
            lines.append("  [info] chaos section missing from fresh run — "
                         "fault-recovery gates NOT applied (did the sweep "
                         "get dropped?)")
        return lines, regressions
    if not bch:
        lines.append("  [info] chaos added since baseline (cross-run "
                     "collapse gate skipped; invariants gated)")
    lines.append(
        f"gate: chaos — recovery within sweep window, goodput under "
        f"faults ≥ {CHAOS_GOODPUT_FLOOR:.2f}x fault-free")
    recovered = fch.get("recovered")
    recovery_s = fch.get("recovery_s")
    if recovered is None:
        lines.append("  [info] chaos recovered flag missing — recovery "
                     "gate NOT applied")
    elif not recovered:
        regressions.append(
            "chaos: post-fault completion rate never regained 90% of "
            "fault-free within the sweep window — stream supervision is "
            "not recovering capacity")
        lines.append("  recovery: not reached within window  REGRESSION")
    else:
        lines.append(f"  recovery to ≥90% capacity in {recovery_s:6.2f} s "
                     "after fault  OK")
    g_free = fch.get("fault_free_flows_s")
    g_fault = fch.get("faulted_flows_s")
    if not g_free or g_fault is None:
        lines.append("  [info] chaos fault-free/faulted flows/s missing — "
                     "goodput gate NOT applied")
    else:
        ratio = g_fault / g_free
        if ratio < CHAOS_GOODPUT_FLOOR:
            regressions.append(
                f"chaos: goodput under injected faults collapsed — "
                f"{g_free:.0f} fault-free flows/s → {g_fault:.0f} faulted "
                f"({ratio:.2f}x < {CHAOS_GOODPUT_FLOOR:.2f} floor)")
            lines.append(f"  goodput fault-free {g_free:9.0f} → faulted "
                         f"{g_fault:9.0f} flows/s ({ratio:5.2f}x)  "
                         "REGRESSION")
        else:
            lines.append(f"  goodput fault-free {g_free:9.0f} → faulted "
                         f"{g_fault:9.0f} flows/s ({ratio:5.2f}x ≥ "
                         f"{CHAOS_GOODPUT_FLOOR:.2f} floor)  OK")
    b_free = (bch or {}).get("fault_free_flows_s")
    if b_free and g_free is not None:
        _collapse_gate("chaos", "fault-free", b_free, g_free,
                       threshold, lines, regressions)
    elif bch:
        lines.append("  [info] chaos fault-free flows/s missing from "
                     f"{'baseline' if not b_free else 'fresh'} run — "
                     "collapse gate NOT applied")
    return lines, regressions


def _collapse_gate(tag: str, row: str, b_agg, f_agg, threshold: float,
                   lines: list[str], regressions: list[str]) -> None:
    """Shared cross-run collapse gate on an aggregate flows/s pair: a
    measured zero is a regression, a collapse past ``max(2x, 1+threshold)``
    is a regression, anything else is an OK line. Callers handle the
    missing-key cases (their gating conditions differ)."""
    if b_agg and f_agg == 0.0:                  # measured, literally zero
        regressions.append(f"{tag}: flows/s collapsed to 0 "
                           f"(baseline {b_agg:.0f})")
        lines.append(f"  {row} {b_agg:9.0f} → 0 flows/s  REGRESSION")
        return
    limit = max(2.0, 1 + threshold)
    ratio = b_agg / f_agg
    verdict = "OK"
    if ratio > limit:
        verdict = "REGRESSION"
        regressions.append(
            f"{tag}: {b_agg:.0f} → {f_agg:.0f} flows/s "
            f"({ratio:.2f}x slowdown > {limit:.2f}x collapse limit)")
    lines.append(f"  {row} {b_agg:9.0f} → {f_agg:9.0f} flows/s "
                 f"({ratio:5.2f}x, collapse limit {limit:.1f}x)  {verdict}")


def _compare_multi_plan(baseline: dict, fresh: dict, threshold: float,
                        lines: list[str], regressions: list[str]):
    """Gate the multi-model serving sweep: per-model served_ms over the
    model intersection + aggregate flows/s. Additions/removals are info."""
    bmp, fmp = baseline.get("multi_plan"), fresh.get("multi_plan")
    if not bmp or not fmp:
        if fmp and not bmp:
            lines.append("  [info] multi_plan added since baseline (ungated this run)")
        elif bmp and not fmp:
            lines.append("  [info] multi_plan section missing from fresh run — "
                         "collapse gate NOT applied (did the sweep get dropped?)")
        return lines, regressions
    if bmp.get("batch") != fmp.get("batch"):
        lines.append(f"  [info] multi_plan batch changed "
                     f"({bmp.get('batch')} → {fmp.get('batch')}); gate skipped")
        return lines, regressions
    limit = max(2.0, 1 + threshold)
    lines.append(f"gate: multi_plan aggregate flows/s @ batch {fmp.get('batch')}, "
                 f"{limit:.1f}x collapse limit (per-model ms are info: sub-ms "
                 "mins swing >40% run-to-run on shared runners)")
    bm, fm = bmp.get("models", {}), fmp.get("models", {})
    for name in sorted(set(bm) & set(fm)):
        b, f = bm[name].get("served_ms"), fm[name].get("served_ms")
        if b is None or f is None:
            continue
        ratio = f / b if b > 0 else float("inf")
        lines.append(f"  [info] {name:9s} {b:9.2f} ms → {f:9.2f} ms  ({ratio:5.2f}x)")
    for name in sorted(set(bm) - set(fm)):
        lines.append(f"  [info] served model removed since baseline: {name}")
    for name in sorted(set(fm) - set(bm)):
        lines.append(f"  [info] served model added since baseline: {name}")
    b_agg = bmp.get("aggregate", {}).get("flows_s")
    f_agg = fmp.get("aggregate", {}).get("flows_s")
    if b_agg and f_agg is not None:
        # collapse detector, not a fine regression meter: sustained host
        # throughput on shared runners swings ~2x between runs, so a
        # threshold-level gate on absolute flows/s flakes; the failure
        # modes this guards (retrace-per-request, scheduling livelock,
        # accidental serialization) cost 5-10x. A measured 0 is a
        # regression in its own right (handled inside the gate).
        _collapse_gate("multi_plan/aggregate", "aggregate", b_agg, f_agg,
                       threshold, lines, regressions)
    else:
        # never skip silently: this is the only multi-model gate, and a
        # schema drift that drops flows_s must be visible in the report
        lines.append("  [info] aggregate flows_s missing from "
                     f"{'baseline' if not b_agg else 'fresh'} run — "
                     "collapse gate NOT applied")
    return lines, regressions


def _append_history(history_dir: pathlib.Path, fresh_path: pathlib.Path) -> list[pathlib.Path]:
    history_dir.mkdir(parents=True, exist_ok=True)
    run_id = os.environ.get("GITHUB_RUN_ID", "local")
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    shutil.copy(fresh_path, history_dir / f"{stamp}-{run_id}.json")
    runs = sorted(history_dir.glob("*.json"))
    for old in runs[:-HISTORY_KEEP]:          # bound the cache size
        old.unlink()
    return sorted(history_dir.glob("*.json"))


def trajectory_table(runs: list[pathlib.Path], limit: int = 10) -> str:
    """Markdown table: one row per stored run, one column per backend."""
    rows = []
    backends: list[str] = []
    for p in runs[-limit:]:
        try:
            doc = _load(p)
        except (OSError, json.JSONDecodeError):
            continue
        be = _engine_backends(doc)
        if not be:
            continue
        backends = sorted(set(backends) | set(be))
        rows.append((p.stem, {k: v.get("per_call_ms") for k, v in be.items()}))
    if not rows:
        return "(no bench history yet)"
    head = "| run | " + " | ".join(f"{b} ms" for b in backends) + " |"
    sep = "|---" * (len(backends) + 1) + "|"
    body = [
        "| " + name + " | " + " | ".join(
            f"{vals.get(b):.2f}" if vals.get(b) is not None else "—"
            for b in backends) + " |"
        for name, vals in rows
    ]
    return "\n".join([head, sep, *body])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=REPO / "BENCH_throughput.json",
                    help="committed baseline JSON (gate reference)")
    ap.add_argument("--fresh", type=pathlib.Path,
                    default=REPO / "BENCH_throughput.json",
                    help="freshly produced bench JSON")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed warm per-call regression (0.25 = +25%%)")
    ap.add_argument("--history-dir", type=pathlib.Path, default=None,
                    help="append the fresh run and print a trajectory table")
    args = ap.parse_args()
    if args.baseline.resolve() == args.fresh.resolve():
        raise SystemExit(
            "compare: --baseline and --fresh resolve to the same file "
            f"({args.baseline}) — comparing a run with itself always passes. "
            "Stash the committed baseline first (e.g. `git show "
            "HEAD:BENCH_throughput.json > /tmp/baseline.json`) or write the "
            "fresh run elsewhere (`benchmarks.run --out fresh.json`).")

    baseline = _load(args.baseline)
    fresh = _load(args.fresh)
    lines, regressions = compare(baseline, fresh, args.threshold)
    report = "\n".join(lines)
    print(report)

    summary_parts = ["## Bench gate", "```", report, "```"]
    if args.history_dir is not None:
        runs = _append_history(args.history_dir, args.fresh)
        table = trajectory_table(runs)
        print("\nbench trajectory (jit-warm per-call ms):\n" + table)
        summary_parts += ["## Bench trajectory (warm per-call ms)", table]

    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write("\n".join(summary_parts) + "\n")

    if regressions:
        print("\nBENCH REGRESSION (>" + f"{args.threshold:.0%} warm per-call):",
              file=sys.stderr)
        for r in regressions:
            print("  " + r, file=sys.stderr)
        sys.exit(1)
    print("\nbench gate: PASS")


if __name__ == "__main__":
    main()
