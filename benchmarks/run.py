"""Benchmark harness: one module per paper table/figure + roofline summary.

``python -m benchmarks.run [--quick] [--only name]``

Prints one ``name,us_per_call,derived`` CSV line per benchmark at the end
(the harness contract), with the detailed tables above them.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
import traceback

# The sharding sweep (throughput.sharding_bench) needs multiple XLA devices;
# on CPU-only hosts that means simulating them. The flag must be set BEFORE
# jax initializes its backends — i.e. before the benchmark modules import —
# and is left alone when the caller exported their own XLA_FLAGS (the
# multi-device CI lane does so explicitly). Same guard as tests/conftest.py.
if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_throughput.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small sizes for CI")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    ap.add_argument("--out", type=pathlib.Path, default=BENCH_JSON,
                    help="where to write the throughput trajectory JSON")
    args = ap.parse_args()

    from benchmarks import accuracy, anomaly, flow_scalability, fusion_ablation, resources, throughput

    benches = {
        "accuracy_table5": accuracy.main,
        "resources_table6": resources.main,
        "flow_scalability_fig7": flow_scalability.main,
        "anomaly_auc_fig8": anomaly.main,
        "throughput_fig9": throughput.main,
        "fusion_ablation": fusion_ablation.main,
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    csv_lines = ["name,us_per_call,derived"]
    derived_by_name = {}
    for name, fn in benches.items():
        print(f"\n=== {name} {'(quick)' if args.quick else ''} ===")
        t0 = time.perf_counter()
        try:
            derived = fn(quick=args.quick)
            derived_by_name[name] = derived
            us = (time.perf_counter() - t0) * 1e6
            summary = ""
            if isinstance(derived, list) and derived and isinstance(derived[0], dict):
                keys = [k for k in ("f1", "auc") if k in derived[0]]
                if keys:
                    vals = [r[keys[0]] for r in derived]
                    summary = f"mean_{keys[0]}={sum(vals)/len(vals):.4f}"
            elif isinstance(derived, dict) and "speedup" in derived:
                summary = f"speedup={derived['speedup']:.0f}x"
            csv_lines.append(f"{name},{us:.0f},{summary}")
        except Exception:
            traceback.print_exc()
            csv_lines.append(f"{name},-1,FAILED")

    th = derived_by_name.get("throughput_fig9")
    if isinstance(th, dict):
        # machine-readable perf trajectory: tok/s, plan-build ms, per-call ms
        # per backend — benchmarks/compare.py gates CI on regressions vs the
        # committed copy of this file.
        args.out.write_text(json.dumps(th, indent=2, sort_keys=True) + "\n")
        print(f"\nwrote {args.out}")

    print("\n" + "\n".join(csv_lines))
    if any("FAILED" in l for l in csv_lines):
        sys.exit(1)


if __name__ == "__main__":
    main()
