"""Fig. 9 reproduction: dataplane line-rate model vs measured JAX throughput.

The switch side is a MODEL (the paper's premise: any P4 program that
compiles runs at line rate — 12.8 Tb/s on Tofino 2 regardless of DL model
size). The CPU side is MEASURED: batched dense inference in JAX on this
host. GPU numbers from the paper's setup cannot be measured here and are
reported as n/a. Clearly labeled modeled-vs-measured, per DESIGN.md §7.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.nets.mlp import mlp_apply, train_mlp

LINE_RATE_BPS = 12.8e12          # Tofino 2 aggregate
AVG_PKT_BITS = 800 * 8           # 800B average packet

def modeled_switch_pps() -> float:
    return LINE_RATE_BPS / AVG_PKT_BITS


def measured_cpu_pps(batch: int = 4096, iters: int = 20) -> tuple[float, float]:
    ds = make_dataset("peerrush", flows_per_class=300)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=150)
    x = jnp.asarray(np.tile(ds.test["stats"], (batch // len(ds.test["stats"]) + 1, 1))[:batch])

    @jax.jit
    def fwd(xb):
        return mlp_apply(m, xb)

    fwd(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fwd(x).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return batch / dt, dt * 1e6


def main(quick: bool = False):
    sw = modeled_switch_pps()
    cpu_pps, us = measured_cpu_pps(batch=1024 if quick else 4096, iters=5 if quick else 20)
    print(f"switch(modeled, line-rate) pps={sw:.3e}")
    print(f"cpu(measured, this host)   pps={cpu_pps:.3e}  us_per_batch={us:.1f}")
    print(f"speedup(modeled/measured)  {sw / cpu_pps:.0f}x")
    return dict(switch_pps=sw, cpu_pps=cpu_pps, speedup=sw / cpu_pps)


if __name__ == "__main__":
    main()
