"""Fig. 9 reproduction: dataplane line-rate model vs measured JAX throughput.

The switch side is a MODEL (the paper's premise: any P4 program that
compiles runs at line rate — 12.8 Tb/s on Tofino 2 regardless of DL model
size). The CPU side is MEASURED: batched dense inference in JAX on this
host. GPU numbers from the paper's setup cannot be measured here and are
reported as n/a. Clearly labeled modeled-vs-measured, per DESIGN.md §7.

Engine sections (the perf-trajectory JSON future PRs gate against,
see benchmarks/compare.py):
  * ``engine``       — MLP plan at batch 1024 (the acceptance anchor):
                       jit-warm vs eager per-bank dispatch vs plan-rebuild
                       cold, per backend, plus whole-plan compile counts
                       (now incl. per-bucket pad_waste + fusion coverage)
                       and a ``fusion`` A/B subsection: the same banks with
                       the cross-bank fusion pass disabled, interleaved-pair
                       timed (CI uploads it as the fusion-delta artifact).
  * ``families``     — RNN / CNN / AE plans, jit-warm per backend.
  * ``batch_ladder`` — one MLP plan called across a ladder of odd batch
                       sizes: the bucket set stays smaller than the batch
                       set, proving bucketing bounds the compile cache.
  * ``multi_plan``   — N heterogeneous models (MLP/RNN/AE) behind ONE
                       MultiModelServer: per-model warm latency through the
                       server vs the same plan called standalone at batch
                       256 (the acceptance bound: ≤ 25% overhead), plus
                       aggregate flows/s over a mixed-size request sweep.
  * ``async_serve``  — the SAME 3-model mixed load pushed through the
                       AsyncMultiModelServer's background drain loop
                       (future-returning submit, WFQ scheduling with a 4:1
                       priority skew) vs the synchronous drain() path.
                       Gated (compare.py): async/sync flows/s ratio must
                       not collapse, and the high-priority model's p50
                       queue-wait must sit below the low-priority one's.
  * ``sharding``     — multi-device scaling sweep (ISSUE 7): one plan built
                       with ``devices=K`` (shard_map over the batch axis,
                       info-only on 1-core hosts) AND the serving-level
                       aggregate — a MultiModelServer with per-device
                       executor streams draining the same typed-request mix
                       at K ∈ {1,2,4,(8)}. The stream aggregate carries the
                       gate (compare.py): scaling efficiency at 4 devices,
                       normalized by min(K, host_parallelism), must hold
                       ≥ 0.6 — real scaling on parallel hosts, "the device
                       pool must not tax throughput" on single-core CI.
  * ``overload``     — deadline/SLO sweep (ISSUE 6): paced producers push
                       offered load at 0.5x/1x/2x(/4x) of the measured
                       saturated capacity against two WFQ classes (4:1)
                       with a per-request ``deadline_ms``; goodput-within-
                       deadline must PLATEAU past saturation instead of
                       collapsing (shedding + admission control drop the
                       doomed tail), and the high-priority class's p99
                       queue-wait must stay bounded by the deadline under
                       2x overload. Gated host-independently (compare.py).
  * ``chaos``        — fault-recovery sweep (ISSUE 9): the same paced 1x
                       load twice through a two-stream async server —
                       fault-free, then with one injected transient
                       device-stream crash mid-phase (queued chunks
                       migrate, the worker respawns). Gated (compare.py,
                       host-independent): recovery to ≥ 90% of the
                       fault-free completion rate within the sweep
                       window, and goodput-under-faults ≥ 0.5x fault-free.
"""

from __future__ import annotations

import concurrent.futures
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.engine import BACKENDS, build_plan
from repro.nets.mlp import mlp_apply, pegasusify_mlp, train_mlp

LINE_RATE_BPS = 12.8e12          # Tofino 2 aggregate
AVG_PKT_BITS = 800 * 8           # 800B average packet

# acceptance anchor: the committed BENCH_throughput.json measures THIS batch;
# quick mode shrinks training/iters but never the batch, so CI quick runs
# stay comparable to the committed baseline (compare.py refuses mismatches).
ENGINE_BATCH = 1024
FAMILY_BATCH = 256


def modeled_switch_pps() -> float:
    return LINE_RATE_BPS / AVG_PKT_BITS


def measured_cpu_pps(batch: int = 4096, iters: int = 20) -> tuple[float, float]:
    ds = make_dataset("peerrush", flows_per_class=300)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=150)
    x = jnp.asarray(np.tile(ds.test["stats"], (batch // len(ds.test["stats"]) + 1, 1))[:batch])

    @jax.jit
    def fwd(xb):
        return mlp_apply(m, xb)

    fwd(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fwd(x).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return batch / dt, dt * 1e6


def _tile_to(x: np.ndarray, batch: int) -> np.ndarray:
    reps = (batch // len(x) + 1,) + (1,) * (x.ndim - 1)
    return np.tile(x, reps)[:batch]


def _timed_call(fn, iters: int) -> float:
    """Min wall ms over ``iters`` calls.

    Min, not mean/median: on shared 2-core CI runners the per-iteration
    spread is routinely 2-3x (scheduler bursts, cgroup throttling), and the
    regression gate compares absolute numbers across runs — the minimum is
    the reproducible compute floor (noise only ever ADDS latency), measured
    stable within ~10% across configs and repeats on the reference host.
    """
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn().block_until_ready()
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.min(times))


def engine_backend_bench(quick: bool = False) -> dict:
    """Whole-plan jit vs eager per-bank dispatch vs per-call plan rebuild.

    ``per_call_ms`` (the regression-gated number) is the jit-warm MIN over
    ``iters`` calls (see ``_timed_call`` for why min): one XLA computation
    per (backend, bucket), zero Python-per-bank work.
    ``per_call_eager_ms`` is the pre-jit engine behavior (plan cached, but
    every bank dispatched eagerly per call); ``per_call_cold_ms`` rebuilds
    the plan before every call (the pre-engine behavior: layout prep +
    quantization re-derived each invocation).
    """
    batch = ENGINE_BATCH
    iters = 30 if quick else 40       # warm min needs samples (see _timed_call)
    eager_iters = 5 if quick else 10
    cold_iters = 2 if quick else 5
    ds = make_dataset("peerrush", flows_per_class=120 if quick else 300)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                  steps=60 if quick else 150)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32), refine_steps=0)
    x = jnp.asarray(_tile_to(ds.test["stats"], batch), jnp.float32)

    t0 = time.perf_counter()
    plan = build_plan(banks)
    plan_build_ms = (time.perf_counter() - t0) * 1e3

    from repro.kernels.fuzzy_lut.ops import _Q8_MEMO

    result = {"plan_build_ms": plan_build_ms, "batch": batch, "iters": iters,
              "quick": quick, "backends": {},
              # plan-audit finding counts of the anchor plan (see
              # docs/ANALYSIS.md) — compare.py flags baselines whose plan
              # carried error findings
              "audit": plan.compile_stats()["audit"]}
    compile_ms_by_be = {}
    for be in BACKENDS:
        t0 = time.perf_counter()
        plan(x, backend=be).block_until_ready()            # trace + compile
        compile_ms_by_be[be] = (time.perf_counter() - t0) * 1e3

    # warm timing: interleaved rounds across backends, with a fixed dense
    # matmul reference sampled in the SAME loop. Interleaving fixes the
    # observed gate-flake mode where contiguous per-backend sampling let one
    # host-throttle burst clip every sample of exactly one backend (a
    # different backend "regressed" each run). The dense reference is a
    # host-speed DIAGNOSTIC for compare.py's report — gating on the
    # normalized ratio was tried and rejected (throttling hits the MXU-bound
    # reference and the gather-bound LUT paths differently).
    ref_a = jnp.asarray(np.random.default_rng(0).normal(
        size=(512, 512)).astype(np.float32))

    @jax.jit
    def _ref(a):
        return a @ a

    _ref(ref_a).block_until_ready()
    warm_samples: dict = {be: [] for be in BACKENDS}
    ref_samples: list = []
    rounds = 3
    per_round = max(1, iters // rounds)
    for _ in range(rounds):
        for be in BACKENDS:
            for _ in range(per_round):
                t0 = time.perf_counter()
                plan(x, backend=be).block_until_ready()
                warm_samples[be].append((time.perf_counter() - t0) * 1e3)
        for _ in range(per_round):
            t0 = time.perf_counter()
            _ref(ref_a).block_until_ready()
            ref_samples.append((time.perf_counter() - t0) * 1e3)
    ref_ms = float(np.min(ref_samples))
    result["ref_dense_ms"] = ref_ms

    for be in BACKENDS:
        compile_ms = compile_ms_by_be[be]
        warm_ms = float(np.min(warm_samples[be]))

        plan(x, backend=be, jit=False).block_until_ready()
        eager_ms = _timed_call(lambda: plan(x, backend=be, jit=False), eager_iters)

        t0 = time.perf_counter()
        for _ in range(cold_iters):
            _Q8_MEMO.clear()                               # defeat the q8 memo
            build_plan(banks)(x, backend=be, jit=False).block_until_ready()
        cold_ms = (time.perf_counter() - t0) / cold_iters * 1e3

        result["backends"][be] = {
            "per_call_ms": warm_ms,
            "per_call_vs_dense": warm_ms / ref_ms,   # diagnostic, not gated
            "per_call_eager_ms": eager_ms,
            "per_call_cold_ms": cold_ms,
            "compile_ms": compile_ms,
            "tok_s": batch / (warm_ms / 1e3),
            "jit_speedup": eager_ms / warm_ms,
            # cold/eager, NOT cold/warm: both sides run the same eager
            # per-bank mode, so this isolates plan caching from the jit win
            # (which jit_speedup reports) and stays comparable across PRs.
            "plan_cache_speedup": cold_ms / eager_ms,
        }
        print(f"engine[{be:9s}] warm {warm_ms:8.2f} ms  eager {eager_ms:8.2f} ms "
              f"cold {cold_ms:8.2f} ms  ({eager_ms / warm_ms:4.1f}x jit, "
              f"{cold_ms / eager_ms:4.1f}x vs rebuild)  "
              f"{batch / (warm_ms / 1e3):12.0f} flows/s")

    # Cross-bank fusion A/B: the SAME banks compiled without the fusion pass
    # (build_plan(fuse=False)), timed in interleaved pairs so each (fused,
    # unfused) sample shares one host-load instant — the pairwise-median
    # speedup stays meaningful through throttle bursts that shift both mins.
    # CI's bench-quick job uploads this subsection as the fusion-delta
    # artifact.
    plan_unfused = build_plan(banks, fuse=False)
    fusion = {"fused_groups": plan.fused_groups,
              "fused_banks": plan.fused_banks, "backends": {}}
    ab_iters = 10 if quick else 20
    for be in ("kernel", "kernel_q8"):
        plan_unfused(x, backend=be).block_until_ready()     # trace + compile
        fs, us = [], []
        for _ in range(ab_iters):
            t0 = time.perf_counter()
            plan(x, backend=be).block_until_ready()
            fs.append((time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            plan_unfused(x, backend=be).block_until_ready()
            us.append((time.perf_counter() - t0) * 1e3)
        fusion["backends"][be] = {
            "fused_ms": float(np.min(fs)),
            "unfused_ms": float(np.min(us)),
            "speedup": float(np.median([u / f for u, f in zip(us, fs)])),
        }
        print(f"fusion[{be:9s}] fused {np.min(fs):7.2f} ms  unfused "
              f"{np.min(us):7.2f} ms  "
              f"({fusion['backends'][be]['speedup']:4.2f}x pairwise median)")
    result["fusion"] = fusion
    result["compile"] = plan.compile_stats()
    return result


def batch_ladder_bench(quick: bool = False) -> dict:
    """Call ONE plan across a ladder of odd batch sizes.

    Bucketing means the number of compiled buckets stays below the number of
    distinct batch sizes — the trajectory JSON records both so regressions
    in the bucket policy (e.g. retrace-per-shape) are visible.
    """
    batches = (48, 64, 100, 256, 777) if quick else (48, 64, 100, 256, 777, 1024)
    iters = 5 if quick else 8
    ds = make_dataset("peerrush", flows_per_class=120)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=60)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32), refine_steps=0)
    plan = build_plan(banks)
    xs = {b: jnp.asarray(_tile_to(ds.test["stats"], b), jnp.float32) for b in batches}

    per_backend: dict = {}
    for be in ("gather", "kernel"):
        per_backend[be] = {}
        for b in batches:
            plan(xs[b], backend=be).block_until_ready()    # warm the bucket
            per_backend[be][str(b)] = _timed_call(
                lambda: plan(xs[b], backend=be), iters)
    stats = plan.compile_stats()
    buckets = sorted({bk for _, bk in stats["buckets"]})
    print(f"ladder: {len(batches)} batch sizes → {len(buckets)} buckets "
          f"{buckets}, {stats['traces']} traces, "
          f"{stats['bucket_hits']} bucket hits")
    return {"batches": list(batches), "per_backend": per_backend,
            "buckets": buckets, "traces": stats["traces"],
            "jit_calls": stats["jit_calls"]}


def _family_models(ds, quick: bool):
    """Small-but-valid teachers per family (parity needs a trained-enough
    model, not an accurate one — same trade the engine tests make)."""
    steps = 30 if quick else 60

    def rnn():
        from repro.nets.rnn import pegasusify_rnn, train_rnn

        m = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes, steps=steps)
        return pegasusify_rnn(m, ds.train["seq"], depth=4), (ds.test["seq"],)

    def cnn():
        from repro.nets.cnn import pegasusify_cnn, train_cnn

        m = train_cnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                      size="B", steps=steps)
        return pegasusify_cnn(m, ds.train["seq"], depth=5), (ds.test["seq"],)

    def ae():
        from repro.nets.autoencoder import anomaly_features, pegasusify_ae, train_autoencoder

        x = ds.train["seq"].reshape(len(ds.train["label"]), -1)
        m = train_autoencoder(x, steps=steps)
        banks = pegasusify_ae(m, x.astype(np.float32), depth=4)
        xt = ds.test["seq"].reshape(len(ds.test["label"]), -1)
        # the AE bank stack consumes the engineered feature view
        return banks, (np.asarray(anomaly_features(xt)),)

    return {"rnn": rnn, "cnn": cnn, "ae": ae}


def family_sweep(quick: bool = False) -> dict:
    """Jit-warm per-call per backend for the non-MLP families."""
    batch = FAMILY_BATCH
    iters = 8 if quick else 12
    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)
    out: dict = {}
    for fam, make in _family_models(ds, quick).items():
        model, raw_inputs = make()
        inputs = tuple(jnp.asarray(_tile_to(np.asarray(r), batch)) for r in raw_inputs)
        t0 = time.perf_counter()
        plan = build_plan(model)
        build_ms = (time.perf_counter() - t0) * 1e3
        fam_res = {"batch": batch, "plan_build_ms": build_ms, "backends": {}}
        for be in BACKENDS:
            plan(*inputs, backend=be).block_until_ready()   # trace + compile
            warm_ms = _timed_call(lambda: plan(*inputs, backend=be), iters)
            fam_res["backends"][be] = {
                "per_call_ms": warm_ms,
                "tok_s": batch / (warm_ms / 1e3),
            }
            print(f"family[{fam:4s}][{be:9s}] warm {warm_ms:8.2f} ms  "
                  f"{batch / (warm_ms / 1e3):12.0f} flows/s")
        fam_res["jit_traces"] = plan.compile_stats()["traces"]
        out[fam] = fam_res
    return out


def multi_plan_bench(quick: bool = False) -> dict:
    """N heterogeneous models behind ONE MultiModelServer (the scale step:
    one process serving mixed traffic classes, Quark/FENIX-style).

    ``served_ms`` is the warm per-model latency of one batch-256 request
    through the full server path (submit → coalesce → bucket-chunk →
    round-robin dispatch → split); ``single_ms`` is the same plan called
    standalone. ``overhead_x = served_ms / single_ms`` is the acceptance
    bound (≤ 1.25). The aggregate sweep drains a mixed-size request burst
    across every model at once and reports total flows/s.
    """
    from repro.launch.serve import MultiModelServer

    batch = FAMILY_BATCH
    iters = 10 if quick else 25
    backend = "onehot"
    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)

    fams = _family_models(ds, quick)
    makers = {"rnn": fams["rnn"], "ae": fams["ae"]}

    def mlp():
        m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                      steps=30 if quick else 60)
        banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                               refine_steps=0)
        return banks, (ds.test["stats"].astype(np.float32),)

    makers = {"mlp": mlp, **makers}

    server = MultiModelServer(backend=backend)
    inputs = {}
    result = {"batch": batch, "backend": backend, "quick": quick,
              "models": {}, "aggregate": {}}
    for name, make in makers.items():
        model, raw_inputs = make()
        inputs[name] = tuple(jnp.asarray(_tile_to(np.asarray(r), batch))
                             for r in raw_inputs)
        t0 = time.perf_counter()
        plan = server.add_model(name, model)
        build_ms = (time.perf_counter() - t0) * 1e3
        result["models"][name] = {"plan_build_ms": build_ms,
                                  "num_banks": plan.num_banks}

    for name in makers:
        plan = server.registry.get(name)
        plan(*inputs[name]).block_until_ready()             # trace + compile

        def served_once(name=name):
            server.submit(name, *inputs[name])
            return server.drain()[name][0]                  # np out: synced

        served_once()                                       # warm server path
        # interleave the two timings so host-load bursts hit both paths
        # alike; overhead_x is the MEDIAN of pairwise ratios — each adjacent
        # (single, served) pair runs under the same load, so the ratio is
        # stable even when a throttling burst outlasts the whole window and
        # shifts every min (observed 2x absolute swings on shared runners)
        singles, serveds = [], []
        for _ in range(iters):
            t0 = time.perf_counter()
            plan(*inputs[name]).block_until_ready()
            singles.append((time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
            served_once()
            serveds.append((time.perf_counter() - t0) * 1e3)
        single_ms = float(np.min(singles))
        served_ms = float(np.min(serveds))
        overhead = float(np.median([s / b for s, b in zip(serveds, singles)]))
        r = result["models"][name]
        r.update(single_ms=single_ms, served_ms=served_ms, overhead_x=overhead)
        print(f"multi[{name:4s}] single {single_ms:7.2f} ms  served "
              f"{served_ms:7.2f} ms  ({overhead:4.2f}x overhead)")

    # aggregate: a mixed-size burst across every model, drained at once.
    # Same mix in quick and full mode (like ENGINE_BATCH): the committed
    # baseline's flows/s must stay comparable to CI's quick run.
    req_sizes = (64, 256, 100, 256)
    def burst():
        for name in makers:
            for s in req_sizes:
                server.submit(name, *[x[:s] for x in inputs[name]])
        return server.drain()

    burst()                                                  # warm all buckets
    flows = sum(req_sizes) * len(makers)
    # flows/s carries CI's 2x collapse gate (compare.py): a single ~100 ms
    # timing window sits inside one host-throttle burst and swings ±45%
    # run-to-run on shared runners. Median over groups spread across
    # several seconds instead.
    groups, rounds_per_group = (4, 2) if quick else (5, 3)
    group_rates = []
    for g in range(groups):
        t0 = time.perf_counter()
        for _ in range(rounds_per_group):
            burst()
        dt = (time.perf_counter() - t0) / rounds_per_group
        group_rates.append(flows / dt)
        if g + 1 < groups:
            time.sleep(0.3)                # step past short throttle bursts
    flows_s = float(np.median(group_rates))
    result["aggregate"] = {
        "models": len(makers), "requests": len(req_sizes) * len(makers),
        "flows": flows, "wall_ms": flows / flows_s * 1e3, "flows_s": flows_s,
        "group_flows_s": [round(r) for r in group_rates],
    }
    st = server.stats()
    result["registry"] = {name: {k: m[k] for k in ("traces", "jit_calls")}
                          for name, m in st["engine"]["models"].items()}
    print(f"multi-plan aggregate: {len(makers)} models, {flows} flows/burst "
          f"→ {flows_s:.0f} flows/s median "
          f"(groups {[round(r / 1e3, 1) for r in group_rates]} kflows/s, "
          f"{st['serving']['batches_dispatched']} micro-batches total)")
    return result


def async_serve_bench(quick: bool = False) -> dict:
    """Async serving runtime vs synchronous drain under a mixed 3-model
    saturated load (ISSUE 5 acceptance).

    Both paths serve the SAME request mix through the SAME compiled plans
    (one shared PlanRegistry — zero duplicate compiles). ``sync_flows_s``
    submits a burst and calls ``drain()`` on the caller's thread;
    ``async_flows_s`` pre-fills the queues, then lets the background WFQ
    drain loop serve everything while the main thread only waits on
    futures. ``vs_sync`` is the paired ratio (acceptance: ≥ 0.9 — the
    async runtime must not tax aggregate throughput; compare.py fails the
    gate below 0.75, collapse-style, because the paired ratio still moves
    ~10-15% under runner throttling). The ``wfq`` subsection runs the
    saturated load with a 4:1 priority skew (mlp high=4.0, ae low=1.0,
    rnn in between) and records per-class p50/p90 queue-waits — the gate
    requires high.p50 < low.p50 (the scheduling invariant, robust to
    absolute host speed).
    """
    from repro.launch.serve import AsyncMultiModelServer, MultiModelServer

    backend = "onehot"
    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)
    fams = _family_models(ds, quick)

    def mlp():
        m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                      steps=30 if quick else 60)
        banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                               refine_steps=0)
        return banks, (ds.test["stats"].astype(np.float32),)

    makers = {"mlp": mlp, "rnn": fams["rnn"], "ae": fams["ae"]}
    # 4:1 WFQ skew: mlp is the high-priority class, ae the low one
    weights = {"mlp": 4.0, "rnn": 2.0, "ae": 1.0}

    sync = MultiModelServer(backend=backend)
    inputs = {}
    for name, make in makers.items():
        model, raw_inputs = make()
        inputs[name] = tuple(jnp.asarray(_tile_to(np.asarray(r), 256))
                             for r in raw_inputs)
        sync.add_model(name, model, weight=weights[name])
    # the async server SHARES the registry: same plans, same jit caches —
    # the comparison isolates the runtime, not compilation luck
    aserver = AsyncMultiModelServer(registry=sync.registry, backend=backend,
                                    queue_depth=None)
    for name in makers:
        aserver.set_priority(name, weight=weights[name])

    req_sizes = (64, 256, 100, 256)
    reps = 2 if quick else 4                     # requests per model per burst

    def fill(server, bursts=1):
        futs = []
        for _ in range(bursts * reps):
            for name in makers:
                for s in req_sizes:
                    futs.append(server.submit(
                        name, *[x[:s] for x in inputs[name]]))
        return futs

    flows = sum(req_sizes) * len(makers) * reps

    # saturated comparison: BOTH paths serve a pre-filled backlog (deep
    # queues are the steady state under line-rate ingestion — and they make
    # the coalescing opportunities identical, so the ratio isolates the
    # runtime overhead: futures, locks, thread handoff, WFQ accounting)
    groups, rounds_per_group = (3, 2) if quick else (5, 2)
    # warm every (model, bucket) at the MEASURED backlog depth: the deep
    # coalesced queues chunk into larger buckets than a single burst would,
    # and a first-group trace compile would otherwise sit inside the window
    fill(sync, bursts=rounds_per_group)
    sync.drain()
    sync_rates, async_rates = [], []
    for g in range(groups):
        # interleave sync and async groups so host-load bursts hit both
        fill(sync, bursts=rounds_per_group)
        t0 = time.perf_counter()
        sync.drain()
        sync_rates.append(flows * rounds_per_group
                          / (time.perf_counter() - t0))
        futs = fill(aserver, bursts=rounds_per_group)
        t0 = time.perf_counter()
        aserver.start()                           # loop serves the backlog
        for f in futs:
            f.result(timeout=600)
        # timed to the LAST future resolution; stop/join is teardown, not
        # serving, and stays outside the window
        async_rates.append(flows * rounds_per_group
                           / (time.perf_counter() - t0))
        aserver.stop()
        if g + 1 < groups:
            time.sleep(0.2)
    sync_flows_s = float(np.median(sync_rates))
    async_flows_s = float(np.median(async_rates))
    ratio = float(np.median([a / s for a, s in zip(async_rates, sync_rates)]))

    # WFQ skew under saturation: pre-fill every queue, ration the rounds
    # (quantum 256 flows per unit weight, so the backlog drains over many
    # DRR rounds), then let the loop schedule — queue-waits are then set
    # purely by the weighted dispatch order
    aserver.quantum = 256
    try:
        # warm pass at the WFQ quantum first: the rationed pulls coalesce
        # into different bucket sizes than the deep-backlog rate section,
        # and a trace compile inside the measured window would stall every
        # class equally and wash out the queue-wait separation
        futs = fill(aserver)
        with aserver:
            for f in futs:
                f.result(timeout=600)
        aserver.reset_latency_stats()
        futs = fill(aserver, bursts=2 if quick else 3)
        with aserver:
            for f in futs:
                f.result(timeout=600)
    finally:
        aserver.quantum = None
    lat = {name: m["queue_wait_ms"]
           for name, m in aserver.stats()["scheduler"]["latency"].items()}
    result = {
        "backend": backend, "quick": quick, "models": len(makers),
        "flows_per_burst": flows, "weights": weights,
        "sync_flows_s": sync_flows_s, "async_flows_s": async_flows_s,
        "vs_sync": ratio,
        "group_rates": {"sync": [round(r) for r in sync_rates],
                        "async": [round(r) for r in async_rates]},
        "wfq": {
            "high": "mlp", "low": "ae", "skew": weights["mlp"] / weights["ae"],
            "high_p50_wait_ms": lat["mlp"]["p50"],
            "low_p50_wait_ms": lat["ae"]["p50"],
            "per_model_wait_ms": lat,
        },
    }
    print(f"async-serve: sync {sync_flows_s:.0f} flows/s, async "
          f"{async_flows_s:.0f} flows/s ({ratio:.2f}x paired median); "
          f"wfq p50 wait high={lat['mlp']['p50']:.2f} ms "
          f"low={lat['ae']['p50']:.2f} ms "
          f"({weights['mlp'] / weights['ae']:.0f}:1 skew)")
    return result


def overload_bench(quick: bool = False) -> dict:
    """Goodput-within-deadline vs offered load (the ISSUE 6 acceptance).

    Two WFQ classes (``hi`` weight 4, ``lo`` weight 1, same tiny MLP plan)
    behind an AsyncMultiModelServer. Capacity is measured first from a
    saturated pre-filled backlog; then paced producer threads offer
    0.5x/1x/2x (full mode adds 4x) of that capacity, every request
    carrying one shared ``deadline_ms`` budget. Per phase the sweep
    records offered vs goodput flows/s and the per-class shed/reject
    counters and queue-wait percentiles (latency reservoirs reset each
    phase so percentiles describe THAT load point).

    The two host-independent invariants compare.py gates:
      * goodput(2x) must stay ≥ 0.5x goodput(1x) — the curve plateaus at
        capacity instead of collapsing (without shedding, every request
        eventually completes LATE and goodput → 0), and
      * the hi class's p99 queue-wait at 2x must stay < 2x the deadline —
        slack-based shedding bounds waits even while ``lo`` drowns.
    """
    from repro.launch.serve import AsyncMultiModelServer

    backend = "onehot"
    req = 64                                    # flows per request
    weights = {"hi": 4.0, "lo": 1.0}
    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                  steps=30 if quick else 60)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                           refine_steps=0)
    x = jnp.asarray(_tile_to(ds.test["stats"].astype(np.float32), req))

    server = AsyncMultiModelServer(backend=backend, queue_depth=None)
    # bound the coalesced slice size: the per-SLICE service time is the
    # shed-slack estimate, and the default quantum (max_batch = 4096 flows)
    # lets a saturated backlog coalesce into slices whose service time
    # exceeds ANY sane deadline — after which every deadline request sheds
    # and, with nothing served, the estimate can never decay back down
    server.quantum = 256
    for name, w in weights.items():
        server.add_model(name, banks, weight=w)

    def settle(futs):
        concurrent.futures.wait(futs, timeout=600)

    # warm EVERY bucket a coalesced slice can hit (≤ the largest per-round
    # credit, quantum x max weight): under load the backlog chunks into
    # arbitrary ladder buckets, and one cold trace compile inside a phase
    # stalls the loop for longer than the whole deadline — every queued
    # request sheds and the phase measures compile luck, not scheduling.
    # Warmed DIRECTLY through each plan (not via submit: queued warm
    # requests coalesce into merged slices, skipping the very buckets they
    # were meant to compile).
    top = int(server.quantum * max(weights.values()))
    x_big = jnp.asarray(_tile_to(ds.test["stats"].astype(np.float32), top))
    for name in weights:
        plan = server.registry.get(name)
        for b in (8, 16, 32, 64, 128, 256, 512, 1024):
            if b <= top:
                plan(x_big[:b]).block_until_ready()
    n_cap = 60 if quick else 150
    capacity = 0.0
    for measured in (False, True):
        futs = [server.submit(n, x) for _ in range(n_cap) for n in weights]
        t0 = time.perf_counter()
        server.start()
        settle(futs)
        if measured:
            capacity = len(futs) * req / (time.perf_counter() - t0)
        server.stop()

    # deadline: generous at capacity (paced queues stay near-empty), fatal
    # under sustained overload (waits grow without bound unless shed).
    # ~30 request-service-times, floored at 100 ms so timer jitter on slow
    # CI hosts can't shed a healthy 1x phase.
    deadline_ms = max(100.0, 30e3 * req / capacity)
    duration = 2.0 if quick else 3.0
    factors = (0.5, 1.0, 2.0) if quick else (0.5, 1.0, 2.0, 4.0)
    count_keys = ("admitted", "rejected", "shed", "shed_flows",
                  "served_flows", "goodput_flows", "late_flows")

    result = {"backend": backend, "quick": quick, "req_flows": req,
              "weights": weights, "capacity_flows_s": capacity,
              "deadline_ms": deadline_ms, "duration_s": duration,
              "phases": {}}
    print(f"overload: capacity {capacity:.0f} flows/s, deadline "
          f"{deadline_ms:.0f} ms, {duration:.0f} s phases")

    for factor in factors:
        server.reset_latency_stats()
        base = server.slo_counters()
        per_class = capacity * factor / len(weights)   # offered flows/s each
        futs_by: dict = {n: [] for n in weights}
        t_start = time.perf_counter()
        t_stop = t_start + duration

        def producer(name):
            # paced, not burst: submit whenever the integral of the offered
            # rate runs ahead of what was sent; 4 ms ticks keep the pacing
            # smooth at rates far above 1/tick (several submits per tick)
            sent = 0
            while (now := time.perf_counter()) < t_stop:
                target = (now - t_start) * per_class
                while sent * req < target:
                    futs_by[name].append(server.submit(
                        name, x, deadline_ms=deadline_ms))
                    sent += 1
                time.sleep(0.004)

        server.start()
        threads = [threading.Thread(target=producer, args=(n,))
                   for n in weights]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for fl in futs_by.values():
            settle(fl)                      # shed futures settle too
        elapsed = time.perf_counter() - t_start
        server.stop()

        cnt = server.slo_counters()
        lat = server._sched.latency_stats()
        per = {n: {k: cnt[n][k] - base[n][k] for k in count_keys}
               for n in weights}
        offered = sum(len(fl) for fl in futs_by.values()) * req / elapsed
        goodput = sum(p["goodput_flows"] for p in per.values()) / elapsed
        phase = {
            "offered_flows_s": offered,
            "goodput_flows_s": goodput,
            "hi_goodput_flows_s": per["hi"]["goodput_flows"] / elapsed,
            "hi_p99_wait_ms": lat.get("hi", {}).get(
                "queue_wait_ms", {}).get("p99"),
            "lo_p99_wait_ms": lat.get("lo", {}).get(
                "queue_wait_ms", {}).get("p99"),
            "elapsed_s": elapsed,
            "per_class": per,
        }
        result["phases"][str(factor)] = phase
        shed = sum(p["shed"] + p["rejected"] for p in per.values())
        print(f"overload[{factor:3.1f}x] offered {offered:8.0f} flows/s  "
              f"goodput {goodput:8.0f} flows/s  shed+rej {shed:5d}  "
              f"hi p99 wait {phase['hi_p99_wait_ms'] or 0:7.1f} ms")
    return result


def sharding_bench(quick: bool = False) -> dict:
    """Multi-device scaling sweep (ISSUE 7 tentpole).

    Two modes, measured separately because they answer different questions:

      * ``plan_sharded`` — ONE plan built with ``devices=K``: the batch axis
        sharded over a K-device mesh via ``shard_map``, bank operands
        replicated, timed jit-warm at the engine batch. On a host with real
        parallel execution streams this is the scaling headline; on the
        1-core CI host (XLA "devices" simulated via
        ``--xla_force_host_platform_device_count``) the partition/stitch
        work is all cost and no win — recorded as INFO, never gated.
      * ``serve_streams`` — the serving-level aggregate that CARRIES the
        gate: a ``MultiModelServer(devices=K)`` (per-device executor
        streams, least-loaded chunk placement) drains the identical
        typed-request mix at every K. ``scaling_efficiency`` normalizes the
        speedup vs K=1 by ``min(K, host_parallelism)``, so a genuinely
        parallel host gates on real scaling while a single-core host gates
        on "the device pool must not tax throughput" — the same 0.6 floor
        catches both regressions (lock convoys, placement pathologies,
        per-device retrace storms) without flaking on host shape.
    """
    import os

    from repro.launch.serve import InferRequest, MultiModelServer

    backend = "onehot"
    n_dev = jax.device_count()
    try:
        host_par = len(os.sched_getaffinity(0))
    except AttributeError:                       # non-Linux fallback
        host_par = os.cpu_count() or 1
    ks = [k for k in ((1, 2, 4) if quick else (1, 2, 4, 8)) if k <= n_dev]

    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                  steps=30 if quick else 60)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                           refine_steps=0)
    batch = ENGINE_BATCH
    x = jnp.asarray(_tile_to(ds.test["stats"].astype(np.float32), batch))

    result = {"backend": backend, "quick": quick, "batch": batch,
              "devices_available": n_dev, "host_parallelism": host_par,
              "ks": ks, "plan_sharded": {}, "serve_streams": {}}

    # --- plan-sharded per-call (info): shard_map overhead vs single-device
    iters = 6 if quick else 10
    single_ms = None
    for k in ks:
        plan = build_plan(banks, devices=k if k > 1 else None)
        plan(x, backend=backend).block_until_ready()       # trace + compile
        ms = _timed_call(lambda: plan(x, backend=backend), iters)
        entry = {"per_call_ms": ms, "flows_s": batch / (ms / 1e3)}
        if k == 1:
            single_ms = ms
        entry["vs_single_x"] = ms / single_ms
        result["plan_sharded"][str(k)] = entry
        print(f"sharding[plan K={k}] warm {ms:8.2f} ms "
              f"({ms / single_ms:4.2f}x vs single)  "
              f"{batch / (ms / 1e3):12.0f} flows/s")

    # --- serving-level stream aggregate (the gated number). Every K —
    # including 1 — runs the SAME per-device-stream code path (an explicit
    # devices=1 builds a one-stream pool), so the efficiency curve measures
    # stream scaling, not two different host-conversion strategies.
    # max_batch caps chunks at 512 flows so each drain produces several
    # chunks and the least-loaded placement actually spreads work.
    from repro.engine import bucket_chunks

    req_sizes = (64, 256, 100, 128)
    reps = 2 if quick else 3
    flows = sum(req_sizes) * reps
    serve_max_batch = 512
    for k in ks:
        server = MultiModelServer(backend=backend, devices=k,
                                  max_batch=serve_max_batch)
        server.add_model("mlp", banks)
        plan = server.registry.get("mlp")
        # warm every (bucket, device) pair a coalesced chunk will land on:
        # placed mode keeps one state replica per device and a first-touch
        # trace inside the timed window would charge compile luck to K
        warm_sizes = sorted(set(bucket_chunks(flows, plan.buckets,
                                              serve_max_batch)))
        for d in jax.devices()[:k]:
            for b in warm_sizes:
                plan(x[:b], device=d).block_until_ready()

        def burst():
            for _ in range(reps):
                for s in req_sizes:
                    server.submit(InferRequest("mlp", x[:s]))
            server.drain()

        burst()                                   # warm the server path too
        groups, rounds_per_group = (4, 2) if quick else (5, 3)
        rates = []
        for g in range(groups):
            t0 = time.perf_counter()
            for _ in range(rounds_per_group):
                burst()
            rates.append(flows / ((time.perf_counter() - t0)
                                  / rounds_per_group))
            if g + 1 < groups:
                time.sleep(0.2)
        dev_st = server.stats()["devices"]
        server.close()
        result["serve_streams"][str(k)] = {
            "flows_s": float(np.median(rates)),
            "group_flows_s": [round(r) for r in rates],
            "devices_used": sum(1 for d in dev_st["per_device"]
                                if d["dispatched_chunks"] > 0),
        }

    f1 = result["serve_streams"]["1"]["flows_s"]
    for k in ks:
        entry = result["serve_streams"][str(k)]
        entry["speedup_vs_1"] = entry["flows_s"] / f1
        entry["scaling_efficiency"] = (entry["speedup_vs_1"]
                                       / min(k, host_par))
        print(f"sharding[serve K={k}] {entry['flows_s']:10.0f} flows/s  "
              f"speedup {entry['speedup_vs_1']:4.2f}x  eff "
              f"{entry['scaling_efficiency']:4.2f} "
              f"(norm /{min(k, host_par)}, {entry['devices_used']} "
              "streams used)")
    result["scaling_efficiency_at_4"] = (
        result["serve_streams"].get("4", {}).get("scaling_efficiency"))
    return result


def chaos_bench(quick: bool = False) -> dict:
    """Fault-recovery sweep (ISSUE 9): goodput under an injected crash.

    One tiny MLP behind an AsyncMultiModelServer with two device streams.
    Capacity is measured from a saturated backlog, then two identical
    paced phases offer 1x that capacity:

      * fault-free — the baseline goodput (completed flows/s, submit to
        last completion), and
      * faulted — the same load, with the chaos injector arming a single
        transient ``stream_dispatch`` crash at 40% of the phase. The
        in-flight and queued chunks migrate to the surviving stream and
        the dead worker respawns with backoff; nothing carries a
        deadline, so every future must still resolve.

    Recovery is read off the completion timestamps: ``recovery_s`` is the
    end of the first post-fault sliding window (``window_s`` wide, 0.1 s
    steps) whose completion rate regains ≥ 90% of the fault-free rate.

    The two host-independent invariants compare.py gates on the fresh
    run: recovery completes within the sweep window (``recovered``) and
    ``goodput_ratio`` (faulted / fault-free flows/s) holds ≥ 0.5 — a
    crash must cost a blip, not the phase.
    """
    from repro.launch.chaos import FaultInjector
    from repro.launch.serve import AsyncMultiModelServer

    backend = "onehot"
    req = 64                                    # flows per request
    devices = min(2, jax.device_count())
    ds = make_dataset("peerrush", flows_per_class=48 if quick else 96)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                  steps=30 if quick else 60)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                           refine_steps=0)
    x = jnp.asarray(_tile_to(ds.test["stats"].astype(np.float32), req))

    server = AsyncMultiModelServer(backend=backend, devices=devices,
                                   queue_depth=None)
    server.quantum = 256        # bound slice size (same rationale as overload)
    server.add_model("mlp", banks)

    # warm every (bucket, device) pair a coalesced chunk can land on — a
    # cold trace inside a timed phase would charge compile luck to the
    # recovery clock (same rationale as the overload/sharding warms).
    top = int(server.quantum)
    x_big = jnp.asarray(_tile_to(ds.test["stats"].astype(np.float32), top))
    plan = server.registry.get("mlp")
    for d in jax.devices()[:devices]:
        for b in (8, 16, 32, 64, 128, 256):
            if b <= top:
                plan(x_big[:b], device=d).block_until_ready()

    def settle(futs):
        concurrent.futures.wait(futs, timeout=600)

    n_cap = 40 if quick else 100
    capacity = 0.0
    for measured in (False, True):
        futs = [server.submit("mlp", x) for _ in range(n_cap)]
        t0 = time.perf_counter()
        server.start()
        settle(futs)
        if measured:
            capacity = n_cap * req / (time.perf_counter() - t0)
        server.stop()

    duration = 2.0 if quick else 3.0
    window = 0.5
    fault_at = 0.4 * duration

    inj = FaultInjector(seed=0)
    inj.armed = False                 # armed mid-phase, at the fault time
    inj.inject("stream_dispatch", stream=0, after=1, count=1)
    server.install_chaos(inj)

    def run_phase(fault: bool) -> dict:
        done_t: list[float] = []
        done_lock = threading.Lock()

        def on_done(_f):
            now = time.perf_counter()
            with done_lock:
                done_t.append(now)

        futs = []
        sent = 0
        armed = False
        server.start()
        t_start = time.perf_counter()
        t_stop = t_start + duration
        while (now := time.perf_counter()) < t_stop:
            if fault and not armed and now - t_start >= fault_at:
                inj.armed = True      # next stream-0 dispatch crashes
                armed = True
            target = (now - t_start) * capacity
            while sent * req < target:
                f = server.submit("mlp", x)
                f.add_done_callback(on_done)
                futs.append(f)
                sent += 1
            time.sleep(0.004)
        settle(futs)                  # no deadlines: ALL must resolve
        server.stop()
        ok = sum(1 for f in futs if f.exception() is None)
        rel = sorted(t - t_start for t in done_t)
        elapsed = rel[-1] if rel else duration
        return {"sent": sent, "ok": ok, "elapsed_s": elapsed,
                "flows_s": ok * req / elapsed, "rel_done": rel}

    free = run_phase(fault=False)
    faulted = run_phase(fault=True)
    dev_st = server.stats()["devices"]
    server.close()

    # sliding-window recovery clock over the faulted phase's completions
    rel = np.asarray(faulted.pop("rel_done"))
    free.pop("rel_done")
    target_rate = 0.9 * free["flows_s"]
    recovery_s = None
    w = fault_at
    while w + window <= faulted["elapsed_s"] + 1e-9:
        in_win = np.count_nonzero((rel >= w) & (rel < w + window))
        if in_win * req / window >= target_rate:
            recovery_s = w + window - fault_at
            break
        w += 0.1

    result = {
        "backend": backend, "quick": quick, "req_flows": req,
        "devices": devices, "capacity_flows_s": capacity,
        "duration_s": duration, "window_s": window, "fault_at_s": fault_at,
        "fault_free_flows_s": free["flows_s"],
        "faulted_flows_s": faulted["flows_s"],
        "goodput_ratio": faulted["flows_s"] / free["flows_s"],
        "recovery_s": recovery_s, "recovered": recovery_s is not None,
        "fault_free": free, "faulted": faulted,
        "crashes": sum(d["crashes"] for d in dev_st["per_device"]),
        "respawns": sum(d["respawns"] for d in dev_st["per_device"]),
        "migrated_chunks": dev_st["migrated_chunks"],
        "chaos": inj.stats(),
    }
    print(f"chaos: fault-free {free['flows_s']:8.0f} flows/s  faulted "
          f"{faulted['flows_s']:8.0f} flows/s  ratio "
          f"{result['goodput_ratio']:4.2f}  recovery "
          f"{recovery_s if recovery_s is not None else float('nan'):.2f} s  "
          f"(crashes {result['crashes']}, migrated "
          f"{result['migrated_chunks']}, respawns {result['respawns']})")
    return result


def main(quick: bool = False):
    sw = modeled_switch_pps()
    cpu_pps, us = measured_cpu_pps(batch=1024 if quick else 4096, iters=5 if quick else 20)
    print(f"switch(modeled, line-rate) pps={sw:.3e}")
    print(f"cpu(measured, this host)   pps={cpu_pps:.3e}  us_per_batch={us:.1f}")
    print(f"speedup(modeled/measured)  {sw / cpu_pps:.0f}x")
    engine = engine_backend_bench(quick=quick)
    ladder = batch_ladder_bench(quick=quick)
    families = family_sweep(quick=quick)
    multi = multi_plan_bench(quick=quick)
    async_serve = async_serve_bench(quick=quick)
    sharding = sharding_bench(quick=quick)
    overload = overload_bench(quick=quick)
    chaos = chaos_bench(quick=quick)
    return dict(switch_pps=sw, cpu_pps=cpu_pps, speedup=sw / cpu_pps,
                audit=engine.get("audit"), engine=engine,
                batch_ladder=ladder, families=families,
                multi_plan=multi, async_serve=async_serve,
                sharding=sharding, overload=overload, chaos=chaos)


if __name__ == "__main__":
    main()
