"""Fig. 9 reproduction: dataplane line-rate model vs measured JAX throughput.

The switch side is a MODEL (the paper's premise: any P4 program that
compiles runs at line rate — 12.8 Tb/s on Tofino 2 regardless of DL model
size). The CPU side is MEASURED: batched dense inference in JAX on this
host. GPU numbers from the paper's setup cannot be measured here and are
reported as n/a. Clearly labeled modeled-vs-measured, per DESIGN.md §7.
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.engine import BACKENDS, build_plan
from repro.nets.mlp import mlp_apply, pegasusify_mlp, train_mlp

LINE_RATE_BPS = 12.8e12          # Tofino 2 aggregate
AVG_PKT_BITS = 800 * 8           # 800B average packet

def modeled_switch_pps() -> float:
    return LINE_RATE_BPS / AVG_PKT_BITS


def measured_cpu_pps(batch: int = 4096, iters: int = 20) -> tuple[float, float]:
    ds = make_dataset("peerrush", flows_per_class=300)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=150)
    x = jnp.asarray(np.tile(ds.test["stats"], (batch // len(ds.test["stats"]) + 1, 1))[:batch])

    @jax.jit
    def fwd(xb):
        return mlp_apply(m, xb)

    fwd(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fwd(x).block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return batch / dt, dt * 1e6


def engine_backend_bench(quick: bool = False) -> dict:
    """Plan caching vs per-call plan rebuild, per engine backend.

    ``cold`` rebuilds the ExecutionPlan before every call; ``warm`` reuses
    ONE plan. For the kernel/kernel_q8 backends cold matches the pre-engine
    per-call behavior (one-hots, padding, quantization re-derived each
    invocation); for gather/onehot — which never needed layouts — the ratio
    measures pure plan-build overhead, not a pre-engine regression.
    """
    batch = 256 if quick else 1024
    iters = 3 if quick else 10
    ds = make_dataset("peerrush", flows_per_class=120 if quick else 300)
    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                  steps=60 if quick else 150)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32), refine_steps=0)
    x = jnp.asarray(
        np.tile(ds.test["stats"], (batch // len(ds.test["stats"]) + 1, 1))[:batch],
        jnp.float32)

    t0 = time.perf_counter()
    plan = build_plan(banks)
    plan_build_ms = (time.perf_counter() - t0) * 1e3

    from repro.kernels.fuzzy_lut.ops import _Q8_MEMO

    result = {"plan_build_ms": plan_build_ms, "batch": batch, "iters": iters,
              "quick": quick, "backends": {}}
    for be in BACKENDS:
        plan(x, backend=be).block_until_ready()            # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            plan(x, backend=be).block_until_ready()
        warm_ms = (time.perf_counter() - t0) / iters * 1e3

        t0 = time.perf_counter()
        for _ in range(iters):
            _Q8_MEMO.clear()                               # defeat the q8 memo
            build_plan(banks)(x, backend=be).block_until_ready()
        cold_ms = (time.perf_counter() - t0) / iters * 1e3

        result["backends"][be] = {
            "per_call_ms": warm_ms,
            "per_call_cold_ms": cold_ms,
            "tok_s": batch / (warm_ms / 1e3),
            "plan_cache_speedup": cold_ms / warm_ms,
        }
        print(f"engine[{be:9s}] warm {warm_ms:8.2f} ms  cold {cold_ms:8.2f} ms "
              f"({cold_ms / warm_ms:5.1f}x)  {batch / (warm_ms / 1e3):12.0f} flows/s")
    return result


def main(quick: bool = False):
    sw = modeled_switch_pps()
    cpu_pps, us = measured_cpu_pps(batch=1024 if quick else 4096, iters=5 if quick else 20)
    print(f"switch(modeled, line-rate) pps={sw:.3e}")
    print(f"cpu(measured, this host)   pps={cpu_pps:.3e}  us_per_batch={us:.1f}")
    print(f"speedup(modeled/measured)  {sw / cpu_pps:.0f}x")
    engine = engine_backend_bench(quick=quick)
    return dict(switch_pps=sw, cpu_pps=cpu_pps, speedup=sw / cpu_pps, engine=engine)


if __name__ == "__main__":
    main()
