"""Fig. 7 reproduction: accuracy vs per-flow storage for CNN-L.

Per-flow register cost (paper §7.3): 16b previous-packet timestamp (IPD) +
(W-1) × index_bits of stored fuzzy indexes. Variants: 28b (4b idx, no IPD),
44b (4b idx + IPD), 72b (8b idx + IPD).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.nets.cnn import (
    cnn_l_apply, pegasus_cnn_l_apply, pegasusify_cnn_l, train_cnn_l,
)
from repro.nets.common import macro_f1

VARIANTS = [
    # (label, index_bits, use_ipd)
    ("28b/flow (4b idx, no IPD)", 4, False),
    ("44b/flow (4b idx + IPD)", 4, True),
    ("72b/flow (8b idx + IPD)", 8, True),
]


def run(flows_per_class: int = 800, steps: int = 600, datasets=("peerrush",)):
    rows = []
    for name in datasets:
        ds = make_dataset(name, flows_per_class=flows_per_class)
        seq, payload, y = ds.train["seq"], ds.train["bytes"], ds.train["label"]
        t_seq, t_payload, t_y = ds.test["seq"], ds.test["bytes"], ds.test["label"]
        nc = ds.num_classes
        for label, bits, use_ipd in VARIANTS:
            sq, tsq = seq.copy(), t_seq.copy()
            if not use_ipd:
                sq[..., 1] = 0
                tsq[..., 1] = 0
            m = train_cnn_l(sq, payload, y, nc, steps=steps)
            peg = pegasusify_cnn_l(m, sq, payload, index_bits=bits)
            pred = np.asarray(
                pegasus_cnn_l_apply(peg, jnp.asarray(tsq), jnp.asarray(t_payload))
            ).argmax(-1)
            flow_bits = (16 if use_ipd else 0) + 7 * bits
            # SRAM to hold 1M flows at this per-flow width (Fig. 7 x-axis)
            sram_mb_1m = flow_bits * 1_000_000 / 8 / 1024 / 1024
            rows.append(dict(dataset=name, variant=label, flow_bits=flow_bits,
                             sram_mb_for_1M_flows=round(sram_mb_1m, 1),
                             f1=round(macro_f1(pred, t_y, nc), 4)))
    return rows


def main(quick: bool = False):
    rows = run(flows_per_class=300 if quick else 800, steps=250 if quick else 600)
    for r in rows:
        print(f"{r['dataset']:<10} {r['variant']:<28} {r['flow_bits']:>4}b/flow "
              f"{r['sram_mb_for_1M_flows']:>6}MB/1Mflows F1={r['f1']}")
    return rows


if __name__ == "__main__":
    main()
