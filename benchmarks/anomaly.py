"""Fig. 8 reproduction: AutoEncoder AUC for unknown-attack detection.

Train on benign flows only; score = MAE reconstruction error (deployed,
table-routed form); report AUROC per (dataset × attack kind).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import DATASETS, anomaly_testset, make_dataset
from repro.nets.autoencoder import (
    auc_score, pegasus_ae_error, pegasusify_ae, train_autoencoder,
)


def run(flows_per_class: int = 800, steps: int = 800, datasets=None):
    rows = []
    for name in datasets or DATASETS:
        ds = make_dataset(name, flows_per_class=flows_per_class)
        x_train = ds.train["seq"].reshape(len(ds.train["label"]), -1)
        ae = train_autoencoder(x_train, steps=steps)
        banks = pegasusify_ae(ae, x_train.astype(np.float32))
        for kind in ("malware", "dos"):
            test = anomaly_testset(ds, kind=kind)
            x = test["seq"].reshape(len(test["label"]), -1)
            scores = np.asarray(pegasus_ae_error(banks, jnp.asarray(x, jnp.float32)))
            rows.append(dict(dataset=name, attack=kind,
                             auc=round(auc_score(scores, test["label"]), 4)))
    return rows


def main(quick: bool = False):
    rows = run(flows_per_class=300 if quick else 800, steps=300 if quick else 800,
               datasets=["peerrush"] if quick else None)
    for r in rows:
        print(f"{r['dataset']:<10} {r['attack']:<8} AUC={r['auc']}")
    return rows


if __name__ == "__main__":
    main()
