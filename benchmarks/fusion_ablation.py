"""§4.3 ablation: lookups per inference before/after each fusion level,
plus wall-time of the three Pegasus apply paths (gather / one-hot / kernel).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MapOp, PartitionOp, PrimitiveGraph, SumReduceOp,
    advanced_nam, advanced_remove_nonlinear, fuse_basic, init_pegasus_linear,
)
from repro.core.fusion import identity
from repro.core.amm import apply_gather, apply_onehot
from repro.kernels.fuzzy_lut.ops import fuzzy_lut_matmul


def _mlp_graph(d=16, h=32, o=4, seed=0):
    """Paper Fig. 5 'initial' layout: BN,FC,ReLU ×2 + head as primitives."""
    rng = np.random.default_rng(seed)
    k, v = d // 4, 4
    w1 = jnp.asarray(rng.normal(size=(d, h)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(h, o)), jnp.float32)
    ops = [
        PartitionOp(dim=v),
        MapOp(fn=lambda xg: 1.1 * xg, linear=True, in_dim=v, out_dim=v,
              table_entries=64, bias=jnp.zeros((k, v)), name="bn1"),
        MapOp(fn=lambda xg: jnp.einsum("...kv,kvn->...kn", xg, w1.reshape(k, v, h)),
              linear=True, in_dim=v, out_dim=h, table_entries=64, name="fc1"),
        SumReduceOp(),
        MapOp(fn=identity, linear=True, in_dim=h, out_dim=h, table_entries=0,
              bias=b1, name="bias1"),
        MapOp(fn=jax.nn.relu, linear=False, in_dim=h, out_dim=h,
              table_entries=64, name="relu"),
        MapOp(fn=lambda x: x @ w2, linear=True, in_dim=h, out_dim=o,
              table_entries=64, name="fc2"),
    ]
    return PrimitiveGraph(ops)


def main(quick: bool = False):
    g = _mlp_graph()
    basic = fuse_basic(g)
    lin = advanced_remove_nonlinear(g)
    nam = advanced_nam(g)
    print(f"lookups initial={g.num_lookups()} basic={basic.num_lookups()} "
          f"adv-linear={lin.num_lookups()} adv-NAM={nam.num_lookups()}")

    # apply-path timing for one PegasusLinear
    rng = np.random.default_rng(0)
    d, n, t = 256, 256, 2048 if not quick else 256
    w = rng.normal(size=(d, n)).astype(np.float32) / np.sqrt(d)
    calib = rng.normal(size=(4096, d)).astype(np.float32)
    layer = init_pegasus_linear(w, None, calib, group_size=4, depth=4, lut_bits=None)
    x = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))

    for name, fn in [
        ("gather", jax.jit(lambda xb: apply_gather(layer, xb))),
        ("onehot", jax.jit(lambda xb: apply_onehot(layer, xb))),
        ("kernel(interp)", lambda xb: fuzzy_lut_matmul(layer, xb, block_t=256, block_n=128, block_k=32)),
    ]:
        fn(x).block_until_ready()
        t0 = time.perf_counter()
        iters = 3 if "kernel" in name else 20
        for _ in range(iters):
            fn(x).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        print(f"apply-path {name:<16} {us:10.1f} us/call  [T={t},D={d},N={n}]")


if __name__ == "__main__":
    main()
