"""Table 5 reproduction: classification accuracy across methods × datasets.

Columns: method, input scale (bits), model size (Kb), PR, RC, F1 per dataset.
Synthetic stand-ins for PeerRush/CICIOT/ISCXVPN (see data/synthetic_traffic).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import DATASETS, make_dataset
from repro.nets.common import macro_f1, precision_recall
from repro.nets.mlp import train_mlp, mlp_apply, pegasusify_mlp, pegasus_mlp_apply
from repro.nets.rnn import train_rnn, pegasusify_rnn, pegasus_rnn_apply
from repro.nets.cnn import (
    train_cnn, pegasusify_cnn, pegasus_cnn_apply,
    train_cnn_l, pegasusify_cnn_l, pegasus_cnn_l_apply,
)
from repro.nets.baselines.leo import train_leo, leo_predict
from repro.nets.baselines.n3ic import train_n3ic, n3ic_apply, n3ic_model_bits
from repro.nets.baselines.bos import train_bos, bos_apply, bos_table_entries


def _peg_size_kb(layers) -> float:
    """Deployed model size = stored table bits (16b words), as the paper counts."""
    bits = 0
    for l in layers:
        bits += int(np.prod(l.lut.shape)) * 16
        bits += int(np.prod(l.trees.thresholds.shape)) * 16
    return bits / 1024.0


def run(flows_per_class: int = 1000, steps: int = 600, datasets=None) -> list[dict]:
    rows = []
    for name in datasets or DATASETS:
        ds = make_dataset(name, flows_per_class=flows_per_class)
        stats, seq, payload, y = (
            ds.train["stats"], ds.train["seq"], ds.train["bytes"], ds.train["label"])
        t_stats, t_seq, t_payload, t_y = (
            ds.test["stats"], ds.test["seq"], ds.test["bytes"], ds.test["label"])
        nc = ds.num_classes

        def rec(method, pred, input_bits, size_kb):
            pr, rc = precision_recall(pred, t_y, nc)
            rows.append(dict(dataset=name, method=method, input_bits=input_bits,
                             size_kb=round(size_kb, 1), pr=round(pr, 4),
                             rc=round(rc, 4), f1=round(macro_f1(pred, t_y, nc), 4)))

        # --- statistical-feature family (same 128-bit input) ---
        leo = train_leo(stats, y, nc, max_nodes=1024)
        rec("Leo(DT)", leo_predict(leo, t_stats), 128, 0.0)

        n3 = train_n3ic(stats, y, nc, steps=steps)
        pred = np.asarray(n3ic_apply(n3, jnp.asarray(t_stats))).argmax(-1)
        rec("N3IC(binMLP)", pred, 128, n3ic_model_bits(n3) / 1024.0)

        mlp = train_mlp(stats, y, nc, steps=steps)
        peg = pegasusify_mlp(mlp, stats.astype(np.float32), refine_steps=80)
        pred = np.asarray(pegasus_mlp_apply(peg, jnp.asarray(t_stats, jnp.float32))).argmax(-1)
        rec("MLP-B", pred, 128, _peg_size_kb(peg))

        # --- raw-sequence family ---
        bos = train_bos(seq, y, nc, steps=steps)
        pred = np.asarray(bos_apply(bos, jnp.asarray(t_seq))).argmax(-1)
        rec("BoS(binRNN)", pred, 18, bos_table_entries() * 8 / 1024.0)

        rnn = train_rnn(seq, y, nc, steps=steps)
        peg = pegasusify_rnn(rnn, seq)
        pred = np.asarray(pegasus_rnn_apply(peg, jnp.asarray(t_seq))).argmax(-1)
        rec("RNN-B", pred, 128, _peg_size_kb(peg.x_banks + peg.h_banks + [peg.out_bank]))

        for size in ("B", "M"):
            cnn = train_cnn(seq, y, nc, size=size, steps=steps)
            pegc = pegasusify_cnn(cnn, seq)
            pred = np.asarray(pegasus_cnn_apply(pegc, jnp.asarray(t_seq))).argmax(-1)
            rec(f"CNN-{size}", pred, 128,
                _peg_size_kb([pegc.window_bank] + pegc.head_banks))

        cnnl = train_cnn_l(seq, payload, y, nc, steps=steps)
        pegl = pegasusify_cnn_l(cnnl, seq, payload, index_bits=8)
        pred = np.asarray(
            pegasus_cnn_l_apply(pegl, jnp.asarray(t_seq), jnp.asarray(t_payload))
        ).argmax(-1)
        rec("CNN-L", pred, 3840, _peg_size_kb([pegl.bank1, pegl.bank2]))
    return rows


def main(quick: bool = False):
    rows = run(flows_per_class=400 if quick else 1000, steps=300 if quick else 600,
               datasets=["peerrush"] if quick else None)
    print(f"{'dataset':<10} {'method':<14} {'in(b)':>6} {'size(Kb)':>9} "
          f"{'PR':>7} {'RC':>7} {'F1':>7}")
    for r in rows:
        print(f"{r['dataset']:<10} {r['method']:<14} {r['input_bits']:>6} "
              f"{r['size_kb']:>9} {r['pr']:>7} {r['rc']:>7} {r['f1']:>7}")
    return rows


if __name__ == "__main__":
    main()
