"""Table 6 reproduction: hardware resource utilization per model.

Compiles each Pegasus model's fused banks to the Tofino-2 MAT emulator and
reports stateful bits/flow, SRAM%, TCAM%, action-bus% — the paper's columns.
"""

from __future__ import annotations

import numpy as np

from repro.data.synthetic_traffic import make_dataset
from repro.dataplane.compile import compile_model
from repro.nets.autoencoder import pegasusify_ae, train_autoencoder
from repro.nets.cnn import (
    pegasusify_cnn, pegasusify_cnn_l, train_cnn, train_cnn_l,
)
from repro.nets.mlp import pegasusify_mlp, train_mlp
from repro.nets.rnn import pegasusify_rnn, train_rnn

# stateful per-flow bits (paper Table 6 / §7.3 accounting)
STATEFUL = {
    "MLP-B": 80,        # min/max len + IPD accumulators
    "RNN-B": 240,       # 8 steps × (len,ipd) + timestamps
    "CNN-B": 72,
    "CNN-M": 72,
    "CNN-L": 44,        # 16b prev-timestamp + 7 × 4b fuzzy index
    "AutoEncoder": 240,
}


def run(flows_per_class: int = 600, steps: int = 400):
    ds = make_dataset("peerrush", flows_per_class=flows_per_class)
    stats, seq, payload, y = (
        ds.train["stats"], ds.train["seq"], ds.train["bytes"], ds.train["label"])
    nc = ds.num_classes
    reports = {}

    mlp = train_mlp(stats, y, nc, steps=steps)
    layers = pegasusify_mlp(mlp, stats.astype(np.float32), refine_steps=0)
    reports["MLP-B"] = compile_model(layers, stateful_bits_per_flow=STATEFUL["MLP-B"]).report()

    rnn = train_rnn(seq, y, nc, steps=steps)
    peg = pegasusify_rnn(rnn, seq)
    reports["RNN-B"] = compile_model(
        peg.x_banks + peg.h_banks + [peg.out_bank],
        stateful_bits_per_flow=STATEFUL["RNN-B"],
    ).report()

    for size in ("B", "M"):
        cnn = train_cnn(seq, y, nc, size=size, steps=steps)
        pegc = pegasusify_cnn(cnn, seq)
        reports[f"CNN-{size}"] = compile_model(
            [pegc.window_bank] + pegc.head_banks,
            stateful_bits_per_flow=STATEFUL[f"CNN-{size}"],
        ).report()

    cnnl = train_cnn_l(seq, payload, y, nc, steps=steps)
    pegl = pegasusify_cnn_l(cnnl, seq, payload)
    reports["CNN-L"] = compile_model(
        [pegl.bank1, pegl.bank2], stateful_bits_per_flow=STATEFUL["CNN-L"]
    ).report()

    ae = train_autoencoder(seq.reshape(len(y), -1), steps=steps)
    banks = pegasusify_ae(ae, seq.reshape(len(y), -1).astype(np.float32))
    reports["AutoEncoder"] = compile_model(
        banks, stateful_bits_per_flow=STATEFUL["AutoEncoder"]
    ).report()
    return reports


def main(quick: bool = False):
    reports = run(flows_per_class=300 if quick else 600, steps=200 if quick else 400)
    print(f"{'model':<14} {'bits/flow':>6} {'SRAM':>7} {'TCAM':>8} {'Bus':>8}  viol")
    for name, rep in reports.items():
        print(rep.table6_row(name) + f"  {rep.validate() or 'ok'}")
    return reports


if __name__ == "__main__":
    main()
