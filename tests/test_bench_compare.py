"""Unit tests for the CI bench-regression gate (benchmarks/compare.py):
pure-dict comparisons — no benchmark execution, rides the fast lane."""

import json
import pathlib
import sys

import pytest

# repo root on sys.path, so `benchmarks` imports the same way run.py does
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.compare import compare, trajectory_table


def _doc(per_call, batch=1024, families=None):
    return {
        "engine": {
            "batch": batch,
            "backends": {be: {"per_call_ms": ms} for be, ms in per_call.items()},
        },
        "families": families or {},
    }


BASE = {"gather": 10.0, "onehot": 20.0, "kernel": 40.0, "kernel_q8": 40.0}


def test_gate_passes_within_threshold():
    fresh = _doc({**BASE, "kernel": 48.0})          # +20% < 25%
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []


def test_gate_fails_over_threshold():
    fresh = _doc({**BASE, "kernel_q8": 55.0})       # +37.5%
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "kernel_q8" in regressions[0]


def test_gate_fails_on_missing_backend():
    fresh = _doc({k: v for k, v in BASE.items() if k != "kernel"})
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert any("missing" in r for r in regressions)


def test_gate_refuses_batch_mismatch():
    with pytest.raises(SystemExit, match="batch mismatch"):
        compare(_doc(BASE), _doc(BASE, batch=256), 0.25)


def test_improvements_are_not_regressions():
    fresh = _doc({be: ms / 3 for be, ms in BASE.items()})
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    assert any("OK" in l for l in lines)


def test_family_info_lines_not_gated():
    fams = {"rnn": {"backends": {"kernel": {"per_call_ms": 999.0}}}}
    lines, regressions = compare(_doc(BASE), _doc(BASE, families=fams), 0.25)
    assert regressions == []                        # families are info-only
    assert any("rnn/kernel" in l for l in lines)


def test_trajectory_table(tmp_path):
    for i, ms in enumerate((30.0, 20.0, 10.0)):
        p = tmp_path / f"run{i}.json"
        p.write_text(json.dumps(_doc({"kernel": ms})))
    table = trajectory_table(sorted(tmp_path.glob("*.json")))
    assert "kernel ms" in table
    assert "30.00" in table and "10.00" in table
    assert table.count("\n") == 4                   # header + sep + 3 rows


def test_trajectory_table_empty(tmp_path):
    assert "no bench history" in trajectory_table([])
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert "no bench history" in trajectory_table([bad])
