"""Unit tests for the CI bench-regression gate (benchmarks/compare.py):
pure-dict comparisons — no benchmark execution, rides the fast lane."""

import json
import pathlib
import sys

import pytest

# repo root on sys.path, so `benchmarks` imports the same way run.py does
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.compare import compare, trajectory_table


def _doc(per_call, batch=1024, families=None, multi=None, async_serve=None,
         overload=None, sharding=None, chaos=None):
    return {
        "engine": {
            "batch": batch,
            "backends": {be: {"per_call_ms": ms} for be, ms in per_call.items()},
        },
        "families": families or {},
        **({"multi_plan": multi} if multi else {}),
        **({"async_serve": async_serve} if async_serve else {}),
        **({"overload": overload} if overload else {}),
        **({"sharding": sharding} if sharding else {}),
        **({"chaos": chaos} if chaos else {}),
    }


def _async(ratio=1.0, hi=5.0, lo=20.0, flows_s=50000.0):
    return {
        "vs_sync": ratio,
        "async_flows_s": flows_s,
        "sync_flows_s": flows_s / ratio if ratio else flows_s,
        "wfq": {"high": "mlp", "low": "ae", "skew": 4.0,
                "high_p50_wait_ms": hi, "low_p50_wait_ms": lo},
    }


def _multi(served, flows_s=10000.0, batch=256):
    return {
        "batch": batch,
        "models": {name: {"served_ms": ms} for name, ms in served.items()},
        "aggregate": {"flows_s": flows_s},
    }


BASE = {"gather": 10.0, "onehot": 20.0, "kernel": 40.0, "kernel_q8": 40.0}
MBASE = {"mlp": 5.0, "rnn": 20.0, "ae": 8.0}


def test_gate_passes_within_threshold():
    fresh = _doc({**BASE, "kernel": 48.0})          # +20% < 25%
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []


def test_gate_fails_over_threshold():
    fresh = _doc({**BASE, "kernel_q8": 55.0})       # +37.5%
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "kernel_q8" in regressions[0]


def test_backend_only_in_baseline_is_info_not_regression():
    """Satellite fix: a retired backend must not fail the PR that retires
    it — intersection-only gating, removal reported as info."""
    fresh = _doc({k: v for k, v in BASE.items() if k != "kernel"})
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    assert any("removed since baseline: kernel" in l for l in lines)


def test_backend_only_in_fresh_is_info_not_regression():
    """...and symmetrically, a PR ADDING a backend must pass the gate."""
    fresh = _doc({**BASE, "kernel_v2": 12.0})
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    assert any("added since baseline: kernel_v2" in l for l in lines)


def test_audit_error_provenance_is_loud_info_not_gate():
    """A run whose anchor plan carried plan-audit ERROR findings gets a
    loud [info] line (schema field only — never a regression); runs
    predating the field, or with clean audits, stay silent."""
    bad = _doc(BASE)
    bad["audit"] = {"error": 2, "warning": 0, "info": 1}
    lines, regressions = compare(_doc(BASE), bad, 0.25)
    assert regressions == []
    assert any("plan-audit ERROR" in l and "fresh" in l for l in lines)
    clean = _doc(BASE)
    clean["audit"] = {"error": 0, "warning": 1, "info": 0}
    lines, _ = compare(clean, _doc(BASE), 0.25)      # no field at all: silent
    assert not any("plan-audit ERROR" in l for l in lines)


def test_gate_refuses_batch_mismatch():
    with pytest.raises(SystemExit, match="batch mismatch"):
        compare(_doc(BASE), _doc(BASE, batch=256), 0.25)


def test_improvements_are_not_regressions():
    fresh = _doc({be: ms / 3 for be, ms in BASE.items()})
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    assert all("REGRESSION" not in l for l in lines)


def test_large_improvements_marked_ratchet():
    """Satellite: a ≥1.3x win is flagged so the fresh JSON becomes the gated
    baseline on merge; sub-ratchet drift stays a plain OK."""
    fresh = _doc({**BASE, "kernel": 40.0 / 1.5, "kernel_q8": 40.0 / 1.4})
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    ratchet_lines = [l for l in lines if "[ratchet]" in l]
    assert len(ratchet_lines) == 2
    assert any("kernel:" in l and "1.50x faster" in l for l in ratchet_lines)
    assert any("commit the fresh" in l for l in lines)
    # gather/onehot unchanged → OK, not ratchet
    assert any(l.strip().startswith("gather") and "OK" in l for l in lines)
    # 1.2x faster is below the ratchet bar: no flag
    mild = _doc({**BASE, "kernel": 40.0 / 1.2})
    lines, _ = compare(_doc(BASE), mild, 0.25)
    assert not any("[ratchet]" in l for l in lines)


def test_host_speed_reference_reported_not_gated():
    """ref_dense_ms (same-loop dense-matmul timing) is a triage diagnostic
    in the report; it must never gate — normalizing by it was tried and
    rejected (throttling hits MXU-bound and gather-bound work differently)."""
    base, fresh = _doc(BASE), _doc(BASE)
    base["engine"]["ref_dense_ms"] = 2.0
    fresh["engine"]["ref_dense_ms"] = 4.0           # host ran 2x slower
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("host-speed reference" in l and "2.00x" in l for l in lines)
    # absent in one file → no reference line, no crash
    lines, regressions = compare(_doc(BASE), fresh, 0.25)
    assert regressions == []
    assert not any("host-speed reference" in l for l in lines)


def test_family_info_lines_not_gated():
    fams = {"rnn": {"backends": {"kernel": {"per_call_ms": 999.0}}}}
    lines, regressions = compare(_doc(BASE), _doc(BASE, families=fams), 0.25)
    assert regressions == []                        # families are info-only
    assert any("rnn/kernel" in l for l in lines)


def test_multi_plan_per_model_ms_is_info_not_gated():
    """Per-model served_ms of one sub-ms request is too noisy for a 25%
    gate on shared runners — reported as info, never failed."""
    base = _doc(BASE, multi=_multi(MBASE))
    fresh = _doc(BASE, multi=_multi({**MBASE, "rnn": 30.0}))    # +50%: info
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("[info] rnn" in l for l in lines)


def test_multi_plan_gate_covers_aggregate_throughput():
    """The aggregate line is a COLLAPSE gate (2x), not a fine meter: host
    throughput swings ~2x run-to-run on shared runners, while the guarded
    failure modes (retrace storms, serialization) cost 5-10x."""
    base = _doc(BASE, multi=_multi(MBASE, flows_s=10000.0))
    bad = _doc(BASE, multi=_multi(MBASE, flows_s=4000.0))       # 2.5x collapse
    _, regressions = compare(base, bad, 0.25)
    assert len(regressions) == 1 and "aggregate" in regressions[0]
    ok = _doc(BASE, multi=_multi(MBASE, flows_s=7000.0))        # 1.43x: noise
    _, regressions = compare(base, ok, 0.25)
    assert regressions == []


def test_multi_plan_model_add_remove_is_info():
    base = _doc(BASE, multi=_multi(MBASE))
    fewer = _doc(BASE, multi=_multi({k: v for k, v in MBASE.items() if k != "ae"}))
    lines, regressions = compare(base, fewer, 0.25)
    assert regressions == []
    assert any("served model removed" in l for l in lines)
    more = _doc(BASE, multi=_multi({**MBASE, "cnn": 11.0}))
    lines, regressions = compare(base, more, 0.25)
    assert regressions == []
    assert any("served model added" in l for l in lines)


def test_multi_plan_dropped_section_or_zero_flows_is_visible():
    base = _doc(BASE, multi=_multi(MBASE))
    # fresh lost the whole section → loud info, not a silent green
    lines, regressions = compare(base, _doc(BASE), 0.25)
    assert regressions == []
    assert any("missing from fresh run" in l for l in lines)
    # a literal 0 flows/s is a measured total collapse, not "missing"
    dead = _doc(BASE, multi=_multi(MBASE, flows_s=0.0))
    _, regressions = compare(base, dead, 0.25)
    assert len(regressions) == 1 and "collapsed to 0" in regressions[0]


def test_multi_plan_absent_or_batch_mismatch_skips_gate():
    # baseline predates the multi_plan section → info, not a crash/fail
    lines, regressions = compare(_doc(BASE), _doc(BASE, multi=_multi(MBASE)), 0.25)
    assert regressions == []
    assert any("multi_plan added" in l for l in lines)
    # batch change skips the multi gate (engine batch mismatch still refuses)
    base = _doc(BASE, multi=_multi(MBASE, batch=256))
    fresh = _doc(BASE, multi=_multi({**MBASE, "rnn": 99.0}, batch=512))
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("batch changed" in l for l in lines)


def test_async_serve_invariants_pass():
    base = _doc(BASE, async_serve=_async())
    fresh = _doc(BASE, async_serve=_async(ratio=0.95, hi=4.0, lo=18.0))
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("vs_sync" in l and "OK" in l for l in lines)
    assert any("wfq p50 wait" in l and "OK" in l for l in lines)


def test_async_serve_ratio_floor_gated():
    """The async path must not tax throughput: a paired ratio below the
    floor fails the FRESH run regardless of the baseline."""
    fresh = _doc(BASE, async_serve=_async(ratio=0.6))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "ratio 0.60" in regressions[0]


def test_async_serve_wfq_ordering_gated():
    """High-priority p50 queue-wait ≥ low-priority = WFQ broken — a
    host-independent invariant, gated on every run."""
    fresh = _doc(BASE, async_serve=_async(hi=21.0, lo=20.0))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "WFQ ordering broken" in regressions[0]


def test_async_serve_cross_run_collapse_gated():
    base = _doc(BASE, async_serve=_async(flows_s=50000.0))
    dead = _doc(BASE, async_serve=_async(flows_s=20000.0))   # 2.5x collapse
    _, regressions = compare(base, dead, 0.25)
    assert len(regressions) == 1 and "collapse limit" in regressions[0]
    ok = _doc(BASE, async_serve=_async(flows_s=30000.0))     # 1.67x: noise
    _, regressions = compare(base, ok, 0.25)
    assert regressions == []


def test_async_serve_zero_or_missing_flows_is_visible():
    """A measured 0 flows/s is a total collapse (regression); a dropped key
    is a loud info line — never a silent green (same rule as multi_plan)."""
    base = _doc(BASE, async_serve=_async())
    dead = _doc(BASE, async_serve=_async(flows_s=0.0))
    _, regressions = compare(base, dead, 0.25)
    assert any("collapsed to 0" in r for r in regressions)
    dropped = _doc(BASE, async_serve={k: v for k, v in _async().items()
                                      if k != "async_flows_s"})
    lines, regressions = compare(base, dropped, 0.25)
    assert regressions == []
    assert any("flows_s missing" in l and "NOT applied" in l for l in lines)


def test_async_serve_missing_section_is_visible_not_silent():
    base = _doc(BASE, async_serve=_async())
    lines, regressions = compare(base, _doc(BASE), 0.25)
    assert regressions == []
    assert any("async_serve section missing" in l for l in lines)
    # added since baseline: invariants still gate, collapse skipped
    lines, regressions = compare(_doc(BASE), base, 0.25)
    assert regressions == []
    assert any("async_serve added since baseline" in l for l in lines)


def _overload(g1=20000.0, g2=22000.0, hi99=60.0, deadline=100.0):
    return {
        "deadline_ms": deadline,
        "capacity_flows_s": 50000.0,
        "phases": {
            "0.5": {"goodput_flows_s": g1 / 2, "hi_p99_wait_ms": 5.0},
            "1.0": {"goodput_flows_s": g1, "hi_p99_wait_ms": 30.0},
            "2.0": {"goodput_flows_s": g2, "hi_p99_wait_ms": hi99},
        },
    }


def test_overload_invariants_pass():
    base = _doc(BASE, overload=_overload())
    fresh = _doc(BASE, overload=_overload(g1=18000.0, g2=19000.0, hi99=80.0))
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("hi p99 wait @2x" in l and "OK" in l for l in lines)
    assert any("goodput 1x" in l and "OK" in l for l in lines)


def test_overload_unbounded_hi_wait_gated():
    """Fresh-run invariant: hi p99 queue-wait ≥ 2x the deadline under 2x
    overload means shedding stopped bounding waits — host-independent,
    gated on every run (even with no baseline section)."""
    fresh = _doc(BASE, overload=_overload(hi99=250.0, deadline=100.0))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "shedding is not bounding waits" in regressions[0]
    # just inside the bound: passes
    ok = _doc(BASE, overload=_overload(hi99=199.0, deadline=100.0))
    _, regressions = compare(_doc(BASE), ok, 0.25)
    assert regressions == []


def test_overload_goodput_collapse_past_saturation_gated():
    """goodput(2x) < 0.5x goodput(1x) = the overload curve collapsed
    instead of plateauing (the failure mode shedding exists to prevent)."""
    fresh = _doc(BASE, overload=_overload(g1=20000.0, g2=8000.0))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "collapsed past saturation" in regressions[0]


def test_overload_cross_run_collapse_gated():
    base = _doc(BASE, overload=_overload(g1=20000.0))
    dead = _doc(BASE, overload=_overload(g1=8000.0, g2=8500.0))  # 2.5x drop
    _, regressions = compare(base, dead, 0.25)
    assert len(regressions) == 1 and "collapse limit" in regressions[0]
    ok = _doc(BASE, overload=_overload(g1=12000.0, g2=13000.0))  # 1.67x
    _, regressions = compare(base, ok, 0.25)
    assert regressions == []


def test_overload_missing_section_or_phases_is_visible():
    base = _doc(BASE, overload=_overload())
    lines, regressions = compare(base, _doc(BASE), 0.25)
    assert regressions == []
    assert any("overload section missing" in l for l in lines)
    # added since baseline: invariants still gate, collapse skipped
    lines, regressions = compare(_doc(BASE), base, 0.25)
    assert regressions == []
    assert any("overload added since baseline" in l for l in lines)
    # dropped phases: loud info, not a crash or a silent green
    broken = _doc(BASE, overload={"deadline_ms": 100.0, "phases": {}})
    lines, regressions = compare(base, broken, 0.25)
    assert regressions == []
    assert any("invariant gates NOT applied" in l for l in lines)
    assert any("collapse gate NOT applied" in l for l in lines)


def _sharding(eff4=0.95, f1=40000.0, host_par=1):
    f4 = f1 * eff4 * min(4, host_par)
    return {
        "host_parallelism": host_par,
        "devices_available": 8,
        "plan_sharded": {"1": {"per_call_ms": 10.0, "vs_single_x": 1.0},
                         "4": {"per_call_ms": 16.0, "vs_single_x": 1.6}},
        "serve_streams": {
            "1": {"flows_s": f1, "speedup_vs_1": 1.0,
                  "scaling_efficiency": 1.0},
            "4": {"flows_s": f4, "speedup_vs_1": f4 / f1,
                  "scaling_efficiency": eff4},
        },
        "scaling_efficiency_at_4": eff4,
    }


def test_sharding_efficiency_floor_gated():
    """The serving-level stream aggregate must scale (or at least not tax):
    efficiency at 4 devices below the 0.6 floor fails the FRESH run."""
    fresh = _doc(BASE, sharding=_sharding(eff4=0.4))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "taxing, not scaling" in regressions[0]
    ok = _doc(BASE, sharding=_sharding(eff4=0.85))
    lines, regressions = compare(_doc(BASE), ok, 0.25)
    assert regressions == []
    assert any("eff @4dev" in l and "OK" in l for l in lines)


def test_sharding_plan_sharded_is_info_not_gated():
    """shard_map per-call overhead on a 1-core host is expected physics —
    the plan-sharded numbers are reported, never failed."""
    sh = _sharding()
    sh["plan_sharded"]["4"] = {"per_call_ms": 99.0, "vs_single_x": 9.9}
    lines, regressions = compare(_doc(BASE), _doc(BASE, sharding=sh), 0.25)
    assert regressions == []
    assert any("plan-sharded K=4" in l and "not gated" in l for l in lines)


def test_sharding_missing_section_is_info_not_failure():
    """ISSUE 7 satellite: a baseline (or a <4-device host's fresh run)
    without the sharding section must not fail the gate."""
    base = _doc(BASE, sharding=_sharding())
    # fresh dropped the section → loud info, not a regression
    lines, regressions = compare(base, _doc(BASE), 0.25)
    assert regressions == []
    assert any("sharding section missing" in l for l in lines)
    # baseline predates the section → info, efficiency still gated
    lines, regressions = compare(_doc(BASE), base, 0.25)
    assert regressions == []
    assert any("sharding added since baseline" in l for l in lines)
    # neither side has it → silent skip, nothing to report
    lines, regressions = compare(_doc(BASE), _doc(BASE), 0.25)
    assert regressions == []
    assert not any("sharding" in l for l in lines)


def test_sharding_efficiency_unavailable_is_info():
    """<4 XLA devices → scaling_efficiency_at_4 is None: info, not a fail."""
    sh = _sharding()
    sh["scaling_efficiency_at_4"] = None
    del sh["serve_streams"]["4"]
    lines, regressions = compare(_doc(BASE), _doc(BASE, sharding=sh), 0.25)
    assert regressions == []
    assert any("efficiency gate NOT applied" in l for l in lines)


def test_sharding_cross_run_collapse_gated():
    base = _doc(BASE, sharding=_sharding(f1=40000.0))
    dead = _doc(BASE, sharding=_sharding(f1=15000.0))    # 2.67x collapse
    _, regressions = compare(base, dead, 0.25)
    assert len(regressions) == 1 and "collapse limit" in regressions[0]
    ok = _doc(BASE, sharding=_sharding(f1=25000.0))      # 1.6x: host noise
    _, regressions = compare(base, ok, 0.25)
    assert regressions == []


def _chaos(free=50000.0, faulted=40000.0, recovered=True, recovery=0.7):
    return {
        "fault_free_flows_s": free,
        "faulted_flows_s": faulted,
        "goodput_ratio": faulted / free if free else None,
        "recovered": recovered,
        "recovery_s": recovery if recovered else None,
        "window_s": 0.5,
        "fault_at_s": 0.8,
    }


def test_chaos_invariants_pass():
    base = _doc(BASE, chaos=_chaos())
    fresh = _doc(BASE, chaos=_chaos(free=45000.0, faulted=30000.0))
    lines, regressions = compare(base, fresh, 0.25)
    assert regressions == []
    assert any("recovery to ≥90%" in l and "OK" in l for l in lines)
    assert any("goodput fault-free" in l and "OK" in l for l in lines)


def test_chaos_recovery_missed_gated():
    """Fresh-run invariant: never regaining 90% capacity inside the sweep
    window means supervision lost the stream for good — host-independent,
    gated even with no baseline section."""
    fresh = _doc(BASE, chaos=_chaos(recovered=False))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "not recovering capacity" in regressions[0]


def test_chaos_goodput_floor_gated():
    """goodput under faults < 0.5x fault-free = the crash cost the phase,
    not a blip (lost chunks / wedged loop / respawn storm)."""
    fresh = _doc(BASE, chaos=_chaos(free=50000.0, faulted=20000.0))
    _, regressions = compare(_doc(BASE), fresh, 0.25)
    assert len(regressions) == 1
    assert "goodput under injected faults collapsed" in regressions[0]
    # exactly at the floor: passes
    ok = _doc(BASE, chaos=_chaos(free=50000.0, faulted=25000.0))
    _, regressions = compare(_doc(BASE), ok, 0.25)
    assert regressions == []


def test_chaos_cross_run_collapse_gated():
    base = _doc(BASE, chaos=_chaos(free=50000.0))
    dead = _doc(BASE, chaos=_chaos(free=20000.0, faulted=16000.0))  # 2.5x
    _, regressions = compare(base, dead, 0.25)
    assert len(regressions) == 1 and "collapse limit" in regressions[0]
    ok = _doc(BASE, chaos=_chaos(free=30000.0, faulted=24000.0))    # 1.67x
    _, regressions = compare(base, ok, 0.25)
    assert regressions == []


def test_chaos_missing_section_or_fields_is_visible():
    base = _doc(BASE, chaos=_chaos())
    lines, regressions = compare(base, _doc(BASE), 0.25)
    assert regressions == []
    assert any("chaos section missing" in l for l in lines)
    # added since baseline: invariants still gate, collapse skipped
    lines, regressions = compare(_doc(BASE), base, 0.25)
    assert regressions == []
    assert any("chaos added since baseline" in l for l in lines)
    # dropped fields: loud info, not a crash or a silent green
    broken = _doc(BASE, chaos={"window_s": 0.5})
    lines, regressions = compare(base, broken, 0.25)
    assert regressions == []
    assert any("recovery gate NOT applied" in l for l in lines)
    assert any("goodput gate NOT applied" in l for l in lines)
    assert any("collapse gate NOT applied" in l for l in lines)


def test_trajectory_table(tmp_path):
    for i, ms in enumerate((30.0, 20.0, 10.0)):
        p = tmp_path / f"run{i}.json"
        p.write_text(json.dumps(_doc({"kernel": ms})))
    table = trajectory_table(sorted(tmp_path.glob("*.json")))
    assert "kernel ms" in table
    assert "30.00" in table and "10.00" in table
    assert table.count("\n") == 4                   # header + sep + 3 rows


def test_trajectory_table_empty(tmp_path):
    assert "no bench history" in trajectory_table([])
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert "no bench history" in trajectory_table([bad])
