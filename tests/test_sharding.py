"""Multi-device sharded serving (ISSUE 7 tentpole).

Parity contract: a plan built with ``devices=K`` (batch axis sharded over
a K-device mesh via ``shard_map``, bank operands replicated) must produce
EXACTLY the arrays of the single-device plan — all four backends, both
kernel strategies, fused and unfused, exact-bucket and ragged batches.
The host devices come from ``--xla_force_host_platform_device_count=8``
(tests/conftest.py, or the multi-device CI lane's XLA_FLAGS).

Also covered here: per-call device placement (the serving runtime's
PLACED mode), the ``devices`` memo key in ``plan_for``, the
least-loaded-placement invariant of :class:`DeviceStreamPool`, and the
multi-device ``MultiModelServer`` end to end.
"""

import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.amm import init_pegasus_linear
from repro.engine import BACKENDS, build_plan
from repro.engine.plan import resolve_devices
from repro.engine.registry import PlanRegistry
from repro.launch.devices import DeviceStreamPool
from repro.launch.serve import InferRequest, MultiModelServer

pytestmark = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 XLA devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _banks(seed: int = 0, n_out: int = 5) -> list:
    rng = np.random.default_rng(seed)
    return [init_pegasus_linear(
        rng.normal(size=(8, n_out)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)]


@pytest.fixture(scope="module")
def x():
    return jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)),
                       jnp.float32)


# ---------------------------------------------------------------------------
# engine: sharded execution mode
# ---------------------------------------------------------------------------

def test_sharded_parity_all_backends(x):
    """devices=4 must be bitwise-identical to single-device for every
    backend, at an exact bucket (32) AND a ragged batch (17 → padded)."""
    banks = _banks()
    single = build_plan(banks)
    sharded = build_plan(banks, devices=4)
    assert len(sharded.devices) == 4
    for be in BACKENDS:
        for n in (32, 17):
            a = np.asarray(single(x[:n], backend=be))
            b = np.asarray(sharded(x[:n], backend=be))
            assert np.array_equal(a, b), f"sharded parity broke for {be}@{n}"


@pytest.mark.kernel
def test_sharded_parity_both_strategies_and_fusion(x):
    """Both Pallas strategies (lookup gather-sum / mxu one-hot matmul),
    fused and unfused, keep exact parity under sharding."""
    banks = _banks(3)
    for strategy in ("mxu", "lookup"):
        for fuse in (True, False):
            single = build_plan(banks, strategy=strategy, fuse=fuse)
            sharded = build_plan(banks, strategy=strategy, fuse=fuse,
                                 devices=4)
            for be in ("kernel", "kernel_q8"):
                for n in (32, 17):   # exact bucket + ragged
                    a = np.asarray(single(x[:n], backend=be))
                    b = np.asarray(sharded(x[:n], backend=be))
                    assert np.array_equal(a, b), \
                        f"parity broke for {be}/{strategy}/fuse={fuse}@{n}"


def test_sharded_bucket_divisibility_validated():
    with pytest.raises(ValueError, match="not divisible"):
        build_plan(_banks(), devices=3, bucket_sizes=(16, 32))


def test_sharded_plan_refuses_per_call_device(x):
    plan = build_plan(_banks(), devices=2)
    with pytest.raises(ValueError, match="sharded across a device mesh"):
        plan(x, device=jax.devices()[0])


def test_placed_mode_runs_on_target_device(x):
    """Per-call placement (the serving runtime's per-device streams): the
    output is committed to the requested device and exactly equal."""
    plan = build_plan(_banks())
    ref = np.asarray(plan(x[:17]))
    for d in jax.devices()[:3]:
        y = plan(x[:17], device=d)
        assert list(y.devices()) == [d]
        assert np.array_equal(np.asarray(y), ref)


def test_devices_participates_in_plan_memo_key(x):
    reg = PlanRegistry()
    banks = _banks()
    p_default = reg.plan_for(banks)
    assert reg.plan_for(banks, devices=None) is p_default
    p_sharded = reg.plan_for(banks, devices=4)
    assert p_sharded is not p_default
    # int count and explicit device tuple resolve to the same key
    assert reg.plan_for(banks, devices=tuple(jax.devices()[:4])) is p_sharded
    assert resolve_devices(2) == tuple(jax.devices()[:2])
    assert p_sharded.compile_stats()["devices"] == 4
    assert p_default.compile_stats()["devices"] == 1


# ---------------------------------------------------------------------------
# DeviceStreamPool: least-loaded placement invariant
# ---------------------------------------------------------------------------

def test_pool_least_loaded_placement():
    """With every stream blocked, successive submits must land on the
    stream with the fewest PENDING FLOWS (ties → lowest index). Submitting
    weights 5, 3, 1, 1, 2 onto 3 blocked streams must therefore place
    them as dev0:5, dev1:3, dev2:(1+1), then dev2 again (4 < 5) → the
    invariant: after every submit, max(pending) - min(pending) is bounded
    by the largest chunk, and each submit picked an argmin stream."""
    gate = threading.Event()
    placed: list[tuple[int, int]] = []   # (flows, device_index)

    with DeviceStreamPool(jax.devices()[:3]) as pool:
        # park one equal-weight blocker on each stream (1000 flows apiece:
        # ties break to the lowest index, so they land 0, 1, 2) — every
        # later placement decision is then observable via pending_flows
        blockers = [pool.submit(lambda d: gate.wait(10), 1000)
                    for _ in range(3)]
        time.sleep(0.05)                 # workers now hold their blockers

        expected = []                    # argmin computed against a model
        loads = [1000, 1000, 1000]
        for flows in (5, 3, 1, 1, 2):
            pick = loads.index(min(loads))
            expected.append((flows, pick))
            loads[pick] += flows
            pool.submit(lambda d, f=flows: placed.append(
                (f, jax.devices().index(d))), flows)
        st = pool.stats()
        pending = [d["pending_flows"] for d in st["per_device"]]
        assert pending == loads, (pending, loads)
        gate.set()
        for b in blockers:
            b.result(timeout=10)
    # after close() every queued task ran on the stream it was placed on
    assert sorted(placed) == sorted(expected), (placed, expected)


def test_pool_stats_and_error_isolation():
    with DeviceStreamPool(jax.devices()[:2]) as pool:
        ok = pool.submit(lambda d: "fine", 4)
        bad = pool.submit(lambda d: 1 / 0, 4)
        assert ok.result(timeout=10) == "fine"
        with pytest.raises(ZeroDivisionError):
            bad.result(timeout=10)
        st = pool.stats()
        assert st["count"] == 2
        assert sum(d["dispatched_chunks"] for d in st["per_device"]) == 1
        assert sum(d["errors"] for d in st["per_device"]) == 1
        assert sum(d["pending_flows"] for d in st["per_device"]) == 0
    with pytest.raises(RuntimeError, match="closed"):
        pool.submit(lambda d: None, 1)


# ---------------------------------------------------------------------------
# serving: multi-device MultiModelServer
# ---------------------------------------------------------------------------

def test_multi_device_server_parity_and_device_stats(x):
    """The devices= server must serve the exact same outputs as the
    single-stream server, and report per-device dispatch counters."""
    reqs = [InferRequest("m", x[: 1 + (i * 7) % 31]) for i in range(12)]
    single = MultiModelServer({"m": _banks(5)}, backend="gather")
    ref = single.serve(reqs)
    server = MultiModelServer({"m": _banks(5)}, backend="gather", devices=4)
    try:
        out = server.serve(reqs)
        for a, b in zip(out, ref):
            assert np.array_equal(np.asarray(a.output), np.asarray(b.output))
        st = server.stats()["devices"]
        assert st["count"] == 4
        total = sum(d["dispatched_flows"] for d in st["per_device"])
        assert total == sum(r.flows for r in reqs)
        assert all(d["pending_flows"] == 0 for d in st["per_device"])
    finally:
        server.close()


def test_multi_device_server_spreads_chunks(x):
    """Many submit+drain rounds must exercise MORE than one device stream
    (the least-loaded policy spreads chunks once a stream is busy)."""
    server = MultiModelServer(backend="gather", devices=4, max_batch=32)
    server.add_model("m", _banks(6), bucket_sizes=(8, 16, 32))
    try:
        for _ in range(4):
            for i in range(8):
                server.submit(InferRequest("m", x[: 8 + (i % 3) * 8]))
            server.drain()   # 120 flows → four 32-capped chunks per round
        st = server.stats()["devices"]
        used = [d for d in st["per_device"] if d["dispatched_chunks"] > 0]
        assert len(used) >= 2, st["per_device"]
    finally:
        server.close()
