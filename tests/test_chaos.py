"""Chaos-driven error-path suite (ISSUE 9): the fault-injection harness
itself (determinism, scoping, occurrence counting), the circuit-breaker
lifecycle, and the self-healing serving behaviors the harness exists to
exercise — backend fallback-ladder parity, deadline-aware bounded retry,
poison-pill bounding, device-stream crash migration + respawn, dead-worker
detection, zero-healthy inline degrade, and the stop(drain=False)
regression. Everything here runs tiny gather/onehot plans or stub device
pools — fast-lane material, runnable under PEGASUS_SANITIZE=1 (the
dedicated `chaos` CI lane does exactly that).
"""

import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.amm import init_pegasus_linear
from repro.launch.chaos import FaultInjector, InjectedFaultError
from repro.launch.devices import DeviceStreamPool
from repro.launch.health import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.launch.scheduler import DeadlineExceededError
from repro.launch.serve import (
    AsyncMultiModelServer, InferRequest, PoisonedRequestError,
    ServerStoppedError,
)


def _banks(seed: int = 0, n_out: int = 5) -> list:
    rng = np.random.default_rng(seed)
    return [init_pegasus_linear(
        rng.normal(size=(8, n_out)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)]


@pytest.fixture(scope="module")
def x():
    return jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)),
                       jnp.float32)


def _serve_one(srv, name, x, timeout=30):
    return srv.submit(InferRequest(name, x)).result(timeout).output


# ---------------------------------------------------------------------------
# CircuitBreaker: the full lifecycle, driven by a fake clock (no sleeps)
# ---------------------------------------------------------------------------


def test_breaker_lifecycle_closed_open_half_open():
    t = [0.0]
    br = CircuitBreaker("m", failure_threshold=2, reset_timeout_s=1.0,
                        clock=lambda: t[0])
    assert br.state == CLOSED and br.allow()
    assert br.record_failure() == CLOSED         # streak 1 of 2
    assert br.record_failure() == OPEN           # tripped
    assert not br.allow()                        # cooldown running
    t[0] = 0.5
    assert not br.allow()
    t[0] = 1.1
    assert br.allow()                            # cooldown elapsed: probe
    assert br.state == HALF_OPEN
    assert not br.allow()                        # half_open_probes=1
    assert br.record_failure() == OPEN           # failed probe re-opens
    t[0] = 1.5
    assert not br.allow()                        # cooldown RESTARTED at 1.1
    t[0] = 2.2
    assert br.allow()
    assert br.record_success() == CLOSED         # probe success reinstates
    st = br.stats()
    assert st["opened"] == 1 and st["reopened"] == 1
    assert st["half_opens"] == 2 and st["reinstated"] == 1
    # one success resets the consecutive streak
    br.record_failure()
    assert br.record_success() == CLOSED
    assert br.record_failure() == CLOSED         # streak restarted at 1


def test_breaker_validates_config():
    with pytest.raises(ValueError):
        CircuitBreaker(failure_threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(reset_timeout_s=-1.0)
    with pytest.raises(ValueError):
        CircuitBreaker(half_open_probes=0)


# ---------------------------------------------------------------------------
# FaultInjector: scoping, occurrence counting, determinism
# ---------------------------------------------------------------------------


def test_injector_scoping_after_count():
    inj = FaultInjector()
    spec = inj.inject("plan_call", model="a", after=2, count=1)
    inj.fire("plan_call", model="b")             # scope mismatch: no match
    inj.fire("plan_call", model="a")             # occurrence 1: passes
    with pytest.raises(InjectedFaultError) as ei:
        inj.fire("plan_call", model="a")         # occurrence 2: fires
    assert ei.value.site == "plan_call"
    assert ei.value.scope["model"] == "a"
    inj.fire("plan_call", model="a")             # count=1 exhausted
    assert spec.matched == 3 and spec.fired == 1
    sched = inj.schedule()
    assert len(sched) == 1
    assert sched[0]["site"] == "plan_call" and sched[0]["occurrence"] == 2


def test_injector_persistent_disarm_and_custom_error():
    inj = FaultInjector()
    boom = RuntimeError("boom")
    inj.inject("stream_dispatch", stream=0, count=None, error=boom)
    for _ in range(3):
        with pytest.raises(RuntimeError) as ei:
            inj.fire("stream_dispatch", stream=0)
        assert ei.value is boom                  # persistent + custom payload
    inj.armed = False
    inj.fire("stream_dispatch", stream=0)        # disarmed: no-op
    inj.armed = True
    inj.clear()
    inj.fire("stream_dispatch", stream=0)        # cleared: no specs
    assert inj.stats()["fired"] == 3             # history survives clear()


def test_injector_slow_mode_stalls_then_proceeds():
    inj = FaultInjector()
    inj.inject("plan_build", mode="slow", delay_ms=30, count=1)
    t0 = time.perf_counter()
    inj.fire("plan_build", model="m", backend="onehot")   # stalls, no raise
    assert time.perf_counter() - t0 >= 0.025


def _probability_schedule(seed: int) -> list:
    inj = FaultInjector(seed=seed)
    inj.inject("plan_call", probability=0.5, count=None)
    for i in range(64):
        try:
            inj.fire("plan_call", model=f"m{i % 3}")
        except InjectedFaultError:
            pass
    return inj.schedule()


def test_injector_determinism_same_seed_same_schedule():
    a, b = _probability_schedule(42), _probability_schedule(42)
    assert a == b
    assert 0 < len(a) < 64                       # probabilistic, not all/none
    assert _probability_schedule(7) != a         # seed actually matters


# ---------------------------------------------------------------------------
# Self-healing serving: fallback ladder, bounded retry, poison pills
# ---------------------------------------------------------------------------


def test_fallback_ladder_parity_and_probe_back(x):
    """A persistent preferred-backend fault trips the breaker; the model
    keeps serving DEGRADED on gather with output parity, other models are
    untouched, and clearing the fault probe-backs to the preferred path."""
    srv = AsyncMultiModelServer(
        {"good": _banks(0), "flaky": _banks(1)}, backend="onehot",
        breaker_failures=3, breaker_reset_s=0.15, max_requeues=10,
        retry_backoff_s=0.005, idle_wait=0.01)
    with srv:
        healthy = _serve_one(srv, "flaky", x)    # preferred path, pre-fault
        inj = FaultInjector(seed=1)
        inj.inject("plan_call", model="flaky", backend="onehot", count=None)
        srv.install_chaos(inj)
        degraded = _serve_one(srv, "flaky", x)   # heals onto gather
        np.testing.assert_allclose(degraded, healthy, rtol=1e-4, atol=1e-4)
        good = _serve_one(srv, "good", x)        # other model unaffected
        assert good.shape == healthy.shape
        h = srv.stats()["health"]
        m = h["models"]["flaky"]
        assert m["degraded"] and m["state"] == OPEN
        assert m["fallback_batches"] >= 1
        assert m["preferred_backend"] == "onehot"
        assert m["fallback_backend"] == "gather"
        assert h["degraded_models"] == ["flaky"]
        assert h["models"]["good"]["state"] == CLOSED
        assert not h["models"]["good"]["degraded"]
        assert h["chaos"]["installed"] and h["chaos"]["fired"] >= 3
        # fault cleared: the next granted probe reinstates the preferred path
        inj.clear()
        time.sleep(0.2)                          # cooldown elapses
        deadline = time.monotonic() + 10
        while (srv.stats()["health"]["models"]["flaky"]["state"] != CLOSED
                and time.monotonic() < deadline):
            _serve_one(srv, "flaky", x)
            time.sleep(0.02)
        m = srv.stats()["health"]["models"]["flaky"]
        assert m["state"] == CLOSED and m["reinstated"] >= 1
        assert m["probe_batches"] >= 1
        assert srv.stats()["health"]["degraded_models"] == []


def test_retry_never_past_request_deadline(x):
    """Bounded retry must stop at the request's own deadline_ms — the
    future fails with the dispatch (or shed) error well before the retry
    budget could run out, and nothing stays queued."""
    srv = AsyncMultiModelServer(
        {"m": _banks()}, backend="gather", breaker_reset_s=60.0,
        max_requeues=50, retry_backoff_s=0.005, idle_wait=0.01)
    with srv:
        inj = FaultInjector()
        inj.inject("plan_call", model="m", count=None)
        srv.install_chaos(inj)
        fut = srv.submit(InferRequest("m", x, deadline_ms=80.0))
        t0 = time.perf_counter()
        with pytest.raises((InjectedFaultError, DeadlineExceededError)):
            fut.result(timeout=10)
        # 50 retries at capped-1s backoff would take ~45s; the deadline
        # bounded it instead
        assert time.perf_counter() - t0 < 5.0
        assert srv.pending().get("m", 0) == 0


def test_poison_pill_fails_typed_after_bounded_requeues(x):
    srv = AsyncMultiModelServer(
        {"m": _banks(), "ok": _banks(3)}, backend="gather",
        breaker_failures=2, breaker_reset_s=60.0, max_requeues=3,
        retry_backoff_s=0.002, idle_wait=0.01)
    with srv:
        inj = FaultInjector()
        inj.inject("plan_call", model="m", count=None)   # every backend
        srv.install_chaos(inj)
        fut = srv.submit(InferRequest("m", x))
        with pytest.raises(PoisonedRequestError) as ei:
            fut.result(timeout=30)
        assert isinstance(ei.value.__cause__, InjectedFaultError)
        assert srv.pending().get("m", 0) == 0    # nothing left to loop on
        assert srv.running                       # the loop survived it
        out = _serve_one(srv, "ok", x)           # and still serves others
        assert out.shape[0] == x.shape[0]
        m = srv.stats()["health"]["models"]["m"]
        assert m["poisoned"] >= 1 and m["retries"] >= 3


def test_stop_without_drain_fails_pending_futures(x):
    """Satellite regression: stop(drain=False) must fail still-pending
    futures with typed ServerStoppedError so a blocked waiter unblocks
    (they used to stay unresolved forever)."""
    srv = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    fut = srv.submit(InferRequest("m", x))       # never start()ed: stays queued
    seen: list = []
    waiter = threading.Thread(
        target=lambda: seen.append(fut.exception(timeout=10)), daemon=True)
    waiter.start()
    srv.stop(drain=False)
    waiter.join(timeout=5)
    assert not waiter.is_alive()                 # the waiter unblocked
    assert isinstance(seen[0], ServerStoppedError)
    assert srv.pending().get("m", 0) == 0


# ---------------------------------------------------------------------------
# DeviceStreamPool supervision (stub devices: the pool is engine-agnostic)
# ---------------------------------------------------------------------------


def test_worker_crash_migrates_chunks_and_respawns():
    inj = FaultInjector()
    inj.inject("stream_dispatch", stream=1, after=1, count=1)
    pool = DeviceStreamPool(["d0", "d1"], chaos=inj, respawn_backoff_s=0.01)
    try:
        gate = threading.Event()
        blocked = pool.submit(lambda d: (gate.wait(10), "blocked")[1], 1000)
        # stream 0 is busy with 1000 pending flows: these place on stream 1,
        # whose worker dies on its first dispatch — the in-hand chunk and
        # any queued ones migrate to stream 0 and still resolve
        futs = [pool.submit(lambda d, i=i: ("ok", i), 1) for i in range(3)]
        gate.set()
        assert blocked.result(timeout=5) == "blocked"
        assert [f.result(timeout=5)[0] for f in futs] == ["ok"] * 3
        st = pool.stats()
        assert st["migrated_chunks"] >= 1
        assert st["per_device"][1]["crashes"] == 1
        # the respawn backoff brings the worker back
        deadline = time.monotonic() + 5
        while (pool.stats()["per_device"][1]["dead"]
                and time.monotonic() < deadline):
            time.sleep(0.01)
        st = pool.stats()
        assert not st["per_device"][1]["dead"]
        assert st["per_device"][1]["respawns"] >= 1
        assert st["dead_streams"] == 0
    finally:
        pool.close()


def test_dead_stream_detected_and_routed_around():
    inj = FaultInjector()
    inj.inject("stream_dispatch", stream=0, after=1, count=1)
    pool = DeviceStreamPool(["d0", "d1"], chaos=inj, respawn_backoff_s=30.0)
    try:
        # stream 0's worker dies in-hand; the chunk migrates and RUNS on d1
        migrated = pool.submit(lambda d: ("ran-on", d), 1)
        assert migrated.result(timeout=5) == ("ran-on", "d1")
        st = pool.stats()                        # satellite: surfaced here
        assert st["dead_streams"] == 1
        assert st["per_device"][0]["dead"] and st["per_device"][0]["crashes"] == 1
        assert not st["per_device"][1]["dead"]
        routed = pool.submit(lambda d: d, 1)     # placement routes around it
        assert routed.result(timeout=5) == "d1"
        assert pool.stats()["healthy_streams"] == 1
    finally:
        pool.close()


def test_silently_dead_worker_detected_at_stats_time():
    """Satellite: a worker that vanished WITHOUT supervision seeing the
    death (simulated by swapping in an already-finished thread) is still
    detected lazily — at stats() time and in placement — and its stream is
    reaped rather than stranding its FIFO."""
    pool = DeviceStreamPool(["d0", "d1"], respawn_backoff_s=30.0)
    try:
        t = threading.Thread(target=lambda: None)
        t.start()
        t.join()
        with pool._lock:
            pool._streams[0].thread = t          # looks dead, never marked
        st = pool.stats()
        assert st["dead_streams"] == 1 and st["per_device"][0]["dead"]
        fut = pool.submit(lambda d: d, 1)
        assert fut.result(timeout=5) == "d1"
    finally:
        pool.close()


def test_zero_healthy_streams_degrade_to_inline_dispatch():
    inj = FaultInjector()
    inj.inject("stream_dispatch", count=None)    # any stream, persistent
    pool = DeviceStreamPool(["d0"], chaos=inj, respawn_backoff_s=5.0)
    try:
        doomed = pool.submit(lambda d: "never", 1)
        # single stream, nowhere to migrate: the chunk fails typed
        with pytest.raises(InjectedFaultError):
            doomed.result(timeout=5)
        # the pool keeps serving INLINE on the caller thread — degraded,
        # not deadlocked (the inline path carries no dispatch hook)
        fut = pool.submit(lambda d: ("inline", d), 1)
        assert fut.result(timeout=1) == ("inline", "d0")
        st = pool.stats()
        assert st["dead_streams"] == 1 and st["healthy_streams"] == 0
        assert st["inline_dispatches"] >= 1
        assert st["per_device"][0]["dead"]
    finally:
        pool.close()


def test_breaker_open_stream_quarantined_then_reinstated():
    """Per-dispatch failures (caught, future-carried) trip the stream's
    breaker without killing the worker; placement routes around the OPEN
    stream, then a cooldown probe chunk reinstates it."""
    pool = DeviceStreamPool(["d0", "d1"], breaker_failures=2,
                            breaker_reset_s=0.1)
    try:
        def bad(d):
            raise ValueError("organic dispatch failure")

        gate = threading.Event()
        blocked = pool.submit(lambda d: (gate.wait(10), "b")[1], 1000)
        for _ in range(2):                       # two failures on stream 1
            f = pool.submit(bad, 1)
            with pytest.raises(ValueError):
                f.result(timeout=5)
        st = pool.stats()
        assert st["per_device"][1]["state"] == OPEN
        assert not st["per_device"][1]["dead"]   # quarantined, not dead
        assert st["per_device"][1]["errors"] == 2
        gate.set()
        assert blocked.result(timeout=5) == "b"
        time.sleep(0.15)                         # cooldown elapses
        # the next placement grants stream 1 a probe chunk; success closes
        deadline = time.monotonic() + 5
        while (pool.stats()["per_device"][1]["state"] != CLOSED
                and time.monotonic() < deadline):
            pool.submit(lambda d: d, 1).result(timeout=5)
        assert pool.stats()["per_device"][1]["state"] == CLOSED
        assert pool.stats()["healthy_streams"] == 2
    finally:
        pool.close()
