"""Tests for repro.analysis: the PG001-PG004 lint (against seeded fixture
files), the suppression grammar, the runtime lock-order/affinity
sanitizer, and a clean-tree pin over src/.

Fixture files under tests/fixtures/analysis/ mark every expected finding
with a ``# VIOLATION PGxxx`` comment ON the offending line; the tests
derive the expected (line, rule) pairs by scanning for those markers, so
fixture edits cannot silently drift from the assertions.
"""

from __future__ import annotations

import re
import threading
import time
from pathlib import Path

import pytest

from repro.analysis import (InstrumentedLock, LockOrderError, ThreadAffinity,
                            ThreadAffinityError, enabled, lint_file,
                            lint_paths, lint_source, main, make_lock,
                            reset_lock_graph)
from repro.analysis.sanitizer import _held
from repro.launch.devices import DeviceStreamPool

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"
SRC = Path(__file__).resolve().parents[1] / "src"

_MARKER = re.compile(r"#\s*VIOLATION\s+(PG\d{3})")


def _expected(path: Path) -> list[tuple[int, str]]:
    """(line, rule) for every `# VIOLATION PGxxx` marker in a fixture."""
    out = []
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        m = _MARKER.search(line)
        if m:
            out.append((i, m.group(1)))
    return sorted(out)


def _found(findings) -> list[tuple[int, str]]:
    return sorted((f.line, f.rule) for f in findings)


# ---------------------------------------------------------------------------
# lint rules against seeded fixtures (exact rule IDs AND line numbers)
# ---------------------------------------------------------------------------


def test_pg001_jax_plan_and_blocking_calls_under_lock():
    path = FIXTURES / "viol_pg001.py"
    findings = lint_file(path)
    assert _found(findings) == _expected(path)
    assert {f.rule for f in findings} == {"PG001"}
    # the str-literal .join() exemption: the clean method contributes none
    assert all("clean_paths" not in f.message for f in findings)


def test_pg001_queue_and_event_blocking_under_lock():
    """The receiver-sensitive half of the blocking table: Queue.get/put and
    Event.wait under a lock are findings; dict.get(key), a plural container
    of queues, and Condition.wait stay exempt."""
    path = FIXTURES / "viol_pg001_blocking.py"
    findings = lint_file(path)
    assert _found(findings) == _expected(path)
    assert {f.rule for f in findings} == {"PG001"}
    assert any("queue/event wait" in f.message for f in findings)


def test_pg002_guarded_by_annotations():
    path = FIXTURES / "viol_pg002.py"
    findings = lint_file(path)
    assert _found(findings) == _expected(path)
    assert {f.rule for f in findings} == {"PG002"}
    # both the read and the write name the attribute and the required lock
    for f in findings:
        assert "_lock" in f.message


def test_pg003_hierarchy_inversion():
    path = FIXTURES / "viol_pg003.py"
    ranks = {"_registry_lock": 0, "_sched_lock": 1}
    findings = lint_file(path, lock_ranks=ranks)
    assert _found(findings) == _expected(path)
    assert findings[0].rule == "PG003"
    assert "rank 0" in findings[0].message and "rank 1" in findings[0].message


def test_pg004_purity_and_donation():
    path = FIXTURES / "viol_pg004.py"
    findings = lint_file(path)
    assert _found(findings) == _expected(path)
    assert {f.rule for f in findings} == {"PG004"}
    messages = "\n".join(f.message for f in findings)
    # all three discovery paths fired: name convention, pallas kernel
    # through functools.partial, jax.jit first argument
    assert "`forward`" in messages
    assert "`_kernel`" in messages
    assert "`_step`" in messages
    # donation: the unsafe read-after-donate is flagged, the same-line
    # rebind in Runner.safe is not (exact-match above already pins this)
    assert "donated buffer `buf`" in messages


def test_suppressions_justified_silent_bare_is_pg000():
    path = FIXTURES / "suppressed.py"
    findings = lint_file(path)
    # every justified suppression silences its finding; the reason-less one
    # still suppresses but surfaces as PG000 on its own line
    assert [f.rule for f in findings] == ["PG000"]
    src_lines = path.read_text().splitlines()
    bare = next(i for i, ln in enumerate(src_lines, start=1)
                if ln.rstrip().endswith("disable=PG001"))
    assert findings[0].line == bare
    assert "justification" in findings[0].message


def test_pg000_unattached_guarded_by_comment():
    findings = lint_source("# guarded-by: _lock\nx = 1\n")
    assert [f.rule for f in findings] == ["PG000"]
    assert "not attached" in findings[0].message


def test_finding_str_is_path_line_rule():
    f = lint_file(FIXTURES / "viol_pg001.py")[0]
    assert str(f).startswith(f"{f.path}:{f.line}: PG001 ")


def test_src_tree_is_clean():
    """The repo's own serving/engine code must lint clean — this is the
    same gate the static-analysis CI lane enforces."""
    assert lint_paths([SRC]) == []


def test_cli_exit_codes(capsys):
    assert main([str(FIXTURES / "viol_pg001.py")]) == 1
    out = capsys.readouterr().out
    assert "PG001" in out and "unsuppressed finding" in out
    assert main([str(SRC / "repro" / "analysis" / "rules.py")]) == 0
    assert main(["--list-rules"]) == 0
    assert "PG004" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("PEGASUS_SANITIZE", "1")
    reset_lock_graph()
    yield
    reset_lock_graph()


def test_lock_order_cycle_detected(sanitized):
    """The canonical deadlock: one code path takes A then B, another takes
    B then A. The graph makes the SECOND ordering raise deterministically,
    even single-threaded, without needing the schedules to interleave."""
    a, b = InstrumentedLock("A"), InstrumentedLock("B")
    with a:
        with b:
            pass                                # records A -> B
    with b:
        with pytest.raises(LockOrderError, match="cycle"):
            a.acquire()                         # B -> A closes the cycle


def test_hierarchy_inversion_raises(sanitized):
    outer = InstrumentedLock("registry._lock")      # rank 0
    inner = InstrumentedLock("serve._ctr_lock")     # rank 2
    with outer:
        with inner:                             # declared order: legal
            pass
    reset_lock_graph()                          # isolate the rank check
    with inner:
        with pytest.raises(LockOrderError, match="inversion"):
            outer.acquire()


def test_nonreentrant_reacquire_raises(sanitized):
    lk = InstrumentedLock("solo._lock")
    with lk:
        with pytest.raises(LockOrderError, match="re-acquired"):
            lk.acquire()
    rl = InstrumentedLock("ree._lock", reentrant=True)
    with rl:
        with rl:                                # declared reentrant: fine
            pass
    assert _held() == []


def test_condition_wait_keeps_held_stack_balanced(sanitized):
    lock = InstrumentedLock("cond._lock")
    cond = threading.Condition(lock)
    flag = []

    def notifier():
        time.sleep(0.05)
        with cond:
            flag.append(1)
            cond.notify_all()

    t = threading.Thread(target=notifier)
    t.start()
    with cond:
        while not flag:
            cond.wait(timeout=5.0)
    t.join(timeout=5.0)
    assert _held() == []                        # balanced across the wait


def test_reset_lock_graph_isolates(sanitized):
    a, b = InstrumentedLock("A"), InstrumentedLock("B")
    with a:
        with b:
            pass
    reset_lock_graph()
    with b:
        with a:                                 # no stale A -> B edge left
            pass


def test_make_lock_plain_when_disabled(monkeypatch):
    monkeypatch.delenv("PEGASUS_SANITIZE", raising=False)
    assert not enabled()
    assert not isinstance(make_lock("x._lock"), InstrumentedLock)
    assert isinstance(make_lock("x._lock", reentrant=True),
                      type(threading.RLock()))


def test_make_lock_instrumented_when_enabled(sanitized):
    assert enabled()
    assert isinstance(make_lock("x._lock"), InstrumentedLock)


def test_thread_affinity(sanitized):
    aff = ThreadAffinity("dispatch")
    aff.assert_here()                           # unbound: never fires
    aff.bind()
    aff.assert_here()                           # owning thread: fine
    errs = []

    def off_thread():
        try:
            aff.assert_here()
        except ThreadAffinityError as e:
            errs.append(e)

    t = threading.Thread(target=off_thread)
    t.start()
    t.join(timeout=5.0)
    assert len(errs) == 1 and "dispatch" in str(errs[0])
    aff.release()
    aff.assert_here()                           # released: free again


def test_pool_assert_worker(sanitized):
    """DeviceStreamPool binds one affinity per worker: assert_worker
    passes on a worker thread and raises anywhere else."""
    with DeviceStreamPool(["devA", "devB"]) as pool:
        deadline = time.monotonic() + 5.0
        while (any(a.bound_ident is None for a in pool._affinities.values())
               and time.monotonic() < deadline):
            time.sleep(0.01)
        with pytest.raises(ThreadAffinityError, match="not a"):
            pool.assert_worker()                # main thread: not a worker
        fut = pool.submit(lambda d: (pool.assert_worker(), d)[1], flows=1)
        assert fut.result(timeout=5.0) == "devA"    # tie -> lowest index


def test_pool_assert_worker_noop_when_disabled(monkeypatch):
    monkeypatch.delenv("PEGASUS_SANITIZE", raising=False)
    with DeviceStreamPool(["d0"]) as pool:
        pool.assert_worker()                    # affinities never bind
