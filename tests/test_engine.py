"""Execution-engine tests: backend parity across every net family, plan
caching (no layout prep / quantization on the call path), and the q8 memo.

Parity contract (ISSUE acceptance): through one ExecutionPlan,
``gather == onehot == kernel`` bitwise-closely for MLP, RNN, CNN, CNN-L and
AutoEncoder pegasus variants, and ``kernel_q8`` matches within int8
quantization tolerance — exactly per-bank, and by prediction agreement at
the net level (index flips near thresholds compound across stacked banks,
so elementwise net-level bounds would be vacuous).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.synthetic_traffic import make_dataset
from repro.engine import (
    BACKENDS, STATS, CompiledBank, FusedBankStack, build_plan, fuse_banks,
    plan_for,
)
from repro.kernels.fuzzy_lut import ops

pytestmark = pytest.mark.kernel   # every case exercises the Pallas backends

FLOWS = 48
STEPS = 5          # parity needs a trained-enough model, not an accurate one
BATCH = 16


@pytest.fixture(scope="module")
def ds():
    return make_dataset("peerrush", flows_per_class=FLOWS)


def _mlp(ds):
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=STEPS)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                           depth=3, refine_steps=0)
    return banks, (jnp.asarray(ds.test["stats"][:BATCH], jnp.float32),)


def _rnn(ds):
    from repro.nets.rnn import pegasusify_rnn, train_rnn

    m = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes, steps=STEPS)
    return pegasusify_rnn(m, ds.train["seq"], depth=4), (
        jnp.asarray(ds.test["seq"][:BATCH]),)


def _cnn(ds):
    from repro.nets.cnn import pegasusify_cnn, train_cnn

    m = train_cnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                  size="B", steps=STEPS)
    return pegasusify_cnn(m, ds.train["seq"], depth=5), (
        jnp.asarray(ds.test["seq"][:BATCH]),)


def _cnn_l(ds):
    from repro.nets.cnn import pegasusify_cnn_l, train_cnn_l

    m = train_cnn_l(ds.train["seq"], ds.train["bytes"], ds.train["label"],
                    ds.num_classes, steps=STEPS)
    peg = pegasusify_cnn_l(m, ds.train["seq"], ds.train["bytes"],
                           enc_depth=4, index_bits=3)
    return peg, (jnp.asarray(ds.test["seq"][:BATCH]),
                 jnp.asarray(ds.test["bytes"][:BATCH]))


def _ae(ds):
    from repro.nets.autoencoder import anomaly_features, pegasusify_ae, train_autoencoder

    x = ds.train["seq"].reshape(len(ds.train["label"]), -1)
    m = train_autoencoder(x, steps=STEPS)
    banks = pegasusify_ae(m, x.astype(np.float32), depth=4)
    xt = ds.test["seq"][:BATCH].reshape(BATCH, -1)
    # the AE bank stack consumes the engineered feature view
    return banks, (anomaly_features(jnp.asarray(xt, jnp.float32)),)


FAMILIES = {"mlp": _mlp, "rnn": _rnn, "cnn": _cnn, "cnn_l": _cnn_l, "ae": _ae}

# mlp + ae are cheap enough for the fast CI lane; the windowed/unrolled
# families train + compile for tens of seconds and ride the full lane.
FAMILY_PARAMS = [
    pytest.param("mlp"),
    pytest.param("ae"),
    pytest.param("rnn", marks=pytest.mark.slow),
    pytest.param("cnn", marks=pytest.mark.slow),
    pytest.param("cnn_l", marks=pytest.mark.slow),
]

_COMPILED: dict[str, tuple] = {}


def _family(ds, family):
    """Lazy per-family (model, plan, inputs) — built once, on first use."""
    if family not in _COMPILED:
        model, inputs = FAMILIES[family](ds)
        _COMPILED[family] = (model, build_plan(model), inputs)
    return _COMPILED[family]


def _compiled(ds, family):
    _, plan, inputs = _family(ds, family)
    return plan, inputs


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_backend_parity(ds, family):
    plan, inputs = _compiled(ds, family)
    ref = np.asarray(plan(*inputs, backend="gather"))
    assert np.isfinite(ref).all()

    # exact backends: identical up to fp32 accumulation order
    for be in ("onehot", "kernel"):
        out = np.asarray(plan(*inputs, backend=be))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{family}:{be}")

    # q8: quantization tolerance per bank, on the REAL activations each bank
    # sees (random inputs land in degenerate leaves and say nothing)
    for i, xb in enumerate(plan.bank_inputs(*inputs)):
        bank = plan.banks[i]
        yg = np.asarray(bank.apply(xb, "gather"))
        yq = np.asarray(bank.apply(xb, "kernel_q8"))
        denom = max(float(np.linalg.norm(yg)), 1e-6)
        rel = float(np.linalg.norm(yq - yg)) / denom
        assert rel < 0.12, (family, i, rel)
    # … and agreeing predictions end-to-end (flips compound across banks)
    outq = np.asarray(plan(*inputs, backend="kernel_q8"))
    assert np.isfinite(outq).all()
    if family != "ae":
        agree = float((outq.argmax(-1) == ref.argmax(-1)).mean())
        assert agree >= 0.75, (family, agree)
    else:
        rel = float(np.linalg.norm(outq - ref) / np.linalg.norm(ref))
        assert rel < 0.25, (family, rel)


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_plan_call_does_no_layout_work(ds, family):
    """Acceptance: after one warm call, further calls perform ZERO layout
    prep and ZERO quantization on any backend."""
    plan, inputs = _compiled(ds, family)
    for be in BACKENDS:
        plan(*inputs, backend=be)            # warm (layouts were plan-time anyway)
    before_layout = STATS.layout_builds
    before_quant = ops.QUANT_STATS["quantize_calls"]
    for be in BACKENDS:
        plan(*inputs, backend=be)
    assert STATS.layout_builds == before_layout
    assert ops.QUANT_STATS["quantize_calls"] == before_quant


def test_bank_layout_built_once():
    """CompiledBank does its layout work in __init__, not in apply()."""
    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(0)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    before = STATS.layout_builds
    bank = CompiledBank(layer)
    assert STATS.layout_builds == before + 1
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    ref = np.asarray(bank.apply(x, "gather"))
    for be in ("onehot", "kernel"):
        np.testing.assert_allclose(np.asarray(bank.apply(x, be)), ref,
                                   rtol=1e-4, atol=1e-5)
    assert STATS.layout_builds == before + 1   # apply() never re-preps


def test_pegasus_linear_compile_method():
    """core/amm hook: PegasusLinear.compile() yields a single-bank plan."""
    from repro.core.amm import apply_gather, init_pegasus_linear

    rng = np.random.default_rng(3)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    plan = layer.compile()
    assert plan.num_banks == 1
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    ref = np.asarray(apply_gather(layer, x))
    for be in BACKENDS[:3]:
        np.testing.assert_allclose(np.asarray(plan(x, backend=be)), ref,
                                   rtol=1e-4, atol=1e-5)


def test_q8_memo_quantizes_once():
    """Satellite fix: fuzzy_lut_matmul_q8 must not re-quantize per call."""
    from repro.core.amm import init_pegasus_linear
    from repro.kernels.fuzzy_lut.ops import fuzzy_lut_matmul_q8

    rng = np.random.default_rng(1)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    fuzzy_lut_matmul_q8(layer, x)
    calls = ops.QUANT_STATS["quantize_calls"]
    hits = ops.QUANT_STATS["cache_hits"]
    fuzzy_lut_matmul_q8(layer, x)
    fuzzy_lut_matmul_q8(layer, x)
    assert ops.QUANT_STATS["quantize_calls"] == calls        # no re-quant
    assert ops.QUANT_STATS["cache_hits"] >= hits + 2


def test_q8_memo_evicts_dead_layers():
    import gc

    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(2)
    layer = init_pegasus_linear(
        rng.normal(size=(4, 3)).astype(np.float32), None,
        rng.normal(size=(32, 4)).astype(np.float32), group_size=2, depth=2,
        lut_bits=None)
    ops.quantized_lut_cached(layer)
    key = id(layer)
    assert key in ops._Q8_MEMO
    del layer
    gc.collect()
    assert key not in ops._Q8_MEMO


def test_plan_for_memoizes(ds):
    banks, _, inputs = _family(ds, "mlp")
    hits = STATS.plan_cache_hits
    p1 = plan_for(banks)
    p2 = plan_for(banks)
    assert p1 is p2
    assert STATS.plan_cache_hits == hits + 1
    np.testing.assert_allclose(
        np.asarray(p1(*inputs, backend="onehot")),
        np.asarray(p1(*inputs, backend="gather")), rtol=1e-4, atol=1e-4)


def test_plan_for_detects_inplace_mutation(ds):
    """Reassigning a bank on the model must invalidate the memo — otherwise
    the engine would keep serving logits from the pre-mutation tables."""
    import dataclasses as dc

    banks, _, inputs = _family(ds, "mlp")
    model = list(banks)
    p1 = plan_for(model)
    y1 = np.asarray(p1(*inputs, backend="gather"))
    assert plan_for(model) is p1                    # unchanged → memo hit
    # simulate refine(): replace a bank with a copy (new object, same arrays)
    model[-1] = dc.replace(model[-1])
    p2 = plan_for(model)
    assert p2 is not p1                             # mutation → rebuilt
    np.testing.assert_allclose(
        np.asarray(p2(*inputs, backend="gather")), y1, rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_plan_for_detects_wrapper_mutation(ds):
    """Same for attribute reassignment on a wrapper model (id-stable key):
    the memo must notice the compiled banks no longer match the model's."""
    import dataclasses as dc

    model, _, inputs = _family(ds, "cnn")
    p1 = plan_for(model)
    assert plan_for(model) is p1
    model.window_bank = dc.replace(model.window_bank)   # refine()-style swap
    p2 = plan_for(model)
    assert p2 is not p1
    np.testing.assert_allclose(
        np.asarray(p2(*inputs, backend="gather")),
        np.asarray(p1(*inputs, backend="gather")), rtol=1e-6, atol=1e-6)


def test_plan_for_detects_aux_mutation():
    """Non-bank attrs (NAM bias, logit LUT, window) are frozen into the plan
    at build; reassigning one on the model must invalidate the memo even
    though every bank is identity-unchanged."""
    import types

    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(7)
    layer = init_pegasus_linear(
        rng.normal(size=(6, 4)).astype(np.float32), None,
        rng.normal(size=(64, 6)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    model = types.SimpleNamespace(
        window_bank=layer, head_banks=[], nam=True,
        out_bias=jnp.zeros(4, jnp.float32), pool_windows=6)
    x = jnp.asarray(rng.normal(size=(4, 8, 2)).astype(np.float32))
    p1 = plan_for(model)
    y1 = np.asarray(p1(x, backend="gather"))
    assert plan_for(model) is p1                    # unchanged → memo hit
    model.out_bias = jnp.ones(4, jnp.float32)       # recalibrated bias
    p2 = plan_for(model)
    assert p2 is not p1                             # aux mutation → rebuilt
    np.testing.assert_allclose(np.asarray(p2(x, backend="gather")), y1 + 1.0,
                               rtol=1e-6, atol=1e-6)


def test_unknown_backend_rejected(ds):
    banks, plan, inputs = _family(ds, "mlp")
    with pytest.raises(ValueError, match="unknown backend"):
        plan(*inputs, backend="dense")
    with pytest.raises(ValueError, match="unknown backend"):
        build_plan(banks, backend="nope")


def test_pegasus_server_batches(ds):
    from repro.launch.serve import PegasusServer

    banks, plan, (x,) = _family(ds, "mlp")
    server = PegasusServer(banks, backend="onehot", max_batch=8)
    reqs = [np.asarray(x[i : i + 4]) for i in range(0, 16, 4)]
    outs = server.serve(reqs)
    assert len(outs) == 4 and all(o.shape[0] == 4 for o in outs)
    ref = np.asarray(plan(x, backend="onehot"))
    np.testing.assert_allclose(np.concatenate(outs), ref, rtol=1e-5, atol=1e-5)
    assert server.requests_served == 4
    assert server.batches_run == 2                 # 16 flows → buckets [8, 8]
    # second round reuses the SAME plan: no new layout/quant work
    before = STATS.layout_builds
    server.serve(reqs)
    assert STATS.layout_builds == before
    # both rounds hit ONE compiled bucket (8): 4 jit calls, 1 trace
    st = server.stats()["engine"]
    assert st["jit_calls"] == 4
    assert st["traces"] == 1
    assert st["bucket_hits"] == 3
    assert st["buckets"] == [("onehot", 8)]


def test_pegasus_server_counts_on_success_only(ds):
    """Satellite: a raising request must not corrupt the serving stats."""
    from repro.launch.serve import PegasusServer

    banks, _, (x,) = _family(ds, "mlp")
    server = PegasusServer(banks, backend="onehot", max_batch=8)
    server.serve([np.asarray(x[:4])])
    assert (server.requests_served, server.batches_run) == (1, 1)
    with pytest.raises(ValueError, match="unknown backend"):
        server.infer(x[:4], backend="dense")
    with pytest.raises(ValueError, match="unknown backend"):
        server.serve([np.asarray(x[:4])], backend="dense")
    assert (server.requests_served, server.batches_run) == (1, 1)
    # and the server still serves fine afterwards
    server.infer(x[:4])
    assert (server.requests_served, server.batches_run) == (2, 2)


def test_bucket_chunks_policy():
    from repro.engine import DEFAULT_BUCKETS, bucket_chunks

    assert bucket_chunks(16, max_batch=8) == [8, 8]
    assert bucket_chunks(256) == [256]              # exact bucket: one chunk
    assert bucket_chunks(300) == [256, 44]          # exact + minimal pad tail
    assert bucket_chunks(904) == [904]              # split wouldn't cut padding
    top = DEFAULT_BUCKETS[-1]
    assert bucket_chunks(top + 904) == [top, 904]
    assert bucket_chunks(2048, max_batch=4096) == [2048]  # the old fixed-1024
    # chunking split this despite its exact bucket
    assert bucket_chunks(3) == [3]
    assert sum(bucket_chunks(12345)) == 12345
    # a cap below the smallest bucket can't bound anything (dispatches pad
    # up to the smallest bucket regardless) — it must not multiply work
    assert bucket_chunks(8, max_batch=4) == [8]
    with pytest.raises(ValueError):
        bucket_chunks(0)


# ---------------------------------------------------------------------------
# Whole-plan jit + batch bucketing (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------


def test_jit_bucket_compile_invariants(ds):
    """Acceptance: repeated calls at one bucket trigger ZERO retraces; a new
    batch size triggers at most one (its bucket's first compile); sub-bucket
    batches round up into already-warm buckets."""
    _, plan, (x,) = _family(ds, "mlp")             # BATCH=16 → bucket 16
    be = "onehot"
    plan(x, backend=be)                            # warm bucket 16
    t0 = STATS.jit_traces
    plan(x, backend=be)
    plan(x, backend=be)
    assert STATS.jit_traces == t0                  # same bucket: no retrace
    plan(x[:9], backend=be)                        # 9 → bucket 16: still warm
    assert STATS.jit_traces == t0
    plan(x[:4], backend=be)                        # 4 → bucket 8: ≤ 1 trace
    assert STATS.jit_traces <= t0 + 1
    traces_after_8 = STATS.jit_traces
    plan(x[:3], backend=be)                        # 3 → bucket 8: warm again
    plan(x[:7], backend=be)
    assert STATS.jit_traces == traces_after_8
    assert ("onehot", 16) in plan.compiled_buckets


def test_bucket_padding_roundtrip(ds):
    """Zero-row bucket padding must not leak into the sliced-off outputs."""
    _, plan, (x,) = _family(ds, "mlp")
    for be in BACKENDS:
        full = np.asarray(plan(x, backend=be))
        odd = np.asarray(plan(x[:11], backend=be))  # 11 → bucket 16
        assert odd.shape[0] == 11
        np.testing.assert_allclose(odd, full[:11], rtol=1e-5, atol=1e-5,
                                   err_msg=f"bucket padding corrupted {be}")


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_jit_matches_eager(ds, family):
    """The jitted whole-plan forward is the same function as the eager
    per-bank dispatch — every backend, every family."""
    plan, inputs = _compiled(ds, family)
    for be in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(plan(*inputs, backend=be)),
            np.asarray(plan(*inputs, backend=be, jit=False)),
            rtol=1e-4, atol=1e-4, err_msg=f"{family}:{be} jit != eager")


def test_kernel_strategy_parity(ds):
    """The MXU one-hot-matmul and interpreter gather-sum kernel strategies
    are semantics-identical (same descent bits, same rows accumulated)."""
    banks, _, (x,) = _family(ds, "mlp")
    p_mxu = build_plan(banks, strategy="mxu")
    p_lookup = build_plan(banks, strategy="lookup")
    for be in ("kernel", "kernel_q8"):
        np.testing.assert_allclose(
            np.asarray(p_mxu(x, backend=be)),
            np.asarray(p_lookup(x, backend=be)),
            rtol=1e-4, atol=1e-4, err_msg=f"strategy parity broke for {be}")


def test_bucket_batch_policy():
    from repro.engine import DEFAULT_BUCKETS, bucket_batch

    assert bucket_batch(1) == DEFAULT_BUCKETS[0]
    assert bucket_batch(8) == 8
    assert bucket_batch(9) == 16
    assert bucket_batch(1024) == 1024
    top = DEFAULT_BUCKETS[-1]
    assert bucket_batch(top + 1) == 2 * top       # beyond the ladder:
    assert bucket_batch(2 * top) == 2 * top       # multiples of the largest
    with pytest.raises(ValueError):
        bucket_batch(0)


# ---------------------------------------------------------------------------
# PlanRegistry + multi-model serving (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------


def _fresh_banks(seed: int, n_out: int = 5) -> list:
    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(seed)
    return [init_pegasus_linear(
        rng.normal(size=(8, n_out)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)]


def test_plan_registry_evicts_dropped_models():
    """Satellite regression: dropping a model must evict its memoized plan
    (the old memo's strong refs pinned models forever — and a recycled id()
    could then alias a stale plan)."""
    import gc

    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    banks = _fresh_banks(11)
    plan = reg.plan_for(banks)
    assert reg.plan_for(banks) is plan
    assert len(reg) == 1
    del banks
    gc.collect()
    assert len(reg) == 0                          # dropped model → evicted
    # a plan the caller still holds keeps working after eviction
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    assert np.isfinite(np.asarray(plan(x, backend="gather"))).all()


def test_plan_is_refcount_reclaimable():
    """An evicted plan must free on refcount drop, not wait for a gen-2 GC
    pass: the jitted forward's closure may not reference the plan object
    (the plan ↔ closure cycle this guards against once existed)."""
    import weakref

    from repro.engine import build_plan

    plan = build_plan(_fresh_banks(31))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    plan(x, backend="gather")                     # populate the jit cache
    ref = weakref.ref(plan)
    del plan
    assert ref() is None                          # no cycle: died on refcount


def test_plan_registry_bounded_and_explicit_eviction():
    from repro.engine import PlanRegistry

    reg = PlanRegistry(max_plans=2)
    keep = [_fresh_banks(s) for s in range(3)]
    plans = [reg.plan_for(m) for m in keep]
    assert len(reg) == 2                          # LRU-bounded
    assert reg.plan_for(keep[0]) is not plans[0]  # oldest was evicted → rebuilt
    assert reg.discard(keep[0]) == 1              # explicit eviction
    assert len(reg) == 1


def test_plan_registry_named_entries():
    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    banks = _fresh_banks(21)
    plan = reg.register("mlp-a", banks, backend="gather")
    assert "mlp-a" in reg and reg.names() == ["mlp-a"]
    assert reg.get("mlp-a") is plan
    assert reg.model("mlp-a") is banks
    st = reg.stats()["mlp-a"]
    assert st["backend"] == "gather" and st["num_banks"] == 1
    assert reg.evict("mlp-a") and "mlp-a" not in reg
    assert not reg.evict("mlp-a")                 # double-evict is a no-op
    with pytest.raises(KeyError):
        reg.get("mlp-a")


def test_plan_registry_reregister_discards_replaced_memo():
    """Satellite: register() over an existing name must discard the replaced
    model's memo entry, exactly like evict() — otherwise the superseded
    plan lingered in the bounded memo until LRU churn or GC."""
    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    a, b = _fresh_banks(51), _fresh_banks(52)
    plan_a = reg.register("m", a)
    assert len(reg) == 1
    plan_b = reg.register("m", b)               # replaces a
    assert plan_b is not plan_a
    assert reg.model("m") is b
    assert len(reg) == 1                        # a's memo entry discarded
    # b's memo entry intact (same build options as register's)
    assert reg.plan_for(b, backend="onehot") is plan_b
    # re-registering the SAME model must not discard its own entry
    assert reg.register("m", b) is plan_b
    assert len(reg) == 1


def test_plan_registry_reregister_same_banks_keeps_memo():
    """Re-registering a DIFFERENT wrapper over the SAME bank objects must
    not discard the (shared, bank-identity-keyed) memo entry the new model
    just produced — that discard would force a duplicate compile on the
    next plan_for."""
    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    banks = _fresh_banks(55)
    a, b = list(banks), list(banks)            # distinct wrappers, same banks
    plan = reg.register("m", a)
    assert len(reg) == 1
    plan2 = reg.register("m", b)               # same key (element identity)
    assert plan2 is plan                       # memo hit, not a rebuild
    assert len(reg) == 1                       # and the entry survived


def test_plan_registry_recompile_refreshes_named_stats():
    """Satellite: the get() recompile-on-stale path must refresh the named
    entry's build stats (plan_build_ms re-timed, recompiles counted) —
    the old path left register()-time numbers on a replaced plan."""
    import dataclasses as dc

    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    model = list(_fresh_banks(53))
    reg.register("m", model)
    st0 = reg.stats()["m"]
    assert st0["recompiles"] == 0
    p1 = reg.get("m")
    assert reg.stats()["m"]["recompiles"] == 0  # fresh get: no rebuild
    model[-1] = dc.replace(model[-1])           # refine()-style bank swap
    p2 = reg.get("m")
    assert p2 is not p1                         # stale → recompiled
    st1 = reg.stats()["m"]
    assert st1["recompiles"] == 1
    assert st1["plan_build_ms"] != st0["plan_build_ms"]   # re-timed
    assert reg.get("m") is p2                   # fresh again: stable


def test_plan_registry_concurrent_first_call_builds_once():
    """Tentpole thread-safety: N threads racing plan_for on one uncached
    model must serialize on the registry lock — exactly ONE build, every
    caller handed the same plan."""
    import threading

    from repro.engine import PlanRegistry

    reg = PlanRegistry()
    banks = _fresh_banks(54)
    before = STATS.plan_builds
    n = 4
    plans = [None] * n
    barrier = threading.Barrier(n)

    def first_call(i):
        barrier.wait()
        plans[i] = reg.plan_for(banks)

    threads = [threading.Thread(target=first_call, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert STATS.plan_builds == before + 1      # no double-compile
    assert all(p is plans[0] for p in plans)


def _multi_server(ds):
    """One server holding 3 mixed-family plans (mlp, ae fast; rnn cached)."""
    from repro.launch.serve import MultiModelServer

    server = MultiModelServer(backend="onehot")
    names = ("mlp", "ae", "rnn")
    for fam in names:
        model, _, _ = _family(ds, fam)
        server.add_model(fam, model)
    return server, names


@pytest.mark.slow
def test_multi_model_outputs_match_standalone_plans(ds):
    """N≥3 mixed-family models behind one server produce outputs identical
    to their standalone plans."""
    server, names = _multi_server(ds)
    reqs = []
    for fam in names:
        _, _, inputs = _family(ds, fam)
        reqs += [(fam, tuple(x[:8] for x in inputs)),
                 (fam, tuple(x[8:16] for x in inputs))]
    outs = server.serve(reqs)
    assert len(outs) == len(reqs)
    for (fam, inputs), out in zip(reqs, outs):
        _, plan, _ = _family(ds, fam)
        ref = np.asarray(plan(*inputs, backend="onehot"))
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5,
                                   err_msg=f"served {fam} != standalone plan")


@pytest.mark.slow
def test_multi_model_compile_caches_isolated(ds):
    """Serving model B must never retrace model A's plan: each plan compiles
    once per (backend, bucket) it actually serves, nothing more."""
    server, names = _multi_server(ds)
    for fam in names:
        _, _, inputs = _family(ds, fam)
        server.submit(fam, *(x[:8] for x in inputs))
    server.drain()
    per_plan = {f: server.registry.get(f).compile_stats()["traces"] for f in names}
    before = STATS.jit_traces
    for _ in range(2):                            # repeat rounds: all warm
        for fam in names:
            _, _, inputs = _family(ds, fam)
            server.submit(fam, *(x[:8] for x in inputs))
        server.drain()
    assert STATS.jit_traces == before             # zero cross-model retraces
    for fam in names:
        assert server.registry.get(fam).compile_stats()["traces"] == per_plan[fam]


@pytest.mark.slow
def test_multi_model_fair_scheduling_drains_all_queues(ds):
    """Round-robin: one micro-batch per pending model per turn — a burst on
    one model cannot monopolize the dispatch order — and every queue ends
    empty."""
    server, names = _multi_server(ds)
    server.max_batch = 8                          # force 2 chunks per model
    for fam in names:
        _, _, inputs = _family(ds, fam)
        for lo in (0, 8):
            server.submit(fam, *(x[lo : lo + 8] for x in inputs))
    assert server.pending() == {f: 2 for f in names}
    log_start = len(server.schedule_log)
    results = server.drain()
    assert server.pending() == {}                 # every queue drained
    assert sorted(results) == sorted(names)
    assert all(len(results[f]) == 2 for f in names)
    log = list(server.schedule_log)[log_start:]
    # 2 chunks per model, interleaved one-per-model per round
    assert log == list(names) + list(names)
    st = server.stats()["serving"]["models"]
    for fam in names:
        assert st[fam]["requests_served"] == 2
        assert st[fam]["batches_run"] == 2
        assert st[fam]["flows_served"] == 16


def test_multi_model_adopts_shared_registry(ds):
    """A server built on a pre-populated registry must serve its names
    (queues/counters adopted at construction, and lazily for names
    registered afterwards)."""
    from repro.engine import PlanRegistry
    from repro.launch.serve import MultiModelServer

    banks, _, (x,) = _family(ds, "mlp")
    reg = PlanRegistry()
    reg.register("pre", banks, backend="onehot")
    server = MultiModelServer(registry=reg, backend="onehot")
    assert server.models() == ["pre"]
    y = server.infer("pre", x[:4])
    assert np.asarray(y).shape[0] == 4
    reg.register("post", banks, backend="onehot")  # registered after init
    server.submit("post", x[:4])
    assert server.drain()["post"][0].shape[0] == 4
    st = server.stats()["serving"]["models"]
    assert st["pre"]["requests_served"] == 1
    assert st["post"]["requests_served"] == 1


def test_multi_model_unknown_name_and_success_only_stats(ds):
    from repro.launch.serve import MultiModelServer

    banks, _, (x,) = _family(ds, "mlp")
    server = MultiModelServer({"mlp": banks}, backend="onehot")
    with pytest.raises(KeyError, match="unknown model"):
        server.submit("nope", x[:4])
    server.submit("mlp", x[:4])
    with pytest.raises(ValueError, match="unknown backend"):
        server.drain(backend="dense")             # every model failed → raise
    st = server.stats()["serving"]["models"]["mlp"]
    assert (st["requests_served"], st["batches_run"]) == (0, 0)
    assert server.pending() == {"mlp": 1}         # failed drain is retryable
    out = server.drain()
    assert out["mlp"][0].shape[0] == 4
    st = server.stats()["serving"]["models"]["mlp"]
    assert (st["requests_served"], st["batches_run"]) == (1, 1)


# ---------------------------------------------------------------------------
# Cross-bank Primitive Fusion (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------


def _chain_banks(seed: int, dims=(8, 8, 8, 5), group_size: int = 2,
                 depth: int = 3) -> list:
    """A sequential stack whose consecutive banks chain exactly (out == in):
    maximal fusion material."""
    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(seed)
    banks = []
    for d_in, d_out in zip(dims, dims[1:]):
        banks.append(init_pegasus_linear(
            rng.normal(size=(d_in, d_out)).astype(np.float32),
            rng.normal(size=d_out).astype(np.float32) * 0.1,
            rng.normal(size=(128, d_in)).astype(np.float32),
            group_size=group_size, depth=depth, lut_bits=None))
    return banks


def test_fuse_banks_groups_compatible_runs():
    """The planning pass groups maximal compatible runs and leaves anything
    incompatible (here: a different partition width v) as per-bank steps."""
    banks = [CompiledBank(l) for l in _chain_banks(40, dims=(8, 8, 8, 4))]
    steps = fuse_banks(banks)
    assert len(steps) == 1 and isinstance(steps[0], FusedBankStack)
    assert steps[0].banks == banks
    assert steps[0].ks == (4, 4, 4) and steps[0].n_out == 4

    # a bank with group_size=4 cannot join a v=2 run
    odd = CompiledBank(_chain_banks(41, dims=(4, 6), group_size=4)[0])
    mixed = fuse_banks([banks[0], banks[1], odd])
    assert len(mixed) == 2
    assert isinstance(mixed[0], FusedBankStack) and mixed[1] is odd

    # a lone bank (or a broken chain) stays per-bank
    assert fuse_banks([banks[0]]) == [banks[0]]


def test_fused_plan_parity_all_backends_and_strategies(ds):
    """Acceptance: the fused-stack output ≡ the per-bank output on every
    backend and both kernel strategies."""
    banks, _, (x,) = _family(ds, "mlp")
    for strategy in ("mxu", "lookup"):
        fused = build_plan(banks, strategy=strategy)
        unfused = build_plan(banks, strategy=strategy, fuse=False)
        assert fused.fused_groups >= 1 and unfused.fused_groups == 0
        for be in BACKENDS:
            np.testing.assert_allclose(
                np.asarray(fused(x, backend=be)),
                np.asarray(unfused(x, backend=be)),
                rtol=1e-4, atol=1e-4,
                err_msg=f"fused != per-bank for {be}/{strategy}")


def test_fused_synthetic_chain_parity():
    """K and N padding inside the stack (first bank wider, last bank
    narrower) must not leak into the output."""
    layers = _chain_banks(42, dims=(12, 8, 8, 3))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, 12)), jnp.float32)
    fused = build_plan(layers)
    unfused = build_plan(layers, fuse=False)
    assert fused.fused_banks == 3
    for be in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(fused(x, backend=be)),
            np.asarray(unfused(x, backend=be)), rtol=1e-4, atol=1e-4,
            err_msg=f"padded stack parity broke on {be}")


def test_fusion_does_not_add_traces(ds):
    """Acceptance: fusing never changes the compile count — one trace per
    (backend, bucket) on the fused plan, exactly like the per-bank plan."""
    banks, _, (x,) = _family(ds, "mlp")
    fused = build_plan(banks)
    unfused = build_plan(banks, fuse=False)
    for plan in (fused, unfused):
        for be in BACKENDS:
            plan(x, backend=be)
            plan(x, backend=be)            # warm: must not retrace
            plan(x[:9], backend=be)        # rounds into the same bucket
    assert fused.compile_stats()["traces"] == unfused.compile_stats()["traces"]
    assert fused.compiled_buckets == unfused.compiled_buckets
    for plan in (fused, unfused):
        assert plan.compile_stats()["traces"] == len(plan.compiled_buckets)


def test_fused_stack_falls_back_on_bad_operands(ds):
    """A stack the kernel refuses (ValueError, e.g. a mis-sized ks tuple)
    must fall back to the per-bank chain instead of raising."""
    banks, _, (x,) = _family(ds, "mlp")
    stack = fuse_banks([CompiledBank(l) for l in banks])[0]
    ref = np.asarray(stack.apply(x, "kernel"))
    stack.ks = stack.ks + (stack.ks[-1],)      # now inconsistent with L
    out = np.asarray(stack.apply(x, "kernel"))  # ValueError → per-bank path
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_compile_stats_reports_pad_waste_and_fusion(ds):
    banks, _, (x,) = _family(ds, "mlp")
    plan = build_plan(banks)
    plan(x[:11], backend="gather")             # 11 → bucket 16: 5 filler rows
    plan(x, backend="gather")                  # exact bucket: zero filler
    st = plan.compile_stats()
    assert st["fused_groups"] == 1 and st["fused_banks"] == len(banks)
    # cumulative per bucket: 11 + 16 requested over 2×16 dispatched
    assert abs(st["pad_waste"]["gather@16"] - (1 - 27 / 32)) < 1e-3
    # MultiModelServer surfaces the same counters per model
    from repro.launch.serve import MultiModelServer

    server = MultiModelServer({"mlp": banks}, backend="gather")
    server.infer("mlp", x[:11])
    mst = server.stats()["engine"]["models"]["mlp"]
    assert mst["fused_groups"] == 1
    assert mst["pad_waste"]["gather@16"] == round(5 / 16, 4)


def test_compile_stats_folds_fused_operand_padding_into_pad_waste():
    """Kernel-backend pad_waste must charge the fused stack's Kmax/Nmax
    operand padding, not just batch filler; the fallback backends run on
    true-size tables and keep the batch-only number. Pinned on the
    (12, 8, 8, 3) chain: ks=(6, 4, 4), C=8, ns=(8, 8, 3) →
    useful = 6·8·8 + 4·8·8 + 4·8·3 = 736 LUT cells of the 3·6·8·8 = 1152
    the stacked slab dispatches."""
    layers = _chain_banks(42, dims=(12, 8, 8, 3))
    plan = build_plan(layers)
    assert plan.fused_banks == 3
    st = plan.compile_stats()
    fused = st["pad_waste_fused"]["group0"]
    assert (fused["layers"], fused["kmax"], fused["nmax"]) == (3, 6, 8)
    assert fused["frac"] == round(1 - 736 / 1152, 4) == 0.3611

    x = jnp.asarray(np.random.default_rng(1).normal(size=(11, 12)), jnp.float32)
    plan(x, backend="kernel")
    plan(x, backend="gather")
    waste = plan.compile_stats()["pad_waste"]
    # gather dispatches per-bank true-size tables: batch filler only
    assert waste["gather@16"] == round(5 / 16, 4)
    # kernel dispatches the padded slab: batch filler × operand efficiency
    assert waste["kernel@16"] == round(1 - (11 / 16) * (736 / 1152), 4)
    # a fully-unfused plan has no operand padding: backends agree again
    unfused = build_plan(layers, fuse=False)
    unfused(x, backend="kernel")
    assert unfused.compile_stats()["pad_waste"]["kernel@16"] == \
        round(5 / 16, 4)
    assert unfused.compile_stats()["pad_waste_fused"] == {}


def test_fuse_flag_participates_in_plan_key(ds):
    banks, _, (x,) = _family(ds, "mlp")
    p_fused = plan_for(banks)
    p_unfused = plan_for(banks, fuse=False)
    assert p_fused is not p_unfused
    assert plan_for(banks) is p_fused           # both memoized independently
    assert plan_for(banks, fuse=False) is p_unfused
    assert p_unfused.fused_groups == 0


def test_donated_inputs_never_invalidate_caller_arrays(ds):
    """__call__ donates its padded buffers to the jitted forward; a caller's
    array must survive both the exact-bucket and the padded path."""
    banks, plan, (x,) = _family(ds, "mlp")
    x16 = jnp.asarray(x[:16])                  # exact bucket size
    y1 = np.asarray(plan(x16, backend="gather"))
    y2 = np.asarray(plan(x16, backend="gather"))
    np.testing.assert_allclose(y1, y2)
    assert not x16.is_deleted()
    _ = np.asarray(x16 + 1.0)                  # still usable
    x11 = jnp.asarray(x[:11])                  # padded up to bucket 16
    plan(x11, backend="gather")
    plan(x11, backend="gather")
    assert not x11.is_deleted()


def test_ops_layout_memo_pads_static_operands_once():
    """Satellite: the ops.py wrappers must not re-pad lut/thr/feat_oh per
    call — one layout build per (layer, geometry), cache hits after."""
    from repro.core.amm import init_pegasus_linear
    from repro.kernels.fuzzy_lut.ops import (
        LAYOUT_STATS, fuzzy_lut_matmul, fuzzy_lut_matmul_q8)

    rng = np.random.default_rng(5)
    layer = init_pegasus_linear(
        rng.normal(size=(24, 10)).astype(np.float32), None,
        rng.normal(size=(256, 24)).astype(np.float32), group_size=4, depth=3,
        lut_bits=None)                          # K=6, N=10: NOT block-divisible
    x = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
    fuzzy_lut_matmul(layer, x, block_t=8, block_n=8, block_k=4)
    builds = LAYOUT_STATS["layout_builds"]
    hits = LAYOUT_STATS["cache_hits"]
    fuzzy_lut_matmul(layer, x, block_t=8, block_n=8, block_k=4)
    fuzzy_lut_matmul(layer, x[:3], block_t=8, block_n=8, block_k=4)
    assert LAYOUT_STATS["layout_builds"] == builds       # no re-pad per call
    assert LAYOUT_STATS["cache_hits"] >= hits + 2
    # the q8 wrapper keeps its own (quantized) layout entry
    fuzzy_lut_matmul_q8(layer, x, block_t=8, block_n=8, block_k=4)
    builds_q8 = LAYOUT_STATS["layout_builds"]
    fuzzy_lut_matmul_q8(layer, x, block_t=8, block_n=8, block_k=4)
    assert LAYOUT_STATS["layout_builds"] == builds_q8


@pytest.mark.slow
def test_fuse_nmax_cap_splits_ballooning_groups():
    """Satellite: one wide bank must not balloon a narrow stack's padded
    [L, Kmax, C, Nmax] footprint — the run splits at the cap (the wide bank
    stands alone), narrow neighbors still fuse, and outputs stay identical
    to the unfused path."""
    layers = _chain_banks(45, dims=(8, 8, 64, 8, 5))    # N = (8, 64, 8, 5)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(16, 8)), jnp.float32)
    wide = build_plan(layers)                   # default cap: all 4 banks fuse
    assert (wide.fused_groups, wide.fused_banks) == (1, 4)
    capped = build_plan(layers, fuse_nmax_cap=16)
    # b0 alone (joining N=64 would balloon it), b1 (N=64) alone, b2+b3 fuse
    assert (capped.fused_groups, capped.fused_banks) == (1, 2)
    unfused = build_plan(layers, fuse=False)
    for be in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(capped(x, backend=be)),
            np.asarray(unfused(x, backend=be)), rtol=1e-4, atol=1e-4,
            err_msg=f"nmax-capped plan parity broke on {be}")
        np.testing.assert_allclose(
            np.asarray(wide(x, backend=be)),
            np.asarray(unfused(x, backend=be)), rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_fuse_nmax_cap_allows_uniformly_wide_runs():
    """Equal-width banks above the cap add no padding — they still fuse."""
    layers = _chain_banks(46, dims=(64, 64, 64))        # N = (64, 64)
    plan = build_plan(layers, fuse_nmax_cap=16)
    assert (plan.fused_groups, plan.fused_banks) == (1, 2)
    x = jnp.asarray(np.random.default_rng(5).normal(size=(8, 64)), jnp.float32)
    unfused = build_plan(layers, fuse=False)
    for be in ("gather", "kernel"):
        np.testing.assert_allclose(
            np.asarray(plan(x, backend=be)),
            np.asarray(unfused(x, backend=be)), rtol=1e-4, atol=1e-4)


def test_fuse_nmax_cap_participates_in_plan_key(ds):
    from repro.engine import DEFAULT_FUSE_NMAX_CAP

    banks, _, _ = _family(ds, "mlp")
    p_default = plan_for(banks)                 # N=(32,32,32,3): one group of 4
    p_capped = plan_for(banks, fuse_nmax_cap=1)
    assert p_default is not p_capped
    # cap 1 splits the narrow classifier off; the equal-width hidden run
    # stays fused (uniform width adds no padding)
    assert (p_default.fused_groups, p_default.fused_banks) == (1, 4)
    assert (p_capped.fused_groups, p_capped.fused_banks) == (1, 3)
    # the default cap is normalized into the key: explicit == implicit
    assert plan_for(banks, fuse_nmax_cap=DEFAULT_FUSE_NMAX_CAP) is p_default
    assert plan_for(banks, fuse_nmax_cap=None) is not p_default


def test_multi_model_drain_isolates_failing_model(ds):
    """A model whose dispatch raises must not lose the other models'
    results, corrupt any counters, or drop its own (retryable) queue."""
    from repro.launch.serve import MultiModelServer

    banks, _, (x,) = _family(ds, "mlp")
    server = MultiModelServer({"good": banks, "bad": banks}, backend="onehot")
    server.submit("good", x[:4])
    server.submit("bad", x[:4, : x.shape[1] // 2])   # wrong feature width
    results = server.drain()                      # good drains, bad isolated
    assert list(results) == ["good"]
    assert results["good"][0].shape[0] == 4
    assert "bad" in server.last_drain_errors
    st = server.stats()["serving"]["models"]
    assert (st["good"]["requests_served"], st["good"]["batches_run"]) == (1, 1)
    assert (st["bad"]["requests_served"], st["bad"]["batches_run"]) == (0, 0)
    assert server.pending() == {"bad": 1}         # bad queue kept for retry
    # a permanently-bad request poisons its queue — discard_pending clears it
    assert server.discard_pending("bad") == 1
    assert server.pending() == {}
    # serve(): the failed model's error carries the served partial results
    with pytest.raises(Exception) as ei:
        server.serve([("good", x[:4]), ("bad", x[:4, : x.shape[1] // 2])])
    assert ei.value.partial_results["good"][0].shape[0] == 4
    server.discard_pending("bad")
