"""Execution-engine tests: backend parity across every net family, plan
caching (no layout prep / quantization on the call path), and the q8 memo.

Parity contract (ISSUE acceptance): through one ExecutionPlan,
``gather == onehot == kernel`` bitwise-closely for MLP, RNN, CNN, CNN-L and
AutoEncoder pegasus variants, and ``kernel_q8`` matches within int8
quantization tolerance — exactly per-bank, and by prediction agreement at
the net level (index flips near thresholds compound across stacked banks,
so elementwise net-level bounds would be vacuous).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.synthetic_traffic import make_dataset
from repro.engine import BACKENDS, STATS, CompiledBank, build_plan, plan_for
from repro.kernels.fuzzy_lut import ops

pytestmark = pytest.mark.kernel   # every case exercises the Pallas backends

FLOWS = 48
STEPS = 5          # parity needs a trained-enough model, not an accurate one
BATCH = 16


@pytest.fixture(scope="module")
def ds():
    return make_dataset("peerrush", flows_per_class=FLOWS)


def _mlp(ds):
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=STEPS)
    banks = pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                           depth=3, refine_steps=0)
    return banks, (jnp.asarray(ds.test["stats"][:BATCH], jnp.float32),)


def _rnn(ds):
    from repro.nets.rnn import pegasusify_rnn, train_rnn

    m = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes, steps=STEPS)
    return pegasusify_rnn(m, ds.train["seq"], depth=4), (
        jnp.asarray(ds.test["seq"][:BATCH]),)


def _cnn(ds):
    from repro.nets.cnn import pegasusify_cnn, train_cnn

    m = train_cnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                  size="B", steps=STEPS)
    return pegasusify_cnn(m, ds.train["seq"], depth=5), (
        jnp.asarray(ds.test["seq"][:BATCH]),)


def _cnn_l(ds):
    from repro.nets.cnn import pegasusify_cnn_l, train_cnn_l

    m = train_cnn_l(ds.train["seq"], ds.train["bytes"], ds.train["label"],
                    ds.num_classes, steps=STEPS)
    peg = pegasusify_cnn_l(m, ds.train["seq"], ds.train["bytes"],
                           enc_depth=4, index_bits=3)
    return peg, (jnp.asarray(ds.test["seq"][:BATCH]),
                 jnp.asarray(ds.test["bytes"][:BATCH]))


def _ae(ds):
    from repro.nets.autoencoder import pegasusify_ae, train_autoencoder

    x = ds.train["seq"].reshape(len(ds.train["label"]), -1)
    m = train_autoencoder(x, steps=STEPS)
    banks = pegasusify_ae(m, x.astype(np.float32), depth=4)
    xt = ds.test["seq"][:BATCH].reshape(BATCH, -1)
    return banks, (jnp.asarray(xt, jnp.float32),)


FAMILIES = {"mlp": _mlp, "rnn": _rnn, "cnn": _cnn, "cnn_l": _cnn_l, "ae": _ae}

# mlp + ae are cheap enough for the fast CI lane; the windowed/unrolled
# families train + compile for tens of seconds and ride the full lane.
FAMILY_PARAMS = [
    pytest.param("mlp"),
    pytest.param("ae"),
    pytest.param("rnn", marks=pytest.mark.slow),
    pytest.param("cnn", marks=pytest.mark.slow),
    pytest.param("cnn_l", marks=pytest.mark.slow),
]

_COMPILED: dict[str, tuple] = {}


def _family(ds, family):
    """Lazy per-family (model, plan, inputs) — built once, on first use."""
    if family not in _COMPILED:
        model, inputs = FAMILIES[family](ds)
        _COMPILED[family] = (model, build_plan(model), inputs)
    return _COMPILED[family]


def _compiled(ds, family):
    _, plan, inputs = _family(ds, family)
    return plan, inputs


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_backend_parity(ds, family):
    plan, inputs = _compiled(ds, family)
    ref = np.asarray(plan(*inputs, backend="gather"))
    assert np.isfinite(ref).all()

    # exact backends: identical up to fp32 accumulation order
    for be in ("onehot", "kernel"):
        out = np.asarray(plan(*inputs, backend=be))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{family}:{be}")

    # q8: quantization tolerance per bank, on the REAL activations each bank
    # sees (random inputs land in degenerate leaves and say nothing)
    for i, xb in enumerate(plan.bank_inputs(*inputs)):
        bank = plan.banks[i]
        yg = np.asarray(bank.apply(xb, "gather"))
        yq = np.asarray(bank.apply(xb, "kernel_q8"))
        denom = max(float(np.linalg.norm(yg)), 1e-6)
        rel = float(np.linalg.norm(yq - yg)) / denom
        assert rel < 0.12, (family, i, rel)
    # … and agreeing predictions end-to-end (flips compound across banks)
    outq = np.asarray(plan(*inputs, backend="kernel_q8"))
    assert np.isfinite(outq).all()
    if family != "ae":
        agree = float((outq.argmax(-1) == ref.argmax(-1)).mean())
        assert agree >= 0.75, (family, agree)
    else:
        rel = float(np.linalg.norm(outq - ref) / np.linalg.norm(ref))
        assert rel < 0.25, (family, rel)


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_plan_call_does_no_layout_work(ds, family):
    """Acceptance: after one warm call, further calls perform ZERO layout
    prep and ZERO quantization on any backend."""
    plan, inputs = _compiled(ds, family)
    for be in BACKENDS:
        plan(*inputs, backend=be)            # warm (layouts were plan-time anyway)
    before_layout = STATS.layout_builds
    before_quant = ops.QUANT_STATS["quantize_calls"]
    for be in BACKENDS:
        plan(*inputs, backend=be)
    assert STATS.layout_builds == before_layout
    assert ops.QUANT_STATS["quantize_calls"] == before_quant


def test_bank_layout_built_once():
    """CompiledBank does its layout work in __init__, not in apply()."""
    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(0)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    before = STATS.layout_builds
    bank = CompiledBank(layer)
    assert STATS.layout_builds == before + 1
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    ref = np.asarray(bank.apply(x, "gather"))
    for be in ("onehot", "kernel"):
        np.testing.assert_allclose(np.asarray(bank.apply(x, be)), ref,
                                   rtol=1e-4, atol=1e-5)
    assert STATS.layout_builds == before + 1   # apply() never re-preps


def test_pegasus_linear_compile_method():
    """core/amm hook: PegasusLinear.compile() yields a single-bank plan."""
    from repro.core.amm import apply_gather, init_pegasus_linear

    rng = np.random.default_rng(3)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    plan = layer.compile()
    assert plan.num_banks == 1
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    ref = np.asarray(apply_gather(layer, x))
    for be in BACKENDS[:3]:
        np.testing.assert_allclose(np.asarray(plan(x, backend=be)), ref,
                                   rtol=1e-4, atol=1e-5)


def test_q8_memo_quantizes_once():
    """Satellite fix: fuzzy_lut_matmul_q8 must not re-quantize per call."""
    from repro.core.amm import init_pegasus_linear
    from repro.kernels.fuzzy_lut.ops import fuzzy_lut_matmul_q8

    rng = np.random.default_rng(1)
    layer = init_pegasus_linear(
        rng.normal(size=(8, 6)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    x = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    fuzzy_lut_matmul_q8(layer, x)
    calls = ops.QUANT_STATS["quantize_calls"]
    hits = ops.QUANT_STATS["cache_hits"]
    fuzzy_lut_matmul_q8(layer, x)
    fuzzy_lut_matmul_q8(layer, x)
    assert ops.QUANT_STATS["quantize_calls"] == calls        # no re-quant
    assert ops.QUANT_STATS["cache_hits"] >= hits + 2


def test_q8_memo_evicts_dead_layers():
    import gc

    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(2)
    layer = init_pegasus_linear(
        rng.normal(size=(4, 3)).astype(np.float32), None,
        rng.normal(size=(32, 4)).astype(np.float32), group_size=2, depth=2,
        lut_bits=None)
    ops.quantized_lut_cached(layer)
    key = id(layer)
    assert key in ops._Q8_MEMO
    del layer
    gc.collect()
    assert key not in ops._Q8_MEMO


def test_plan_for_memoizes(ds):
    banks, _, inputs = _family(ds, "mlp")
    hits = STATS.plan_cache_hits
    p1 = plan_for(banks)
    p2 = plan_for(banks)
    assert p1 is p2
    assert STATS.plan_cache_hits == hits + 1
    np.testing.assert_allclose(
        np.asarray(p1(*inputs, backend="onehot")),
        np.asarray(p1(*inputs, backend="gather")), rtol=1e-4, atol=1e-4)


def test_plan_for_detects_inplace_mutation(ds):
    """Reassigning a bank on the model must invalidate the memo — otherwise
    the engine would keep serving logits from the pre-mutation tables."""
    import dataclasses as dc

    banks, _, inputs = _family(ds, "mlp")
    model = list(banks)
    p1 = plan_for(model)
    y1 = np.asarray(p1(*inputs, backend="gather"))
    assert plan_for(model) is p1                    # unchanged → memo hit
    # simulate refine(): replace a bank with a copy (new object, same arrays)
    model[-1] = dc.replace(model[-1])
    p2 = plan_for(model)
    assert p2 is not p1                             # mutation → rebuilt
    np.testing.assert_allclose(
        np.asarray(p2(*inputs, backend="gather")), y1, rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_plan_for_detects_wrapper_mutation(ds):
    """Same for attribute reassignment on a wrapper model (id-stable key):
    the memo must notice the compiled banks no longer match the model's."""
    import dataclasses as dc

    model, _, inputs = _family(ds, "cnn")
    p1 = plan_for(model)
    assert plan_for(model) is p1
    model.window_bank = dc.replace(model.window_bank)   # refine()-style swap
    p2 = plan_for(model)
    assert p2 is not p1
    np.testing.assert_allclose(
        np.asarray(p2(*inputs, backend="gather")),
        np.asarray(p1(*inputs, backend="gather")), rtol=1e-6, atol=1e-6)


def test_plan_for_detects_aux_mutation():
    """Non-bank attrs (NAM bias, logit LUT, window) are frozen into the plan
    at build; reassigning one on the model must invalidate the memo even
    though every bank is identity-unchanged."""
    import types

    from repro.core.amm import init_pegasus_linear

    rng = np.random.default_rng(7)
    layer = init_pegasus_linear(
        rng.normal(size=(6, 4)).astype(np.float32), None,
        rng.normal(size=(64, 6)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)
    model = types.SimpleNamespace(
        window_bank=layer, head_banks=[], nam=True,
        out_bias=jnp.zeros(4, jnp.float32), pool_windows=6)
    x = jnp.asarray(rng.normal(size=(4, 8, 2)).astype(np.float32))
    p1 = plan_for(model)
    y1 = np.asarray(p1(x, backend="gather"))
    assert plan_for(model) is p1                    # unchanged → memo hit
    model.out_bias = jnp.ones(4, jnp.float32)       # recalibrated bias
    p2 = plan_for(model)
    assert p2 is not p1                             # aux mutation → rebuilt
    np.testing.assert_allclose(np.asarray(p2(x, backend="gather")), y1 + 1.0,
                               rtol=1e-6, atol=1e-6)


def test_unknown_backend_rejected(ds):
    banks, plan, inputs = _family(ds, "mlp")
    with pytest.raises(ValueError, match="unknown backend"):
        plan(*inputs, backend="dense")
    with pytest.raises(ValueError, match="unknown backend"):
        build_plan(banks, backend="nope")


def test_pegasus_server_batches(ds):
    from repro.launch.serve import PegasusServer

    banks, plan, (x,) = _family(ds, "mlp")
    server = PegasusServer(banks, backend="onehot", max_batch=8)
    reqs = [np.asarray(x[i : i + 4]) for i in range(0, 16, 4)]
    outs = server.serve(reqs)
    assert len(outs) == 4 and all(o.shape[0] == 4 for o in outs)
    ref = np.asarray(plan(x, backend="onehot"))
    np.testing.assert_allclose(np.concatenate(outs), ref, rtol=1e-5, atol=1e-5)
    assert server.requests_served == 4
    assert server.batches_run == 2                 # 16 flows / max_batch=8
    # second round reuses the SAME plan: no new layout/quant work
    before = STATS.layout_builds
    server.serve(reqs)
    assert STATS.layout_builds == before
    # both rounds hit ONE compiled bucket (8): 4 jit calls, 1 trace
    st = server.stats()
    assert st["jit_calls"] == 4
    assert st["traces"] == 1
    assert st["bucket_hits"] == 3
    assert st["buckets"] == [("onehot", 8)]


# ---------------------------------------------------------------------------
# Whole-plan jit + batch bucketing (ISSUE 2 tentpole)
# ---------------------------------------------------------------------------


def test_jit_bucket_compile_invariants(ds):
    """Acceptance: repeated calls at one bucket trigger ZERO retraces; a new
    batch size triggers at most one (its bucket's first compile); sub-bucket
    batches round up into already-warm buckets."""
    _, plan, (x,) = _family(ds, "mlp")             # BATCH=16 → bucket 16
    be = "onehot"
    plan(x, backend=be)                            # warm bucket 16
    t0 = STATS.jit_traces
    plan(x, backend=be)
    plan(x, backend=be)
    assert STATS.jit_traces == t0                  # same bucket: no retrace
    plan(x[:9], backend=be)                        # 9 → bucket 16: still warm
    assert STATS.jit_traces == t0
    plan(x[:4], backend=be)                        # 4 → bucket 8: ≤ 1 trace
    assert STATS.jit_traces <= t0 + 1
    traces_after_8 = STATS.jit_traces
    plan(x[:3], backend=be)                        # 3 → bucket 8: warm again
    plan(x[:7], backend=be)
    assert STATS.jit_traces == traces_after_8
    assert ("onehot", 16) in plan.compiled_buckets


def test_bucket_padding_roundtrip(ds):
    """Zero-row bucket padding must not leak into the sliced-off outputs."""
    _, plan, (x,) = _family(ds, "mlp")
    for be in BACKENDS:
        full = np.asarray(plan(x, backend=be))
        odd = np.asarray(plan(x[:11], backend=be))  # 11 → bucket 16
        assert odd.shape[0] == 11
        np.testing.assert_allclose(odd, full[:11], rtol=1e-5, atol=1e-5,
                                   err_msg=f"bucket padding corrupted {be}")


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_jit_matches_eager(ds, family):
    """The jitted whole-plan forward is the same function as the eager
    per-bank dispatch — every backend, every family."""
    plan, inputs = _compiled(ds, family)
    for be in BACKENDS:
        np.testing.assert_allclose(
            np.asarray(plan(*inputs, backend=be)),
            np.asarray(plan(*inputs, backend=be, jit=False)),
            rtol=1e-4, atol=1e-4, err_msg=f"{family}:{be} jit != eager")


def test_kernel_strategy_parity(ds):
    """The MXU one-hot-matmul and interpreter gather-sum kernel strategies
    are semantics-identical (same descent bits, same rows accumulated)."""
    banks, _, (x,) = _family(ds, "mlp")
    p_mxu = build_plan(banks, strategy="mxu")
    p_lookup = build_plan(banks, strategy="lookup")
    for be in ("kernel", "kernel_q8"):
        np.testing.assert_allclose(
            np.asarray(p_mxu(x, backend=be)),
            np.asarray(p_lookup(x, backend=be)),
            rtol=1e-4, atol=1e-4, err_msg=f"strategy parity broke for {be}")


def test_bucket_batch_policy():
    from repro.engine import DEFAULT_BUCKETS, bucket_batch

    assert bucket_batch(1) == DEFAULT_BUCKETS[0]
    assert bucket_batch(8) == 8
    assert bucket_batch(9) == 16
    assert bucket_batch(1024) == 1024
    top = DEFAULT_BUCKETS[-1]
    assert bucket_batch(top + 1) == 2 * top       # beyond the ladder:
    assert bucket_batch(2 * top) == 2 * top       # multiples of the largest
    with pytest.raises(ValueError):
        bucket_batch(0)
