"""Tests for the Tofino-2 MAT emulator: CRC, integer pipeline, resources."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import init_pegasus_linear
from repro.core.amm import apply_gather
from repro.core.quantization import choose_qspec
from repro.dataplane.compile import compile_model, place_physical
from repro.dataplane.crc import leaf_tcam_rules, range_to_ternary, tree_leaf_boxes
from repro.dataplane.resources import TOFINO2

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def test_range_to_ternary_exact_cover():
    rules = range_to_ternary(3, 12, 4)
    for x in range(16):
        matched = sum(r.matches(x) for r in rules)
        assert matched == (1 if 3 <= x <= 12 else 0)


def test_range_to_ternary_full_and_single():
    assert len(range_to_ternary(0, 255, 8)) == 1       # one wildcard rule
    assert len(range_to_ternary(77, 77, 8)) == 8 or len(range_to_ternary(77, 77, 8)) == 1
    # single value needs exactly one exact rule
    rules = range_to_ternary(77, 77, 8)
    assert len(rules) == 1 and rules[0].mask == 255


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(data=st.data(), bits=st.sampled_from([4, 8]))
    def test_property_crc_partition(data, bits):
        """CRC rules cover [lo,hi] exactly once and nothing else."""
        hi = data.draw(st.integers(0, 2**bits - 1))
        lo = data.draw(st.integers(0, hi))
        rules = range_to_ternary(lo, hi, bits)
        for x in range(2**bits):
            assert sum(r.matches(x) for r in rules) == (1 if lo <= x <= hi else 0)


def _two_layer(rng, depth=4):
    d, h, o, s = 8, 8, 4, 4096
    X = rng.integers(0, 256, size=(s, d)).astype(np.float32)
    w1 = rng.normal(size=(d, h)).astype(np.float32) * 0.05
    b1 = rng.normal(size=(h,)).astype(np.float32)
    w2 = rng.normal(size=(h, o)).astype(np.float32) * 0.3
    l1 = init_pegasus_linear(w1, b1, X, group_size=2, depth=depth, lut_bits=None)
    h_pre = np.asarray(apply_gather(l1, jnp.asarray(X)))
    l2 = init_pegasus_linear(
        w2, None, h_pre, group_size=2, depth=depth, lut_bits=None,
        act_fn=lambda c: jnp.maximum(c, 0),
    )
    y = np.asarray(apply_gather(l2, jnp.asarray(h_pre)))
    return X, [l1, l2], y


def test_integer_pipeline_matches_float_model():
    rng = np.random.default_rng(0)
    X, layers, y_float = _two_layer(rng)
    pipe = compile_model(layers, stateful_bits_per_flow=80)
    out = pipe.run_batch(X[:128])
    spec = choose_qspec(np.asarray(layers[-1].lut), bits=16)
    y_int = out / spec.scale
    # fixed-point error only: bounded by a few quanta of each layer
    assert np.abs(y_int - y_float[:128]).max() < 0.05 * np.abs(y_float).max()


def test_tree_leaf_boxes_partition_input_space():
    """Leaf boxes tile the quantized input space (disjoint + complete)."""
    rng = np.random.default_rng(3)
    X = rng.integers(0, 16, size=(512, 2)).astype(np.float32)
    from repro.core import fit_tree

    tree = fit_tree(X, depth=3)
    boxes = tree_leaf_boxes(
        np.asarray(tree.features), np.asarray(tree.thresholds), 3, 2, bits=4
    )
    count = np.zeros((16, 16), dtype=int)
    for box in boxes:
        (l0, h0), (l1, h1) = box
        if l0 > h0 or l1 > h1:
            continue
        count[l0 : h0 + 1, l1 : h1 + 1] += 1
    np.testing.assert_array_equal(count, 1)


def test_resource_report_within_budget_and_stages():
    rng = np.random.default_rng(4)
    X, layers, _ = _two_layer(rng)
    pipe = compile_model(layers, stateful_bits_per_flow=80)
    rep = pipe.report()
    assert rep.validate() == []
    assert rep.stages_used >= 2  # at least one physical stage per layer
    assert 0 < rep.sram_pct < 100 and 0 <= rep.tcam_pct < 100


def test_place_physical_splits_oversized_logical_stage():
    """A logical stage whose tables exceed one stage's bus must split."""
    rng = np.random.default_rng(5)
    d, n, s = 32, 64, 2048  # 16 tables × 64×16b rows = wide bus demand
    X = rng.integers(0, 256, size=(s, d)).astype(np.float32)
    w = rng.normal(size=(d, n)).astype(np.float32) * 0.05
    layer = init_pegasus_linear(w, None, X, group_size=2, depth=4, lut_bits=None)
    pipe = compile_model([layer])
    assert place_physical(pipe) > 1
