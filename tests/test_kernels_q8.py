"""int8-LUT kernel validation: quantization properties + kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fuzzy_tree import fit_tree, stack_trees
from repro.kernels.fuzzy_lut.ops import prepare_feat_onehot
from repro.kernels.fuzzy_lut.quantized import (
    fuzzy_lut_q8_pallas, fuzzy_lut_q8_ref, quantize_lut_int8,
)
from repro.kernels.fuzzy_lut.ref import fuzzy_lut_matmul_ref


def _problem(rng, t, k, v, depth, n):
    data = rng.normal(size=(max(4 * 2**depth, 64), k * v)).astype(np.float32)
    trees = stack_trees(
        [fit_tree(data[:, g * v : (g + 1) * v], depth) for g in range(k)])
    lut = jnp.asarray(rng.normal(size=(k, 2**depth, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(t, k, v)).astype(np.float32))
    return x, trees, lut


def test_quantize_lut_int8_roundtrip():
    rng = np.random.default_rng(0)
    lut = jnp.asarray(rng.normal(size=(4, 16, 8)).astype(np.float32))
    q, s = quantize_lut_int8(lut)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * s[:, None, None]
    rel = float(jnp.linalg.norm(deq - lut) / jnp.linalg.norm(lut))
    assert rel < 0.01  # int8 symmetric: ~0.4% rms for gaussian


@pytest.mark.parametrize("t,k,v,depth,n,blocks", [
    (16, 4, 4, 3, 8, (16, 8, 2)),
    (64, 8, 2, 4, 16, (32, 16, 4)),
])
def test_q8_kernel_matches_ref(t, k, v, depth, n, blocks):
    rng = np.random.default_rng(t + k)
    x, trees, lut = _problem(rng, t, k, v, depth, n)
    q, s = quantize_lut_int8(lut)
    feat_oh = prepare_feat_onehot(trees.features, v)
    bt, bn, bk = blocks
    got = fuzzy_lut_q8_pallas(x, feat_oh, trees.thresholds, q, s,
                              depth=depth, block_t=bt, block_n=bn, block_k=bk)
    want = fuzzy_lut_q8_ref(x, trees.features, trees.thresholds, q, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_q8_close_to_fp32_path():
    """End-to-end: int8 LUT result within quantization error of fp32 LUT."""
    rng = np.random.default_rng(5)
    x, trees, lut = _problem(rng, 32, 8, 4, 4, 16)
    q, s = quantize_lut_int8(lut)
    feat_oh = prepare_feat_onehot(trees.features, 4)
    got = fuzzy_lut_q8_pallas(x, feat_oh, trees.thresholds, q, s,
                              depth=4, block_t=32, block_n=16, block_k=8)
    want = fuzzy_lut_matmul_ref(x, trees.features, trees.thresholds, lut)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.01, rel
