"""int8-LUT kernel validation: quantization properties + kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fuzzy_tree import fit_tree, stack_trees
from repro.kernels.fuzzy_lut.ops import prepare_feat_onehot
from repro.kernels.fuzzy_lut.quantized import (
    fuzzy_lut_q8_pallas, fuzzy_lut_q8_ref, quantize_lut_int8,
)
from repro.kernels.fuzzy_lut.ref import fuzzy_lut_matmul_ref


def _problem(rng, t, k, v, depth, n):
    data = rng.normal(size=(max(4 * 2**depth, 64), k * v)).astype(np.float32)
    trees = stack_trees(
        [fit_tree(data[:, g * v : (g + 1) * v], depth) for g in range(k)])
    lut = jnp.asarray(rng.normal(size=(k, 2**depth, n)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(t, k, v)).astype(np.float32))
    return x, trees, lut


def test_quantize_lut_int8_roundtrip():
    rng = np.random.default_rng(0)
    lut = jnp.asarray(rng.normal(size=(4, 16, 8)).astype(np.float32))
    q, s = quantize_lut_int8(lut)
    assert q.dtype == jnp.int8
    deq = q.astype(jnp.float32) * s[:, None, None]
    rel = float(jnp.linalg.norm(deq - lut) / jnp.linalg.norm(lut))
    assert rel < 0.01  # int8 symmetric: ~0.4% rms for gaussian


@pytest.mark.parametrize("t,k,v,depth,n,blocks", [
    (16, 4, 4, 3, 8, (16, 8, 2)),
    (64, 8, 2, 4, 16, (32, 16, 4)),
])
def test_q8_kernel_matches_ref(t, k, v, depth, n, blocks):
    rng = np.random.default_rng(t + k)
    x, trees, lut = _problem(rng, t, k, v, depth, n)
    q, s = quantize_lut_int8(lut)
    feat_oh = prepare_feat_onehot(trees.features, v)
    bt, bn, bk = blocks
    got = fuzzy_lut_q8_pallas(x, feat_oh, trees.thresholds, q, s,
                              depth=depth, block_t=bt, block_n=bn, block_k=bk)
    want = fuzzy_lut_q8_ref(x, trees.features, trees.thresholds, q, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("strategy", ["lookup", "mxu"])
def test_q8_stack_kernel_matches_chained_q8(strategy):
    """Stacked q8 kernel (dequant folded into the in-VMEM table) ≡ chaining
    the single-bank q8 kernel with the same int8 tables and scales."""
    from repro.kernels.fuzzy_lut.quantized import fuzzy_lut_stack_q8_pallas

    rng = np.random.default_rng(11)
    ks, v, depth, n_out, t = (4, 4, 4), 2, 3, 4, 16
    c = 2 ** depth
    i = c - 1
    l, kmax, nmax = len(ks), max(ks), 8
    feat_oh = np.zeros((l, kmax, i, v), np.float32)
    thr = np.full((l, kmax, i), np.inf, np.float32)
    lut_q8 = np.zeros((l, kmax, c, nmax), np.int8)
    scales = np.zeros((l, kmax), np.float32)
    bias = np.zeros((l, nmax), np.float32)
    for layer in range(l):
        k = ks[layer]
        feats = rng.integers(0, v, size=(k, i))
        feat_oh[layer, :k] = np.eye(v, dtype=np.float32)[feats]
        thr[layer, :k] = rng.normal(size=(k, i)).astype(np.float32)
        n = n_out if layer == l - 1 else ks[layer + 1] * v
        fp = rng.normal(size=(k, c, n)).astype(np.float32) * 0.3
        q, s = quantize_lut_int8(jnp.asarray(fp))
        lut_q8[layer, :k, :, :n] = np.asarray(q)
        scales[layer, :k] = np.asarray(s)
        bias[layer, :n] = rng.normal(size=n).astype(np.float32) * 0.1
    x = jnp.asarray(rng.normal(size=(t, ks[0], v)).astype(np.float32))
    args = tuple(map(jnp.asarray, (feat_oh, thr, lut_q8, scales, bias)))

    got = fuzzy_lut_stack_q8_pallas(x, *args, depth=depth, ks=ks,
                                    n_out=n_out, strategy=strategy)
    h = x
    for layer, k in enumerate(ks):
        n = n_out if layer == l - 1 else ks[layer + 1] * v
        y = fuzzy_lut_q8_pallas(
            h[:, :k], args[0][layer, :k], args[1][layer, :k],
            args[2][layer, :k, :, :n], args[3][layer, :k],
            depth=depth, block_t=t, block_n=n, block_k=k, strategy=strategy)
        y = y + args[4][layer, :n]
        if layer + 1 < l:
            h = y.reshape(t, ks[layer + 1], v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y),
                               rtol=1e-4, atol=1e-5)


def test_q8_close_to_fp32_path():
    """End-to-end: int8 LUT result within quantization error of fp32 LUT."""
    rng = np.random.default_rng(5)
    x, trees, lut = _problem(rng, 32, 8, 4, 4, 16)
    q, s = quantize_lut_int8(lut)
    feat_oh = prepare_feat_onehot(trees.features, 4)
    got = fuzzy_lut_q8_pallas(x, feat_oh, trees.thresholds, q, s,
                              depth=4, block_t=32, block_n=16, block_k=8)
    want = fuzzy_lut_matmul_ref(x, trees.features, trees.thresholds, lut)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.01, rel
