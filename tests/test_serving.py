"""Async serving runtime tests (ISSUE 5): WFQ scheduler invariants
(deficit round-robin flow shares, priority ordering, progress), bounded-
queue backpressure (reject vs block), thread-safe ingestion under
concurrent submit/add_model/drain, the future-returning async server, and
the PartialDrainError regression (no mutation of slotted exceptions).
Plus the deadline/SLO layer (ISSUE 6): slack-based shedding, admission
control, goodput counters, and the asyncio frontend.

Everything here runs tiny gather-backend plans — fast-lane material.
"""

import asyncio
import threading
import time
from concurrent.futures import Future

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.amm import init_pegasus_linear
from repro.launch.scheduler import (
    PRIORITY_WEIGHTS, DeadlineExceededError, QueueFullError, WFQScheduler,
)
from repro.launch.serve import (
    AsyncMultiModelServer, InferRequest, InferResult, MultiModelServer,
    PartialDrainError,
)


def _banks(seed: int = 0, n_out: int = 5) -> list:
    rng = np.random.default_rng(seed)
    return [init_pegasus_linear(
        rng.normal(size=(8, n_out)).astype(np.float32), None,
        rng.normal(size=(64, 8)).astype(np.float32), group_size=2, depth=3,
        lut_bits=None)]


@pytest.fixture(scope="module")
def x():
    return jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)),
                       jnp.float32)


# ---------------------------------------------------------------------------
# WFQScheduler unit tests: pure queue/credit mechanics, no plans involved
# ---------------------------------------------------------------------------


def test_drr_flow_share_matches_weights():
    """Under sustained backlog, served flows converge to the weight ratio —
    the WFQ acceptance invariant, measured over a long pull log."""
    s = WFQScheduler()
    s.add_queue("hi", weight=4.0)
    s.add_queue("lo", weight=1.0)
    for _ in range(400):
        s.submit("hi", (), 32)
        s.submit("lo", (), 32)
    served = {"hi": 0, "lo": 0}
    while s.pending().get("hi") and s.pending().get("lo"):  # both backlogged
        for name, reqs in s.pull_round(64):
            served[name] += sum(r.size for r in reqs)
    ratio = served["hi"] / max(served["lo"], 1)
    assert 3.0 <= ratio <= 5.0, served          # 4:1 within tolerance


def test_drr_high_weight_dispatches_first_each_round():
    s = WFQScheduler()
    s.add_queue("lo", weight=1.0)               # inserted FIRST
    s.add_queue("hi", weight=4.0)
    s.submit("lo", (), 8)
    s.submit("hi", (), 8)
    order = [name for name, _ in s.pull_round(8)]
    assert order == ["hi", "lo"]                # descending weight wins


def test_drr_equal_weights_degenerate_to_round_robin():
    """With uniform weights and quantum = one micro-batch, each round
    releases one request per model in insertion order (the PR-3 behavior
    the fair-scheduling test pins at the server level)."""
    s = WFQScheduler()
    for name in ("a", "b", "c"):
        s.add_queue(name)
        for _ in range(2):
            s.submit(name, (), 8)
    log = []
    while s.pending():
        log += [name for name, _ in s.pull_round(8)]
    assert log == ["a", "b", "c", "a", "b", "c"]


def test_drr_oversize_request_eventually_dispatches():
    """A request bigger than one quantum must not stall: credit accumulates
    across internal catch-up rounds until the head fits."""
    s = WFQScheduler()
    s.add_queue("a", weight=1.0)
    s.submit("a", (), 1000)                     # >> quantum
    out = s.pull_round(64)
    assert len(out) == 1
    assert out[0][0] == "a" and out[0][1][0].size == 1000
    assert not s.pending()


def test_drr_idle_queue_forfeits_credit():
    """Classic DRR: an emptied queue resets its deficit — idle models never
    bank bandwidth to burst past their weight later."""
    s = WFQScheduler()
    s.add_queue("a")
    s.submit("a", (), 4)
    s.pull_round(64)                            # served; queue now empty
    assert s._deficit["a"] == 0.0


def test_priority_classes_map_to_weights():
    s = WFQScheduler()
    assert s.add_queue("h", priority="high").weight == PRIORITY_WEIGHTS["high"]
    assert s.add_queue("n").weight == PRIORITY_WEIGHTS["normal"]
    assert s.add_queue("l", priority="low").weight == PRIORITY_WEIGHTS["low"]
    assert s.add_queue("w", weight=2.5).weight == 2.5
    with pytest.raises(ValueError, match="unknown priority"):
        s.add_queue("bad", priority="urgent")
    assert s.set_weight("l", priority="high") == PRIORITY_WEIGHTS["high"]
    # set_weight validates too — no bare calls, no unknown classes
    with pytest.raises(ValueError, match="unknown priority"):
        s.set_weight("l", priority="urgent")
    with pytest.raises(ValueError, match="weight= or priority="):
        s.set_weight("l")
    # re-adding an existing queue with an EXPLICIT class re-weights it;
    # without one, the existing weight is kept
    assert s.add_queue("n", priority="high").weight == PRIORITY_WEIGHTS["high"]
    assert s.add_queue("n").weight == PRIORITY_WEIGHTS["high"]
    # depth/policy of a live queue change only via configure
    s.add_queue("b", depth=4, policy="reject")
    s.configure("b", depth=1, policy="block")
    q = s.add_queue("b")
    assert (q.depth, q.policy) == (1, "block")


def test_reregister_model_updates_priority(x):
    """add_model over an existing name must honor the new scheduling class
    (the queue already exists — its weight must not silently stay stale)."""
    server = MultiModelServer({"m": _banks()}, backend="gather")
    assert server.stats()["scheduler"]["models"]["m"]["weight"] == 1.0
    server.add_model("m", _banks(9), priority="high", queue_depth=7)
    st = server.stats()["scheduler"]["models"]["m"]
    assert st["weight"] == PRIORITY_WEIGHTS["high"]
    assert st["depth"] == 7


def test_backpressure_reject_policy():
    s = WFQScheduler()
    s.add_queue("a", depth=2, policy="reject")
    s.submit("a", (), 1)
    s.submit("a", (), 1)
    with pytest.raises(QueueFullError, match="policy=reject"):
        s.submit("a", (), 1)
    s.pull_round(8)                             # frees the queue
    s.submit("a", (), 1)                        # accepted again


def test_backpressure_block_times_out_then_releases():
    s = WFQScheduler()
    s.add_queue("a", depth=1, policy="block")
    s.submit("a", (), 1)
    t0 = time.perf_counter()
    with pytest.raises(QueueFullError, match="after blocking"):
        s.submit("a", (), 1, timeout=0.05)
    assert time.perf_counter() - t0 >= 0.04     # actually blocked
    # a dispatcher pulling frees space → the parked submitter completes
    done = []

    def parked():
        s.submit("a", (), 1, timeout=5.0)
        done.append(1)

    t = threading.Thread(target=parked)
    t.start()
    time.sleep(0.02)
    assert s.pull_round(8)
    t.join(5.0)
    assert done == [1]
    assert s.pending() == {"a": 1}


def test_backpressure_blocked_submitter_freed_by_unbounding():
    """configure(depth=None) while a submitter is parked on a full queue
    must wake it cleanly (the re-check must tolerate the lifted bound)."""
    s = WFQScheduler()
    s.add_queue("a", depth=1, policy="block")
    s.submit("a", (), 1)
    done = []

    def parked():
        s.submit("a", (), 1, timeout=5.0)
        done.append(1)

    t = threading.Thread(target=parked)
    t.start()
    time.sleep(0.02)
    s.configure("a", depth=None)                # lift the bound
    t.join(5.0)
    assert done == [1]
    assert s.pending() == {"a": 2}


def test_latency_reservoir_percentiles():
    s = WFQScheduler()
    s.add_queue("a")
    for _ in range(10):
        s.submit("a", (), 4)
    for name, reqs in s.pull_round(1000):
        s.record_service(name, reqs, 7.5)
    st = s.latency_stats()["a"]
    assert st["samples"] == 10
    assert st["service_ms"]["p50"] == 7.5
    assert st["queue_wait_ms"]["p50"] >= 0.0
    s.reset_latency()
    assert s.latency_stats() == {}


# ---------------------------------------------------------------------------
# MultiModelServer: thread-safe ingestion + PartialDrainError
# ---------------------------------------------------------------------------


class _SlottedError(Exception):
    """Immutable exception (slotted-type stand-in): attribute assignment
    fails — the old ``err.partial_results = ...`` decoration crashed here."""

    __slots__ = ()

    def __setattr__(self, key, value):
        raise AttributeError(f"immutable exception: cannot set {key!r}")


def test_serve_wraps_failures_in_partial_drain_error(x):
    banks = _banks()
    server = MultiModelServer({"good": banks, "bad": banks}, backend="gather")
    boom = _SlottedError("kernel rejected the batch")
    real_get = server.registry.get
    server.registry.get = (
        lambda name: (_ for _ in ()).throw(boom) if name == "bad"
        else real_get(name))
    with pytest.raises(PartialDrainError) as ei:
        server.serve([("good", x[:4]), ("bad", x[:4])])
    err = ei.value
    assert err.partial_results["good"][0].shape[0] == 4   # served work kept
    assert err.failed["bad"] is boom
    assert err.__cause__ is boom                # wrapped, chained...
    assert not hasattr(boom, "partial_results")  # ...and NOT mutated
    # the good model's work was counted; bad's queue is intact for retry
    st = server.stats()["serving"]["models"]
    assert st["good"]["requests_served"] == 1
    assert st["bad"]["requests_served"] == 0
    assert server.pending() == {"bad": 1}


def test_serve_partial_slice_failure_still_raises_partial_drain_error(x):
    """A model whose FIRST slice serves but whose second fails must still
    surface as failed: its partial output list in by_model must not count
    as success (the pre-fix path fell through to an IndexError instead of
    PartialDrainError)."""
    server = MultiModelServer({"m": _banks()}, backend="gather")
    server.quantum = 8                          # one 8-flow request per round
    calls = {"n": 0}
    real_get = server.registry.get

    def flaky_get(name):
        calls["n"] += 1
        if calls["n"] >= 2:                     # slice 1 fine, slice 2 dies
            raise RuntimeError("device fell over")
        return real_get(name)

    server.registry.get = flaky_get
    with pytest.raises(PartialDrainError) as ei:
        server.serve([("m", x[:8]), ("m", x[8:16])])
    err = ei.value
    assert isinstance(err.failed["m"], RuntimeError)
    assert len(err.partial_results.get("m", [])) == 1   # served prefix kept
    # the failed slice was requeued for retry
    assert server.pending() == {"m": 1}


def test_concurrent_submit_and_add_model_during_drain(x):
    """Satellite regression: submits and add_model racing a drain must
    neither crash (the old ``self._queues.items()`` iteration raised
    ``RuntimeError: dictionary changed size during iteration``) nor lose
    requests (the old commit ``clear()``-ed whole queues, wiping anything
    submitted mid-drain). Deterministic check: every submitted flow comes
    back exactly once."""
    server = MultiModelServer({"m0": _banks()}, backend="gather")
    server.submit("m0", x[:8])
    server.drain()                              # warm the plan
    n_threads, n_reqs = 4, 40
    sizes = [1 + (i % 8) for i in range(n_reqs)]
    expected = n_threads * sum(sizes)
    errors: list = []

    def submitter():
        try:
            for sz in sizes:
                server.submit("m0", x[:sz])
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    def modeler():
        try:
            for i in range(6):
                server.add_model(f"extra-{i}", _banks(seed=100 + i))
                time.sleep(0.001)
        except Exception as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=submitter) for _ in range(n_threads)]
    threads.append(threading.Thread(target=modeler))
    for t in threads:
        t.start()
    collected = 0
    deadline = time.monotonic() + 120
    while ((any(t.is_alive() for t in threads) or server.pending())
           and time.monotonic() < deadline):
        for outs in server.drain().values():
            collected += sum(o.shape[0] for o in outs)
    for t in threads:
        t.join(5.0)
    assert errors == []
    assert collected == expected                # nothing lost, nothing doubled
    assert server.pending() == {}
    assert (server.stats()["serving"]["models"]["m0"]["flows_served"]
            == expected + 8)


def test_sync_server_weighted_drain_order(x):
    """Server-level WFQ: a 4:1 weight skew yields a ~4:1 micro-batch share
    in schedule_log while both models stay backlogged."""
    server = MultiModelServer(backend="gather", max_batch=8)
    server.add_model("hi", _banks(0), weight=4.0)
    server.add_model("lo", _banks(7), weight=1.0)
    for _ in range(20):
        server.submit("hi", x[:8])
        server.submit("lo", x[:8])
    server.drain()
    log = list(server.schedule_log)
    # first 5 rounds: hi releases 4 chunks per round to lo's 1
    head = log[:25]
    assert head.count("hi") >= 3 * head.count("lo"), head
    # everything drains in the end regardless of weight
    assert log.count("hi") == log.count("lo") == 20


# ---------------------------------------------------------------------------
# AsyncMultiModelServer: background loop, futures, backpressure, priorities
# ---------------------------------------------------------------------------


def test_async_futures_match_sync_outputs(x):
    banks = _banks()
    sync = MultiModelServer({"m": banks}, backend="gather")
    ref = np.concatenate([np.asarray(sync.infer("m", x[i : i + 4]))
                          for i in range(0, 16, 4)])
    server = AsyncMultiModelServer({"m": banks}, backend="gather")
    with server:
        futs = [server.submit("m", x[i : i + 4]) for i in range(0, 16, 4)]
        outs = [f.result(timeout=60) for f in futs]
    np.testing.assert_allclose(np.concatenate(outs), ref, rtol=1e-6, atol=1e-6)
    st = server.stats()
    assert st["serving"]["models"]["m"]["requests_served"] == 4
    assert st["serving"]["models"]["m"]["flows_served"] == 16
    lat = st["scheduler"]["latency"]["m"]
    assert lat["samples"] == 4
    assert lat["queue_wait_ms"]["p50"] >= 0.0
    assert not server.running                   # __exit__ stopped the loop


def test_async_failure_lands_on_future_not_queue(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    with server:
        bad = server.submit("m", x[:4, :4])     # wrong feature width
        with pytest.raises(Exception):
            bad.result(timeout=60)
        # the loop is still alive and the queue clean: later requests serve
        good = server.submit("m", x[:4])
        assert good.result(timeout=60).shape[0] == 4
    assert server.pending() == {}               # failed request NOT requeued
    st = server.stats()["serving"]["models"]["m"]
    assert st["requests_served"] == 1           # success-only counting
    assert "m" in server.last_drain_errors


def test_async_stop_drains_pending(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    server.start()
    futs = [server.submit("m", x[: 1 + (i % 8)]) for i in range(64)]
    server.stop()                               # drain=True default
    assert all(f.done() for f in futs)
    assert sum(f.result().shape[0] for f in futs) == sum(
        1 + (i % 8) for i in range(64))


def test_async_backpressure_reject_before_loop_starts(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather",
                                   queue_depth=2, policy="reject")
    f1, f2 = server.submit("m", x[:2]), server.submit("m", x[:2])
    with pytest.raises(QueueFullError):
        server.submit("m", x[:2])
    with server:                                # loop drains the queue
        assert f1.result(timeout=60).shape[0] == 2
        assert f2.result(timeout=60).shape[0] == 2
        f3 = server.submit("m", x[:2])          # space again
        assert f3.result(timeout=60).shape[0] == 2


def test_async_backpressure_block_bounds_producer(x):
    """policy=block parks the submitting thread until the loop frees space
    — every request still completes exactly once."""
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather",
                                   queue_depth=2, policy="block")
    with server:
        futs = [server.submit("m", x[:3], timeout=60) for _ in range(12)]
        outs = [f.result(timeout=60) for f in futs]
    assert len(outs) == 12 and all(o.shape[0] == 3 for o in outs)


def test_async_priority_queue_wait_under_saturation(x):
    """Acceptance: under a saturated backlog, a 4:1 WFQ weight skew gives
    the high-priority model a strictly lower p50 queue-wait."""
    banks = _banks()
    server = AsyncMultiModelServer(backend="gather", queue_depth=None,
                                   max_batch=32)
    server.add_model("hi", banks, weight=4.0)
    server.add_model("lo", banks, weight=1.0)
    # saturate BEFORE the loop starts: every request is already queued when
    # scheduling begins, so waits are set purely by the WFQ dispatch order
    futs = []
    for _ in range(40):
        futs.append(server.submit("hi", x))
        futs.append(server.submit("lo", x))
    with server:
        for f in futs:
            f.result(timeout=120)
    lat = {n: server.stats()["scheduler"]["latency"][n]["queue_wait_ms"]
           for n in ("hi", "lo")}
    assert lat["hi"]["p50"] < lat["lo"]["p50"], lat
    # and the flow share matches the skew while both were backlogged
    log = list(server.schedule_log)
    head = log[: len(log) // 2]
    assert head.count("hi") >= 2 * head.count("lo"), head[:20]


def test_async_serve_requires_running_loop(x):
    """serve() without a started loop must raise, not hang on futures that
    nothing will ever resolve."""
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    with pytest.raises(RuntimeError, match="not running"):
        server.serve([("m", x[:4])])
    with server:
        assert server.serve([("m", x[:4])])[0].shape[0] == 4
    with pytest.raises(RuntimeError, match="not running"):   # after stop()
        server.serve([("m", x[:4])])


def test_remove_model_fails_pending_futures(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    fut = server.submit("m", x[:4])             # loop not started: stays queued
    assert server.remove_model("m")
    with pytest.raises(KeyError, match="removed"):
        fut.result(timeout=5)
    with pytest.raises(KeyError, match="unknown model"):
        server.submit("m", x[:4])


def test_discard_pending_cancels_futures(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    fut = server.submit("m", x[:4])
    assert server.discard_pending("m") == 1
    assert fut.cancelled()
    assert server.pending() == {}


# ---------------------------------------------------------------------------
# Deadline/SLO layer (ISSUE 6): shedding, admission control, goodput
# ---------------------------------------------------------------------------


def test_shed_fails_future_and_never_dispatches():
    """The acceptance triple: an expired deadline-bearing request is shed
    (typed error on its future, never pulled), while a no-deadline request
    on the SAME queue dispatches untouched."""
    s = WFQScheduler()
    s.add_queue("m")
    doomed, fine = Future(), Future()
    s.submit("m", ("doomed",), 4, future=doomed, deadline_ms=1e-6)
    s.submit("m", ("fine",), 4, future=fine)
    time.sleep(0.005)                           # burn the 1 ns budget
    pulled = s.pull_round(64)
    assert [r.inputs for _, reqs in pulled for r in reqs] == [("fine",)]
    assert isinstance(doomed.exception(timeout=0), DeadlineExceededError)
    assert not fine.done()                      # dispatched, not failed
    shed = s.take_shed()
    assert [r.inputs for r in shed["m"]] == [("doomed",)]
    assert s.take_shed() == {}                  # take = drain-once
    c = s.counters()["m"]
    assert (c["admitted"], c["shed"], c["shed_flows"]) == (2, 1, 4)
    assert c["max_wait_ms"] > 0.0
    assert s.pending() == {}                    # shed frees backlog too


def test_deadline_validation():
    s = WFQScheduler()
    s.add_queue("m")
    with pytest.raises(ValueError, match="deadline_ms"):
        s.submit("m", (), 1, deadline_ms=0.0)
    with pytest.raises(ValueError, match="admit_ms"):
        s.add_queue("n", admit_ms=-1.0)


def test_shed_slack_uses_service_estimate():
    """Shedding is SLACK-based: a deadline that raw queue-wait has not yet
    burned is still shed when the EWMA service time would blow it anyway
    (dispatching work guaranteed to finish late is wasted capacity) — BUT
    the estimate's claim is capped at half the budget, so a fresh request
    always gets deadline/2 of queue time first (an inflated estimate must
    not shed everything forever: only served slices can correct it)."""
    s = WFQScheduler()
    s.add_queue("m")
    s.submit("m", (), 8)
    for name, reqs in s.pull_round(64):
        s.record_service(name, reqs, 50.0)      # svc estimate := 50 ms
    fresh = Future()
    s.submit("m", (), 8, future=fresh, deadline_ms=40.0)  # < svc estimate
    # self-healing guarantee: not shed instantly despite estimate > budget
    assert len(s.pull_round(64)) == 1
    assert not fresh.done()
    # past the half-budget (40/2 = 20 ms of wait), the estimate sheds it
    fut = Future()
    s.submit("m", (), 8, future=fut, deadline_ms=40.0)
    time.sleep(0.025)                           # 25 ms > 40 - min(50, 20)
    assert s.pull_round(64) == []               # shed, not dispatched
    assert isinstance(fut.exception(timeout=0), DeadlineExceededError)


def test_admission_control_refuses_doomed_and_over_horizon():
    """Once a service rate is observed, a submit whose predicted queue-wait
    already exceeds its deadline is refused up front (typed error), and an
    admit_ms horizon rejects ANY submit past it (QueueFullError). Without
    rate data admission stays inactive — nothing is refused blind."""
    s = WFQScheduler()
    s.add_queue("m")
    s.submit("m", (), 100, deadline_ms=1.0)     # no rate yet: admitted
    for name, reqs in s.pull_round(1000):
        s.record_service(name, reqs, 100.0)     # rate := 1000 flows/s
    for _ in range(5):
        s.submit("m", (), 10)                   # 50-flow backlog ≈ 50 ms
    with pytest.raises(DeadlineExceededError, match="admission"):
        s.submit("m", (), 1, deadline_ms=10.0)
    s.submit("m", (), 1, deadline_ms=200.0)     # enough slack: admitted
    s.configure("m", admit_ms=20.0)
    with pytest.raises(QueueFullError, match="admit_ms"):
        s.submit("m", (), 1)                    # horizon caps ALL submits
    c = s.counters()["m"]
    assert c["rejected"] == 2
    assert c["service_rate_flows_s"] == pytest.approx(1000.0)
    assert c["head_wait_ms"] >= 0.0


def test_goodput_counters_split_on_deadline():
    s = WFQScheduler()
    s.add_queue("m")
    s.submit("m", (), 4, deadline_ms=60_000.0)  # will finish well inside
    s.submit("m", (), 4)                        # no deadline: neither bucket
    for name, reqs in s.pull_round(64):
        s.record_service(name, reqs, 1.0)
    c = s.counters()["m"]
    assert c["served_flows"] == 8
    assert c["goodput_flows"] == 4
    assert c["late_flows"] == 0
    s.reset_counters()
    c = s.counters()["m"]
    assert c["served_flows"] == 0
    assert c["service_ms_ewma"] == pytest.approx(1.0)    # estimate survives


def test_sync_serve_reports_sheds_via_partial_drain_error(x):
    """Satellite acceptance: sync serve() surfaces sheds through
    PartialDrainError WITHOUT losing the other results, and the queue is
    clean afterwards (sheds never poison later drains)."""
    server = MultiModelServer({"m": _banks()}, backend="gather")
    server.serve([("m", x[:4])])                # warm the plan
    with pytest.raises(PartialDrainError) as ei:
        server.serve([("m", x[:4], 1e-6), ("m", x[4:8])])
    err = ei.value
    assert err.failed == {}                     # nothing FAILED — one shed
    assert [len(v) for v in err.shed.values()] == [1]
    assert isinstance(err.shed["m"][0], DeadlineExceededError)
    assert len(err.partial_results["m"]) == 1   # the other request served
    assert err.partial_results["m"][0].shape[0] == 4
    assert server.last_shed == {"m": 1}
    assert server.pending() == {}
    # deadline-free serving is untouched afterwards
    assert len(server.serve([("m", x[:4]), ("m", x[4:8])])) == 2
    slo = server.slo_counters()["m"]
    assert slo["shed"] == 1 and slo["goodput_flows"] == 0


def test_sync_drain_records_sheds_without_futures(x):
    server = MultiModelServer({"m": _banks()}, backend="gather")
    server.submit("m", x[:4], deadline_ms=1e-6)
    server.submit("m", x[4:8])
    time.sleep(0.005)
    out = server.drain()
    assert len(out["m"]) == 1                   # only the live request
    assert server.last_shed == {"m": 1}


def test_async_deadline_shed_fails_future(x):
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    doomed = server.submit("m", x[:4], deadline_ms=1e-6)  # queued pre-start
    fine = server.submit("m", x[4:8])
    time.sleep(0.005)
    with server:
        assert fine.result(timeout=60).shape[0] == 4
        with pytest.raises(DeadlineExceededError):
            doomed.result(timeout=60)
    st = server.stats()
    assert st["slo"]["models"]["m"]["shed"] == 1
    assert st["serving"]["models"]["m"]["requests_served"] == 1


def test_infer_async_roundtrip_and_shed(x):
    banks = _banks()
    ref = np.asarray(MultiModelServer({"m": banks},
                                      backend="gather").infer("m", x[:4]))

    async def scenario():
        with AsyncMultiModelServer({"m": banks}, backend="gather") as server:
            out = await server.infer_async("m", x[:4], deadline_ms=60_000.0)
            np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)
            with pytest.raises(DeadlineExceededError):
                await server.infer_async("m", x[:4], deadline_ms=1e-6)
        with pytest.raises(RuntimeError, match="not running"):
            await server.infer_async("m", x[:4])

    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# Typed request API (ISSUE 7): InferRequest/InferResult routing + the
# deprecated legacy shims must stay exactly equivalent
# ---------------------------------------------------------------------------

def test_infer_request_normalizes_and_validates(x):
    req = InferRequest("m", x[:4])
    assert isinstance(req.inputs, tuple) and len(req.inputs) == 1
    assert req.flows == 4
    assert InferRequest("m", (x[:4], x[:4])).flows == 4
    with pytest.raises(ValueError, match="priority"):
        InferRequest("m", x[:4], priority="urgent")
    with pytest.raises(ValueError, match="deadline_ms"):
        InferRequest("m", x[:4], deadline_ms=0.0)


def test_typed_and_legacy_infer_parity(x):
    server = MultiModelServer({"m": _banks()}, backend="gather")
    res = server.infer(InferRequest("m", x[:4]))
    assert isinstance(res, InferResult)
    assert res.model == "m" and res.flows == 4
    with pytest.warns(DeprecationWarning):
        legacy = server.infer("m", x[:4])
    np.testing.assert_array_equal(np.asarray(res.output), np.asarray(legacy))


def test_typed_and_legacy_submit_drain_parity(x):
    server = MultiModelServer({"m": _banks()}, backend="gather")
    server.submit(InferRequest("m", x[:5]))
    with pytest.warns(DeprecationWarning):
        server.submit("m", x[5:12])
    out = server.drain()
    assert [o.shape[0] for o in out["m"]] == [5, 7]


def test_typed_serve_returns_results_legacy_returns_arrays(x):
    server = MultiModelServer({"m": _banks()}, backend="gather")
    typed = server.serve([InferRequest("m", x[:4]), InferRequest("m", x[4:10])])
    assert [r.flows for r in typed] == [4, 6]
    assert all(isinstance(r, InferResult) for r in typed)
    assert all(r.queue_wait_ms is not None and r.queue_wait_ms >= 0
               for r in typed)
    with pytest.warns(DeprecationWarning):
        legacy = server.serve([("m", x[:4]), ("m", x[4:10])])
    for r, o in zip(typed, legacy):
        np.testing.assert_array_equal(np.asarray(r.output), np.asarray(o))
    with pytest.raises(TypeError, match="mix"):
        server.serve([InferRequest("m", x[:4]), ("m", x[:4])])


def test_typed_async_submit_and_serve(x):
    banks = _banks()
    ref = np.asarray(MultiModelServer({"m": banks},
                                      backend="gather").infer(
                                          InferRequest("m", x[:4])).output)
    with AsyncMultiModelServer({"m": banks}, backend="gather") as server:
        res = server.submit(InferRequest("m", x[:4])).result(timeout=60)
        assert isinstance(res, InferResult) and res.flows == 4
        assert res.queue_wait_ms is not None and res.queue_wait_ms >= 0
        np.testing.assert_allclose(np.asarray(res.output), ref,
                                   rtol=1e-6, atol=1e-6)
        with pytest.warns(DeprecationWarning):
            raw = server.submit("m", x[:4]).result(timeout=60)
        np.testing.assert_array_equal(np.asarray(res.output), np.asarray(raw))
        outs = server.serve([InferRequest("m", x[:3]),
                             InferRequest("m", x[3:9])])
        assert [o.flows for o in outs] == [3, 6]


def test_typed_infer_async_returns_result(x):
    banks = _banks()

    async def scenario():
        with AsyncMultiModelServer({"m": banks}, backend="gather") as server:
            res = await server.infer_async(InferRequest("m", x[:4]))
            assert isinstance(res, InferResult) and res.flows == 4
            with pytest.raises(DeadlineExceededError):
                await server.infer_async(
                    InferRequest("m", x[:4], deadline_ms=1e-6))

    asyncio.run(scenario())


def test_per_request_priority_queue_jump(x):
    """A high-priority request jumps the model's FIFO ahead of queued
    normal/low entries (FIFO among equals); cross-model WFQ unaffected."""
    server = MultiModelServer({"m": _banks()}, backend="gather")
    server.submit(InferRequest("m", x[:1], priority="low"))
    server.submit(InferRequest("m", x[1:3]))
    server.submit(InferRequest("m", x[3:6]))
    assert server.submit(InferRequest("m", x[6:10], priority="high")) == 0
    assert server.submit(InferRequest("m", x[10:15], priority="high")) == 1
    # a normal submit still slots ahead of the low-priority tail entry
    assert server.submit(InferRequest("m", x[15:16])) == 4
    out = server.drain()["m"]
    # served in rank order: the two highs (4, 5 flows), then the normals
    # (2, 3, 1 flows in submit order), then the low (1 flow)
    assert [o.shape[0] for o in out] == [4, 5, 2, 3, 1, 1]


def test_scheduler_priority_rank_validation():
    s = WFQScheduler()
    s.add_queue("a")
    with pytest.raises(ValueError, match="priority"):
        s.submit("a", (np.zeros((1, 2)),), 1, priority="asap")


def test_stats_snapshot_consistent_under_concurrent_drain(x):
    """Regression for the stats()/counter paths the concurrency sweep
    fixed: per-model counters and batches_dispatched snapshot inside ONE
    _ctr_lock critical section, and models() is read BEFORE that lock
    (the registry->counter hierarchy inversion the sanitizer caught). A
    stats() poller racing live submitters must only ever observe
    well-formed, monotonically growing totals."""
    server = AsyncMultiModelServer({"m": _banks()}, backend="gather")
    stop = threading.Event()
    errs: list = []
    seen: list = []

    def poll_stats():
        last = 0
        while not stop.is_set():
            try:
                st = server.stats()["serving"]
                total = st["flows_served"]
                assert isinstance(total, int) and total >= last, (total, last)
                assert st["models"]["m"]["flows_served"] == total
                last = total
                seen.append(total)
            except Exception as e:  # noqa: BLE001 — re-raised on main thread
                errs.append(e)
                return

    def submit_batch(futs_out):
        for i in range(16):
            futs_out.append(server.submit("m", x[: 1 + (i % 8)]))

    with server:
        pollers = [threading.Thread(target=poll_stats) for _ in range(2)]
        for t in pollers:
            t.start()
        futs: list = []
        lists = [[] for _ in range(3)]
        subs = [threading.Thread(target=submit_batch, args=(fl,))
                for fl in lists]
        for t in subs:
            t.start()
        for t in subs:
            t.join(timeout=60)
        for fl in lists:
            futs.extend(fl)
        total_flows = 0
        for f in futs:
            total_flows += f.result(timeout=60).shape[0]
        stop.set()
        for t in pollers:
            t.join(timeout=10)
    assert not errs, errs[0]
    assert total_flows == 3 * sum(1 + (i % 8) for i in range(16))
    st = server.stats()["serving"]
    assert st["models"]["m"]["flows_served"] == total_flows
    assert st["flows_served"] == total_flows
    assert st["batches_dispatched"] >= 1
    assert seen and seen[-1] <= total_flows
