"""Test-session bootstrap: simulate a multi-device host.

The sharding tests (tests/test_sharding.py) need more than one XLA device;
on the CPU-only CI hosts that means forcing the host platform to expose
several device streams. The flag must be in the environment BEFORE jax
initializes its backends, so it is set here — conftest imports before any
test module — and only when the caller has not already chosen their own
XLA_FLAGS (the dedicated multi-device CI lane exports it explicitly).
"""

import os
import sys

if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()
