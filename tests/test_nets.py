"""End-to-end tests for the paper's models + baselines on synthetic traffic.

These assert the paper's QUALITATIVE claims (the quantitative ones live in
benchmarks/): ordering between methods, small pegasusification deltas, AUC
above chance, resource deployability.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.synthetic_traffic import anomaly_testset, make_dataset
from repro.nets.common import macro_f1

# Minutes-scale teacher trainings: full-CI lane only.
pytestmark = pytest.mark.slow

FLOWS = 400
STEPS = 250


@pytest.fixture(scope="module")
def ds():
    return make_dataset("peerrush", flows_per_class=FLOWS)


def test_mlp_beats_n3ic_and_small_peg_delta(ds):
    from repro.nets.baselines.n3ic import n3ic_apply, train_n3ic
    from repro.nets.mlp import mlp_apply, pegasusify_mlp, pegasus_mlp_apply, train_mlp

    stats, y = ds.train["stats"], ds.train["label"]
    ts, ty = ds.test["stats"], ds.test["label"]
    n3 = train_n3ic(stats, y, ds.num_classes, steps=STEPS)
    f1_n3 = macro_f1(np.asarray(n3ic_apply(n3, jnp.asarray(ts))).argmax(-1), ty, ds.num_classes)
    mlp = train_mlp(stats, y, ds.num_classes, steps=STEPS)
    f1_dense = macro_f1(
        np.asarray(mlp_apply(mlp, jnp.asarray(ts))).argmax(-1), ty, ds.num_classes)
    peg = pegasusify_mlp(mlp, stats.astype(np.float32), refine_steps=40)
    f1_peg = macro_f1(
        np.asarray(pegasus_mlp_apply(peg, jnp.asarray(ts, jnp.float32))).argmax(-1),
        ty, ds.num_classes)
    assert f1_peg > f1_n3, (f1_peg, f1_n3)             # paper Table 5 ordering
    assert f1_dense - f1_peg < 0.05, (f1_dense, f1_peg)  # §7.5 small delta


def test_rnn_beats_bos(ds):
    from repro.nets.baselines.bos import bos_apply, train_bos
    from repro.nets.rnn import pegasusify_rnn, pegasus_rnn_apply, train_rnn

    seq, y = ds.train["seq"], ds.train["label"]
    ts, ty = ds.test["seq"], ds.test["label"]
    bos = train_bos(seq, y, ds.num_classes, steps=STEPS)
    f1_bos = macro_f1(np.asarray(bos_apply(bos, jnp.asarray(ts))).argmax(-1), ty, ds.num_classes)
    rnn = train_rnn(seq, y, ds.num_classes, steps=STEPS)
    peg = pegasusify_rnn(rnn, seq)
    f1_peg = macro_f1(
        np.asarray(pegasus_rnn_apply(peg, jnp.asarray(ts))).argmax(-1), ty, ds.num_classes)
    assert f1_peg > f1_bos, (f1_peg, f1_bos)


def test_cnn_l_scale_beats_cnn_b(ds):
    from repro.nets.cnn import (
        pegasus_cnn_apply, pegasus_cnn_l_apply, pegasusify_cnn, pegasusify_cnn_l,
        train_cnn, train_cnn_l,
    )

    seq, payload, y = ds.train["seq"], ds.train["bytes"], ds.train["label"]
    ts, tp, ty = ds.test["seq"], ds.test["bytes"], ds.test["label"]
    cb = train_cnn(seq, y, ds.num_classes, size="B", steps=STEPS)
    pegb = pegasusify_cnn(cb, seq)
    f1_b = macro_f1(
        np.asarray(pegasus_cnn_apply(pegb, jnp.asarray(ts))).argmax(-1), ty, ds.num_classes)
    cl = train_cnn_l(seq, payload, y, ds.num_classes, steps=STEPS)
    pegl = pegasusify_cnn_l(cl, seq, payload, index_bits=8)
    f1_l = macro_f1(
        np.asarray(pegasus_cnn_l_apply(pegl, jnp.asarray(ts), jnp.asarray(tp))).argmax(-1),
        ty, ds.num_classes)
    # input scale 3840b ≫ 128b → accuracy win (paper §7.3)
    assert f1_l > f1_b, (f1_l, f1_b)


def test_autoencoder_auc_above_chance(ds):
    """Was the last known-failing-at-seed test: raw-space MAE scored in-range
    attacks at chance (malware AUC ~0.54). Fixed by the z-space AE teacher
    (anomaly_features + benign standardization) in repro.nets.autoencoder."""
    from repro.nets.autoencoder import (
        auc_score, pegasus_ae_error, pegasusify_ae, train_autoencoder,
    )

    x_train = ds.train["seq"].reshape(len(ds.train["label"]), -1)
    ae = train_autoencoder(x_train, steps=STEPS)
    banks = pegasusify_ae(ae, x_train.astype(np.float32))
    for kind in ("malware", "dos"):
        test = anomaly_testset(ds, kind=kind)
        x = test["seq"].reshape(len(test["label"]), -1)
        scores = np.asarray(pegasus_ae_error(banks, jnp.asarray(x, jnp.float32)))
        auc = auc_score(scores, test["label"])
        assert auc > 0.8, (kind, auc)                   # paper Fig. 8: 0.89–0.99


def test_resource_reports_deployable(ds):
    from repro.dataplane.compile import compile_model
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    stats, y = ds.train["stats"], ds.train["label"]
    mlp = train_mlp(stats, y, ds.num_classes, steps=STEPS)
    layers = pegasusify_mlp(mlp, stats.astype(np.float32), refine_steps=0)
    rep = compile_model(layers, stateful_bits_per_flow=80).report()
    assert rep.validate() == [], rep.validate()


def test_leo_tree_reasonable(ds):
    from repro.nets.baselines.leo import leo_predict, train_leo

    stats, y = ds.train["stats"], ds.train["label"]
    tree = train_leo(stats, y, ds.num_classes, max_nodes=512)
    f1 = macro_f1(leo_predict(tree, ds.test["stats"]), ds.test["label"], ds.num_classes)
    assert f1 > 0.7, f1
    assert tree.node_count <= 512
