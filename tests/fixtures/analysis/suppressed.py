"""Suppression fixture: justified suppressions are silent; a bare
``disable=`` (no written reason) is itself a PG000 finding — but the
suppression is still honored, so the PG000 is the ONLY finding here."""

import threading
import time


class S:
    def __init__(self):
        self._lock = threading.Lock()

    def justified_inline(self):
        with self._lock:
            time.sleep(0)  # pegasus-lint: disable=PG001 startup barrier, lock held < 1us by construction

    def justified_standalone(self):
        with self._lock:
            # pegasus-lint: disable=PG001 shutdown path, no waiters by design
            time.sleep(0)

    def justified_block(self):
        # pegasus-lint: disable-block=PG001 drain loop: single-threaded teardown, nothing contends
        with self._lock:
            time.sleep(0)
            time.sleep(0)

    def bare_reason_missing(self):
        with self._lock:
            time.sleep(0)  # pegasus-lint: disable=PG001
