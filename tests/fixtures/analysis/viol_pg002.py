"""Seeded PG002 violations — lint fixture, parsed by tests, never imported."""

import threading


class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = {}  # guarded-by: _lock
        self.count = 0    # guarded-by: _lock

    def unguarded_read(self):
        return len(self._items)  # VIOLATION PG002

    def unguarded_write(self):
        self.count += 1  # VIOLATION PG002

    def guarded(self):
        with self._lock:
            self._items["k"] = 1
            self.count += 1
        return True

    # holds: _lock
    def helper_with_contract(self):
        return self._items.get("k")

    def condition_alias_counts(self):
        # _work/_space Conditions share _lock, so holding one IS holding it
        with self._work:
            return dict(self._items)
