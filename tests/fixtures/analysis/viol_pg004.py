"""Seeded PG004 violations — lint fixture, parsed by tests, never imported.

Covers all three traced-body discovery paths (name convention, jax.jit
first argument, functools.partial-wrapped pallas_call kernel) plus
donation safety.
"""

import functools
import random
import threading
import time

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp

_TRACE_LOCK = threading.Lock()


class _Counters:
    total = 0


COUNTERS = _Counters()


def forward(params, x):
    t0 = time.time()  # VIOLATION PG004
    print("tracing", t0)  # VIOLATION PG004
    COUNTERS.total += 1  # VIOLATION PG004
    with _TRACE_LOCK:  # VIOLATION PG004
        pass
    return jnp.tanh(x @ params)


def _kernel(scale, x_ref, o_ref):
    o_ref[...] = x_ref[...] * scale * random.random()  # VIOLATION PG004


def launch(x):
    op = pl.pallas_call(functools.partial(_kernel, 2.0), out_shape=x)
    return op(x)


def _step(state, buf):
    t0 = time.perf_counter()  # VIOLATION PG004
    return state + buf + t0


class Runner:
    def __init__(self, state):
        self._state = state
        self._jit = jax.jit(_step, donate_argnums=(1,))

    def unsafe(self, buf):
        y = self._jit(self._state, buf)
        return y + buf  # VIOLATION PG004

    def safe(self, buf):
        buf = self._jit(self._state, buf)
        return buf + 1.0
