"""Seeded PG001 violations for the receiver-sensitive blocking table
(queue.Queue.get/put, threading.Event.wait) — lint fixture, parsed by
tests, never imported.

Lines carrying a ``# VIOLATION PGxxx`` marker are asserted (by exact line
number) to be flagged; everything else must stay clean — in particular
``dict.get(key)``, a PLURAL container of queues, and ``Condition.wait()``
(which releases the lock while parked).
"""

import queue
import threading


class Mailroom:
    def __init__(self):
        self._lock = threading.Lock()
        self.work_queue = queue.Queue()
        self.inbox = queue.Queue()
        self.done_event = threading.Event()
        self.ready = threading.Event()
        self._queues = {}
        self._cond = threading.Condition(self._lock)

    def drain_under_lock(self):
        with self._lock:
            item = self.work_queue.get()  # VIOLATION PG001
            self.inbox.put(item)  # VIOLATION PG001
        return item

    def wait_under_lock(self):
        with self._lock:
            self.done_event.wait()  # VIOLATION PG001
            self.ready.wait(timeout=1.0)  # VIOLATION PG001

    def bare_q_under_lock(self, q):
        with self._lock:
            return q.get()  # VIOLATION PG001

    def clean_paths(self, name):
        with self._lock:
            # dict.get(key) takes a positional arg: not a blocking Queue.get
            q = self._queues.get(name)
            # plural receiver = a container OF queues, not a queue itself
            self._queues.setdefault(name, q)
            # Condition.wait releases the lock while parked — the one
            # legitimate way to sleep under a lock
            self._cond.wait(timeout=0.01)
        # queue ops OUTSIDE the lock are ordinary blocking calls: fine
        self.work_queue.put(name)
        return self.work_queue.get()
