"""Seeded PG001 violations — lint fixture, parsed by tests, never imported.

Lines carrying a ``# VIOLATION PGxxx`` marker are asserted (by exact line
number) to be flagged; everything else must stay clean.
"""

import threading
import time

import jax


class Server:
    def __init__(self):
        self._lock = threading.Lock()

    def dispatch_under_lock(self, x, device):
        with self._lock:
            return jax.device_put(x, device)  # VIOLATION PG001

    def build_under_lock(self, model):
        with self._lock:
            plan = build_plan(model)  # VIOLATION PG001
        return plan

    def sleep_under_lock(self):
        with self._lock:
            time.sleep(0.1)  # VIOLATION PG001

    def block_under_lock(self, t, fut):
        with self._lock:
            t.join()  # VIOLATION PG001
            return fut.result()  # VIOLATION PG001

    def clean_paths(self, names, x, device):
        label = ", ".join(names)
        with self._lock:
            # str.join on a literal separator is formatting, not blocking
            tag = " | ".join(names)
        y = jax.device_put(x, device)  # dispatch OUTSIDE the lock: fine
        return label, tag, y
