"""Seeded PG003 violation — lint fixture, parsed with an explicit
lock_order of {"_registry_lock": 0, "_sched_lock": 1} (outer -> inner)."""

import threading


class S:
    def __init__(self):
        self._registry_lock = threading.RLock()
        self._sched_lock = threading.Lock()

    def declared_order(self):
        with self._registry_lock:
            with self._sched_lock:
                return 1

    def inverted_order(self):
        with self._sched_lock:
            with self._registry_lock:  # VIOLATION PG003
                return 2
