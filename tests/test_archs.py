"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; assert shapes + finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models.transformer import (
    decode_step, forward_train, init_decode_state, init_model, lm_loss,
    padded_vocab,
)

B, S = 2, 32

# the heaviest smoke configs ride the full lane only
_HEAVY = {"whisper_large_v3", "qwen2_vl_2b", "nemotron_4_340b", "phi3_5_moe"}
ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a for a in ARCH_IDS
]


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.encoder_layers:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32)
        batch["dec_tokens"] = jax.random.randint(ks[1], (B, 16), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, 16), 0, cfg.vocab_size)
    elif cfg.frontend_stub:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_loss(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward_train(cfg, params, batch)
    s_out = batch.get("dec_tokens", batch.get("tokens", batch.get("embeds")))
    exp_s = s_out.shape[1]
    assert logits.shape == (B, exp_s, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits)).all(), arch
    loss = lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_smoke_train_step_reduces_loss(arch):
    from repro.train.optimizer import adamw_init, adamw_update

    cfg = smoke_config(arch)
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state = adamw_init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, state, _ = adamw_update(params, grads, state, lr=1e-3)
        return params, state, loss

    losses = []
    for _ in range(5):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert np.isfinite(losses).all(), arch
    assert losses[-1] < losses[0], (arch, losses)  # memorizing one batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    kv_len = 64
    state = init_decode_state(cfg, B, kv_len, dtype=jnp.float32)
    tokens = jnp.zeros((B, 1), jnp.int32)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    logits, new_state = decode_step(
        cfg, params, state, tokens, jnp.int32(0), enc_out=enc_out)
    assert logits.shape == (B, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits)).all(), arch
    # state must actually update
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), state, new_state),
    )
    assert changed, arch


@pytest.mark.slow
def test_decode_matches_forward_for_dense():
    """Prefill-vs-decode consistency: greedy logits agree step by step."""
    cfg = smoke_config("deepseek_coder_33b")
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab_size)
    logits_full, _ = forward_train(cfg, params, {"tokens": toks})
    state = init_decode_state(cfg, 1, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, state = decode_step(cfg, params, state, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits_full), rtol=2e-3, atol=2e-3)


def test_full_configs_param_counts():
    """Published sizes sanity: ~2B/340B/20B/33B/72B/1.3B/42B/314B/1.5B."""
    expect = {
        "qwen2_vl_2b": (1.2e9, 2.6e9),
        "nemotron_4_340b": (3.0e11, 3.8e11),
        "granite_20b": (1.7e10, 2.4e10),
        "deepseek_coder_33b": (2.8e10, 3.8e10),
        "qwen2_72b": (6.4e10, 8.0e10),
        "xlstm_1_3b": (1.0e9, 1.9e9),
        "phi3_5_moe": (3.6e10, 4.8e10),
        "grok_1_314b": (2.6e11, 3.6e11),
        "hymba_1_5b": (1.0e9, 2.1e9),
        "whisper_large_v3": (1.2e9, 2.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)


@pytest.mark.slow
def test_chunked_attention_matches_naive():
    from repro.models.attention import _sdpa, _sdpa_chunked
    import jax

    key = jax.random.PRNGKey(0)
    b, s, kv, g, hd = 2, 2048, 2, 3, 32
    h = kv * g
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd), jnp.float32)
    for window in (None, 256):
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        mask = j <= i
        if window:
            mask = mask & (j > i - window)
        want = _sdpa(q, k, v, mask[None, None, None], num_kv_groups=g)
        got = _sdpa_chunked(q, k, v, num_kv_groups=g, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
