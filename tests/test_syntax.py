"""Pegasus Syntax translator tests (paper §6.2 / Fig. 6) + extra property
tests on fusion and quantization invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fuse_basic
from repro.core.syntax import (
    SyntaxError_, map_op, partition, program, sumreduce, translate,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _fig6_program(w):
    """The paper's Fig. 6 snippet: Partition(dim=2,stride=2) → Map(CNN) →
    SumReduce, over an 8-byte input vector."""
    k, v, n = 4, 2, 8

    def conv_map(xg):
        return jnp.einsum("...kv,kvn->...kn", xg, w)

    return program(
        partition(dim=2, stride=2),
        map_op(clustering_depth=4, fn=conv_map, linear=True, out_dim=n,
               name="cnn_kernel"),
        sumreduce(),
    )


def test_translate_fig6_and_evaluate():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4, 2, 8)), jnp.float32)
    graph = translate(_fig6_program(w), input_dim=8)
    x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
    out = graph.evaluate(x)
    want = jnp.einsum("bkv,kvn->bn", x.reshape(3, 4, 2), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)
    assert graph.num_lookups() == 1
    assert graph.table_entries() == 16          # 2^clustering_depth


def test_translate_infers_out_dim():
    spec = program(
        partition(dim=4),
        map_op(clustering_depth=3, fn=lambda xg: xg @ jnp.ones((4, 7)),
               linear=True),
        sumreduce(),
    )
    graph = translate(spec, input_dim=8)
    assert graph.ops[1].out_dim == 7


@pytest.mark.parametrize("bad,msg", [
    (program(partition(dim=3)), "does not tile"),
    (program(sumreduce()), "SumReduce before"),
    (program(partition(dim=2), partition(dim=2)), "nested Partition"),
    (program({"op": "Conv"}), "unknown op"),
    (program(partition(dim=2),
             map_op(clustering_depth=0, fn=lambda x: x)), "out of range"),
])
def test_translate_rejects_illformed(bad, msg):
    with pytest.raises(SyntaxError_, match=msg):
        translate(bad, input_dim=8)


def test_translated_graph_fuses():
    """Syntax output is a normal PrimitiveGraph: Basic Fusion applies."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(4, 2, 8)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    spec = program(
        partition(dim=2, stride=2),
        map_op(clustering_depth=4, fn=lambda xg: jnp.einsum("...kv,kvn->...kn", xg, w),
               linear=True, out_dim=8),
        sumreduce(),
        map_op(clustering_depth=8, fn=lambda x: x @ w2, linear=True, out_dim=3),
    )
    graph = translate(spec, input_dim=8)
    fused = fuse_basic(graph)
    x = jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)
    np.testing.assert_allclose(np.asarray(graph.evaluate(x)),
                               np.asarray(fused.evaluate(x)), rtol=1e-4, atol=1e-5)
    assert fused.num_lookups() < graph.num_lookups()


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        k=st.sampled_from([2, 4]),
        v=st.sampled_from([2, 3]),
        n=st.sampled_from([4, 8]),
    )
    def test_property_fusion_preserves_semantics(seed, k, v, n):
        """Basic fusion is semantics-preserving for random affine chains."""
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(k, v, n)), jnp.float32)
        w2 = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        spec = program(
            partition(dim=v),
            map_op(clustering_depth=4, linear=True, out_dim=n,
                   fn=lambda xg: jnp.einsum("...kv,kvn->...kn", xg, w)),
            sumreduce(),
            map_op(clustering_depth=8, fn=jax.nn.relu, out_dim=n),
            map_op(clustering_depth=8, fn=lambda x: x @ w2, linear=True,
                   out_dim=3, bias=None),
        )
        graph = translate(spec, input_dim=k * v)
        fused = fuse_basic(graph)
        x = jnp.asarray(rng.normal(size=(4, k * v)), jnp.float32)
        np.testing.assert_allclose(np.asarray(graph.evaluate(x)),
                                   np.asarray(fused.evaluate(x)),
                                   rtol=1e-4, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), bits=st.sampled_from([8, 12, 16]))
    def test_property_fixed_point_error_bound(seed, bits):
        """Quantization error ≤ half a quantum over the calibrated range."""
        from repro.core import choose_qspec, dequantize, quantize

        rng = np.random.default_rng(seed)
        x = rng.normal(scale=10.0, size=(256,)).astype(np.float32)
        spec = choose_qspec(x, bits=bits)
        err = np.abs(np.asarray(dequantize(quantize(jnp.asarray(x), spec), spec)) - x)
        assert err.max() <= 0.5 / spec.scale + 1e-6
