"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracle (interpret=True executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_pegasus_linear
from repro.core.amm import apply_gather
from repro.core.fuzzy_tree import fit_tree, stack_trees
from repro.kernels.fuzzy_lut.kernel import fuzzy_lut_pallas
from repro.kernels.fuzzy_lut.ops import fuzzy_lut_matmul, prepare_feat_onehot
from repro.kernels.fuzzy_lut.ref import fuzzy_lut_matmul_ref, tree_descent_ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _random_problem(rng, t, k, v, depth, n, lut_dtype=jnp.float32):
    data = rng.normal(size=(max(4 * 2**depth, 64), k * v)).astype(np.float32)
    trees = stack_trees(
        [fit_tree(data[:, g * v : (g + 1) * v], depth) for g in range(k)]
    )
    lut = jnp.asarray(
        rng.normal(size=(k, 2**depth, n)).astype(np.float32), dtype=lut_dtype
    )
    x = jnp.asarray(rng.normal(size=(t, k, v)).astype(np.float32))
    return x, trees, lut


SHAPE_SWEEP = [
    # t, k, v, depth, n, (bt, bn, bk)
    (8, 2, 2, 1, 4, (8, 4, 2)),
    (16, 4, 4, 2, 8, (8, 8, 2)),
    (32, 8, 4, 3, 16, (16, 16, 4)),
    (64, 16, 8, 4, 32, (32, 32, 8)),
    pytest.param(128, 32, 4, 4, 64, (64, 64, 16), marks=pytest.mark.slow),
    pytest.param(256, 64, 2, 5, 128, (128, 128, 32), marks=pytest.mark.slow),
]


@pytest.mark.parametrize("t,k,v,depth,n,blocks", SHAPE_SWEEP)
def test_kernel_matches_oracle_shape_sweep(t, k, v, depth, n, blocks):
    rng = np.random.default_rng(t * 1000 + k)
    x, trees, lut = _random_problem(rng, t, k, v, depth, n)
    feat_oh = prepare_feat_onehot(trees.features, v)
    bt, bn, bk = blocks
    got = fuzzy_lut_pallas(
        x, feat_oh, trees.thresholds, lut,
        depth=depth, block_t=bt, block_n=bn, block_k=bk,
    )
    want = fuzzy_lut_matmul_ref(x, trees.features, trees.thresholds, lut)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("lut_dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(lut_dtype):
    rng = np.random.default_rng(11)
    x, trees, lut = _random_problem(rng, 32, 8, 4, 4, 16, lut_dtype=lut_dtype)
    feat_oh = prepare_feat_onehot(trees.features, 4)
    got = fuzzy_lut_pallas(
        x, feat_oh, trees.thresholds, lut, depth=4,
        block_t=16, block_n=16, block_k=4,
    )
    want = fuzzy_lut_matmul_ref(x, trees.features, trees.thresholds, lut)
    tol = 1e-5 if lut_dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_kernel_accumulation_over_k_blocks():
    """K-innermost accumulation must equal single-block result."""
    rng = np.random.default_rng(13)
    x, trees, lut = _random_problem(rng, 16, 8, 4, 3, 8)
    feat_oh = prepare_feat_onehot(trees.features, 4)
    one = fuzzy_lut_pallas(x, feat_oh, trees.thresholds, lut, depth=3,
                           block_t=16, block_n=8, block_k=8)
    many = fuzzy_lut_pallas(x, feat_oh, trees.thresholds, lut, depth=3,
                            block_t=16, block_n=8, block_k=2)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many), rtol=1e-5, atol=1e-5)


def test_ops_wrapper_pads_ragged_shapes():
    """T/K/N not divisible by blocks → wrapper pads; result unchanged."""
    rng = np.random.default_rng(15)
    d, n, s = 24, 10, 1024  # K=6 groups of 4 — not a multiple of block_k
    w = rng.normal(size=(d, n)).astype(np.float32)
    calib = rng.normal(size=(s, d)).astype(np.float32)
    layer = init_pegasus_linear(w, None, calib, group_size=4, depth=3, lut_bits=None)
    x = jnp.asarray(calib[:37])  # ragged T
    got = fuzzy_lut_matmul(layer, x, block_t=16, block_n=8, block_k=4)
    want = apply_gather(layer, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ops_wrapper_batch_dims():
    rng = np.random.default_rng(16)
    d, n, s = 16, 8, 512
    w = rng.normal(size=(d, n)).astype(np.float32)
    b = rng.normal(size=(n,)).astype(np.float32)
    calib = rng.normal(size=(s, d)).astype(np.float32)
    layer = init_pegasus_linear(w, b, calib, group_size=4, depth=3, lut_bits=None)
    x = jnp.asarray(rng.normal(size=(3, 5, d)).astype(np.float32))
    got = fuzzy_lut_matmul(layer, x, block_t=8, block_n=8, block_k=4)
    want = apply_gather(layer, x)
    assert got.shape == (3, 5, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def _stacked_problem(rng, l, t, kmax, v, depth, nmax, ks, n_out):
    """Random stacked operands: padded groups carry +inf thr / zero LUT."""
    c = 2 ** depth
    i = c - 1
    feat_oh = np.zeros((l, kmax, i, v), np.float32)
    thr = np.full((l, kmax, i), np.inf, np.float32)
    lut = np.zeros((l, kmax, c, nmax), np.float32)
    bias = np.zeros((l, nmax), np.float32)
    for layer in range(l):
        k = ks[layer]
        feats = rng.integers(0, v, size=(k, i))
        feat_oh[layer, :k] = np.eye(v, dtype=np.float32)[feats]
        thr[layer, :k] = rng.normal(size=(k, i)).astype(np.float32)
        n = n_out if layer == l - 1 else ks[layer + 1] * v
        lut[layer, :k, :, :n] = rng.normal(size=(k, c, n)).astype(np.float32) * 0.3
        bias[layer, :n] = rng.normal(size=n).astype(np.float32) * 0.1
    x = rng.normal(size=(t, ks[0], v)).astype(np.float32)
    return map(jnp.asarray, (x, feat_oh, thr, lut, bias))


@pytest.mark.parametrize("strategy", ["lookup", "mxu"])
def test_stack_kernel_matches_chained_single_bank(strategy):
    """The stacked-layer kernel ≡ chaining the single-bank kernel per layer
    (re-partition + bias applied between layers), on both strategies."""
    from repro.kernels.fuzzy_lut.kernel import fuzzy_lut_stack_pallas

    rng = np.random.default_rng(7)
    ks, v, depth, n_out, t = (6, 4, 4, 4), 2, 3, 3, 16
    x, feat_oh, thr, lut, bias = _stacked_problem(
        rng, len(ks), t, max(ks), v, depth, 8, ks, n_out)
    got = fuzzy_lut_stack_pallas(
        x, feat_oh, thr, lut, bias, depth=depth, ks=ks, n_out=n_out,
        strategy=strategy)

    h = x
    for layer, k in enumerate(ks):
        n = n_out if layer == len(ks) - 1 else ks[layer + 1] * v
        y = fuzzy_lut_pallas(
            h[:, :k], feat_oh[layer, :k], thr[layer, :k],
            lut[layer, :k, :, :n], depth=depth, block_t=t, block_n=n,
            block_k=k, strategy=strategy)
        y = y + bias[layer, :n]
        if layer + 1 < len(ks):
            h = y.reshape(t, ks[layer + 1], v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y),
                               rtol=1e-5, atol=1e-5)


def test_stack_kernel_tiles_batch():
    """T larger than block_t: grid-tiled result equals the one-tile result."""
    from repro.kernels.fuzzy_lut.kernel import fuzzy_lut_stack_pallas

    rng = np.random.default_rng(9)
    ks, v, depth, n_out = (4, 4), 2, 3, 8
    x, feat_oh, thr, lut, bias = _stacked_problem(
        rng, len(ks), 64, max(ks), v, depth, 8, ks, n_out)
    one = fuzzy_lut_stack_pallas(x, feat_oh, thr, lut, bias, depth=depth,
                                 ks=ks, n_out=n_out, block_t=64)
    many = fuzzy_lut_stack_pallas(x, feat_oh, thr, lut, bias, depth=depth,
                                  ks=ks, n_out=n_out, block_t=16)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many),
                               rtol=1e-5, atol=1e-5)


def test_block_divisibility_raises_value_error():
    """Satellite bugfix: mis-padded operands raise ValueError naming the
    offending dims (an assert would vanish under ``python -O`` and the
    engine fallback could never catch it)."""
    from repro.kernels.fuzzy_lut.kernel import fuzzy_lut_stack_pallas

    rng = np.random.default_rng(21)
    x, trees, lut = _random_problem(rng, 12, 4, 4, 2, 8)   # T=12 vs block 8
    feat_oh = prepare_feat_onehot(trees.features, 4)
    with pytest.raises(ValueError, match=r"T=12 % block 8"):
        fuzzy_lut_pallas(x, feat_oh, trees.thresholds, lut, depth=2,
                         block_t=8, block_n=8, block_k=4)
    with pytest.raises(ValueError, match=r"N=8 % block 3"):
        fuzzy_lut_pallas(x, feat_oh, trees.thresholds, lut, depth=2,
                         block_t=12, block_n=3, block_k=4)

    ks, v, depth, n_out = (4, 4), 4, 2, 8
    sx, sf, st_, sl, sb = _stacked_problem(
        rng, 2, 12, 4, v, depth, 16, ks, n_out)
    with pytest.raises(ValueError, match=r"T=12 % block 8"):
        fuzzy_lut_stack_pallas(sx, sf, st_, sl, sb, depth=depth, ks=ks,
                               n_out=n_out, block_t=8)
    with pytest.raises(ValueError, match="ks has 3 entries"):
        fuzzy_lut_stack_pallas(sx, sf, st_, sl, sb, depth=depth,
                               ks=(4, 4, 4), n_out=n_out, block_t=12)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        t=st.integers(2, 24),
        k=st.sampled_from([2, 4, 8]),
        v=st.sampled_from([2, 4]),
        depth=st.integers(1, 4),
        n=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_property_kernel_equals_oracle(t, k, v, depth, n, seed):
        rng = np.random.default_rng(seed)
        x, trees, lut = _random_problem(rng, t, k, v, depth, n)
        feat_oh = prepare_feat_onehot(trees.features, v)
        got = fuzzy_lut_pallas(
            x, feat_oh, trees.thresholds, lut, depth=depth,
            block_t=t, block_n=n, block_k=k,
        )
        want = fuzzy_lut_matmul_ref(x, trees.features, trees.thresholds, lut)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        depth=st.integers(1, 5),
    )
    def test_property_descent_reaches_valid_leaf(seed, depth):
        """Invariant: every input reaches exactly one leaf in [0, 2^d)."""
        rng = np.random.default_rng(seed)
        data = rng.normal(size=(128, 3)).astype(np.float32)
        tree = fit_tree(data, depth)
        stacked = stack_trees([tree])
        idx = tree_descent_ref(
            jnp.asarray(data[:, None, :]), stacked.features, stacked.thresholds
        )
        assert int(idx.min()) >= 0 and int(idx.max()) < 2**depth
