"""Distributed-runtime tests: optimizer, checkpointing (incl. crash recovery
and elastic restore), gradient compression, train loop, serve loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.launch.train import TrainLoop, make_train_step, synthetic_batches
from repro.models.transformer import init_model, lm_loss
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, lr=5e-2,
                                        weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), [0, 0], atol=1e-2)


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)
    assert float(f(jnp.int32(55))) < float(f(jnp.int32(20)))


def test_grad_clipping():
    from repro.train.optimizer import clip_by_global_norm

    grads = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"layer": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step_count": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    restored, step = ckpt.restore(str(tmp_path), t)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(t["layer"]["w"]))


def test_checkpoint_keep_last_k(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep=2)
    assert ckpt.latest_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_crash_mid_save_ignored(tmp_path):
    """A partial (uncommitted) save must not be picked up."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate crash: directory exists but no COMMITTED marker
    os.makedirs(tmp_path / "step_2")
    (tmp_path / "step_2" / "manifest.json").write_text("{broken")
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, step = ckpt.restore(str(tmp_path), t)
    assert step == 1


def test_elastic_restore_new_mesh(tmp_path):
    """Save under one sharding, restore under another mesh shape."""
    devs = jax.devices()
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 0, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, _ = ckpt.restore(str(tmp_path), t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    c.save(3, _tree())
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# train loop + fault tolerance
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_trainloop_runs_and_loss_finite(small_mesh, tmp_path):
    cfg = smoke_config("deepseek_coder_33b")
    loop = TrainLoop(cfg, small_mesh, ckpt_dir=str(tmp_path), ckpt_every=3)
    m = loop.run(synthetic_batches(cfg, 2, 16), steps=4)
    assert np.isfinite(float(m["loss"]))
    assert ckpt.latest_step(str(tmp_path)) == 4


@pytest.mark.slow
def test_crash_recovery_resumes_identically(small_mesh, tmp_path):
    """Train 6 steps straight vs 3 + 'crash' + restore + 3: same params."""
    cfg = smoke_config("qwen2_vl_2b")

    def batches():
        return synthetic_batches(cfg, 2, 16, seed=0)

    d1 = tmp_path / "a"
    loop = TrainLoop(cfg, small_mesh, ckpt_dir=str(d1), ckpt_every=100)
    gen = batches()
    loop.run(gen, steps=6)
    w_straight = np.asarray(jax.tree.leaves(loop.params)[0])

    d2 = tmp_path / "b"
    loop_a = TrainLoop(cfg, small_mesh, ckpt_dir=str(d2), ckpt_every=3)
    gen2 = batches()
    loop_a.run(gen2, steps=3)          # checkpoints at step 3; "crash" here
    del loop_a
    loop_b = TrainLoop(cfg, small_mesh, ckpt_dir=str(d2), ckpt_every=100)
    assert loop_b.start_step == 3       # restored
    # replay the SAME data stream from step 3
    gen3 = batches()
    for _ in range(3):
        next(gen3)
    loop_b.run(gen3, steps=3)
    w_resumed = np.asarray(jax.tree.leaves(loop_b.params)[0])
    np.testing.assert_allclose(w_straight, w_resumed, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_grad_compression_small_error():
    """bf16 gradient compression: <1% relative error on the update."""
    cfg = smoke_config("granite_20b")
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = next(synthetic_batches(cfg, 2, 16))
    loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
    comp = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree.leaves(grads), jax.tree.leaves(comp)))
    den = sum(float(jnp.sum(a**2)) for a in jax.tree.leaves(grads))
    assert (num / den) ** 0.5 < 0.01


@pytest.mark.slow
def test_microbatched_step_matches_full_batch():
    cfg = smoke_config("qwen2_vl_2b")
    params = init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)
    batch = next(synthetic_batches(cfg, 4, 16))
    s1 = make_train_step(cfg, microbatches=1)
    s2 = make_train_step(cfg, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    a, b = jax.tree.leaves(p1)[0], jax.tree.leaves(p2)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_server_generates(small_mesh):
    from repro.launch.serve import Server

    cfg = smoke_config("hymba_1_5b")
    server = Server(cfg, small_mesh, kv_len=32, batch_size=2)
    out = server.generate(np.ones((2, 1), np.int32), max_new=4)
    assert out.shape == (2, 5)
    assert np.isfinite(out).all()
