"""Unit tests for repro.core: fuzzy trees, primitives, fusion, quantization, AMM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FixedPointSpec,
    PegasusLinear,
    PrimitiveGraph,
    MapOp,
    PartitionOp,
    SumReduceOp,
    advanced_nam,
    advanced_remove_nonlinear,
    build_matmul_lut,
    choose_qspec,
    dequantize,
    fake_quant_spec,
    fit_tree,
    fuse_basic,
    hard_index,
    init_pegasus_linear,
    partition,
    pegasus_linear_apply,
    quantize,
    soft_index,
    stack_trees,
    sum_reduce,
)
from repro.core.amm import apply_gather, apply_onehot, apply_soft, dense_reference
from repro.core.fuzzy_tree import hard_index_stacked, leaf_one_hot


# ---------------------------------------------------------------------------
# fuzzy tree
# ---------------------------------------------------------------------------


def test_fit_tree_paper_figure3():
    """Reproduce Figure 3: split C0 on x1@5 etc., centroid C6 = mean."""
    data = np.array(
        [[1.0, 2.0], [2.0, 3.0], [3.0, 7.0], [2.0, 8.0], [4.0, 9.0], [5.0, 10.0]],
        np.float32,
    )
    tree = fit_tree(data, depth=2)
    # all points land in a leaf whose centroid is the mean of its members
    idx = hard_index(tree, jnp.asarray(data))
    for leaf in np.unique(np.asarray(idx)):
        members = data[np.asarray(idx) == leaf]
        np.testing.assert_allclose(
            np.asarray(tree.centroids)[leaf], members.mean(axis=0), rtol=1e-5
        )


def test_hard_index_routes_to_nearest_region():
    rng = np.random.default_rng(0)
    data = rng.normal(size=(512, 4)).astype(np.float32)
    tree = fit_tree(data, depth=4)
    idx = np.asarray(hard_index(tree, jnp.asarray(data)))
    assert idx.min() >= 0 and idx.max() < 16
    # quantization error must beat the trivial single-centroid baseline
    cent = np.asarray(tree.centroids)[idx]
    err = ((data - cent) ** 2).sum()
    base = ((data - data.mean(0)) ** 2).sum()
    assert err < 0.6 * base


@pytest.mark.slow
def test_soft_index_matches_hard_at_low_temperature():
    rng = np.random.default_rng(1)
    data = rng.normal(size=(256, 3)).astype(np.float32)
    tree = fit_tree(data, depth=3)
    x = jnp.asarray(data[:32])
    hard = np.asarray(hard_index(tree, x))
    soft = np.asarray(soft_index(tree, x, temperature=1e-4))
    np.testing.assert_array_equal(soft.argmax(-1), hard)
    np.testing.assert_allclose(soft.sum(-1), 1.0, rtol=1e-4)


@pytest.mark.slow
def test_soft_index_is_differentiable():
    rng = np.random.default_rng(2)
    data = rng.normal(size=(128, 2)).astype(np.float32)
    tree = fit_tree(data, depth=2)

    def loss(thr):
        from repro.core.fuzzy_tree import FuzzyTree

        t = FuzzyTree(tree.features, thr, tree.centroids)
        p = soft_index(t, jnp.asarray(data[:16]), temperature=0.5)
        return (p * jnp.arange(4)).sum()

    g = jax.grad(loss)(tree.thresholds)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0


def test_stacked_index_matches_per_tree():
    rng = np.random.default_rng(3)
    data = rng.normal(size=(256, 8)).astype(np.float32)
    trees = [fit_tree(data[:, i * 2 : (i + 1) * 2], 3) for i in range(4)]
    stacked = stack_trees(trees)
    xg = jnp.asarray(data[:16].reshape(16, 4, 2))
    got = np.asarray(hard_index_stacked(stacked, xg))
    for k in range(4):
        want = np.asarray(hard_index(trees[k], xg[:, k]))
        np.testing.assert_array_equal(got[:, k], want)


# ---------------------------------------------------------------------------
# primitives + fusion
# ---------------------------------------------------------------------------


def test_partition_shapes_and_stride():
    x = jnp.arange(12.0)
    g = partition(x, dim=4)
    assert g.shape == (3, 4)
    g2 = partition(x, dim=4, stride=2)
    assert g2.shape == (5, 4)
    np.testing.assert_allclose(np.asarray(g2[1]), [2, 3, 4, 5])


def _mlp_graph(w1, b1, w2, gamma, beta):
    """BN -> FC -> ReLU -> FC chain as a primitive graph (Fig. 5 shape).

    Affine ops keep their constant in ``bias`` (fn strictly linear) so the
    fusion passes can hoist it correctly across SumReduce.
    """
    from repro.core.fusion import identity

    k, v = 2, 2

    def bn_scale(xg):
        return gamma * xg

    def fc_groups(xg):  # per-group partial matmul [.., K, v] -> [.., K, N]
        return jnp.einsum("...kv,kvn->...kn", xg, w1.reshape(k, v, -1))

    def relu(x):
        return jax.nn.relu(x)

    def fc2(x):
        return x @ w2

    n = w1.shape[1]
    return PrimitiveGraph(
        [
            PartitionOp(dim=v, name="part"),
            MapOp(fn=bn_scale, linear=True, in_dim=v, out_dim=v, table_entries=16, bias=beta, name="bn"),
            MapOp(fn=fc_groups, linear=True, in_dim=v, out_dim=n, table_entries=16, bias=None, name="fc1"),
            SumReduceOp(),
            MapOp(fn=identity, linear=True, in_dim=n, out_dim=n, table_entries=0, bias=b1, name="bias1"),
            MapOp(fn=relu, linear=False, in_dim=n, out_dim=n, table_entries=16, name="relu"),
            MapOp(fn=fc2, linear=True, in_dim=n, out_dim=w2.shape[1], table_entries=16, name="fc2"),
        ]
    )


def test_basic_fusion_preserves_semantics_and_reduces_lookups():
    rng = np.random.default_rng(4)
    w1 = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    gamma = jnp.float32(1.3)
    beta = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    g = _mlp_graph(w1, b1, w2, gamma, beta)
    fused = fuse_basic(g)
    x = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(g.evaluate(x)), np.asarray(fused.evaluate(x)), rtol=1e-4, atol=1e-5
    )
    assert fused.num_lookups() < g.num_lookups()


def test_advanced_remove_nonlinear_single_lookup():
    rng = np.random.default_rng(5)
    w1 = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    g = _mlp_graph(w1, b1, w2, jnp.float32(1.0), jnp.zeros((2, 2), jnp.float32))
    lin = advanced_remove_nonlinear(g)
    # linear pipeline: the only lookup(s) left are the fused per-group maps
    assert lin.num_lookups() <= 2
    # and it is exactly the linear part of the model
    x = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    want = ((x @ w1) + b1) @ w2
    np.testing.assert_allclose(np.asarray(lin.evaluate(x)), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_advanced_nam_structure():
    rng = np.random.default_rng(6)
    w1 = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    g = _mlp_graph(w1, b1, w2, jnp.float32(1.0), jnp.zeros((2, 2), jnp.float32))
    nam = advanced_nam(g)
    assert nam.num_lookups() == 1
    assert isinstance(nam.ops[0], PartitionOp)
    assert isinstance(nam.ops[-1], SumReduceOp)
    x = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    out = nam.evaluate(x)
    assert out.shape == (4, 3)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


def test_choose_qspec_ranges():
    spec = choose_qspec(np.array([-100.0, 100.0]), bits=16)
    # values up to 128 must be representable
    q = quantize(jnp.asarray([99.7]), spec)
    x = dequantize(q, spec)
    np.testing.assert_allclose(np.asarray(x), [99.7], atol=2.0 / spec.scale)
    spec_small = choose_qspec(np.array([0.0, 5.0]), bits=16)
    assert spec_small.frac_bits > spec.frac_bits  # adaptive binary point


def test_fake_quant_ste_gradient():
    spec = FixedPointSpec(bits=8, frac_bits=4)
    g = jax.grad(lambda x: fake_quant_spec(x, spec).sum())(jnp.asarray([0.3, 7.9, 100.0]))
    np.testing.assert_allclose(np.asarray(g), [1.0, 1.0, 0.0])  # clip STE


# ---------------------------------------------------------------------------
# approximate matmul (PegasusLinear)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_layer():
    rng = np.random.default_rng(7)
    d, n, s = 16, 8, 4096
    w = rng.normal(size=(d, n)).astype(np.float32) / np.sqrt(d)
    b = rng.normal(size=(n,)).astype(np.float32)
    calib = rng.normal(size=(s, d)).astype(np.float32)
    layer = init_pegasus_linear(w, b, calib, group_size=4, depth=4, lut_bits=None)
    return w, b, calib, layer


def test_amm_paths_agree(small_layer):
    w, b, calib, layer = small_layer
    x = jnp.asarray(calib[:64])
    y_g = apply_gather(layer, x)
    y_o = apply_onehot(layer, x)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_o), rtol=1e-4, atol=1e-5)


def test_amm_approximates_dense(small_layer):
    w, b, calib, layer = small_layer
    x = jnp.asarray(calib[:512])
    y_ref = dense_reference(jnp.asarray(w), jnp.asarray(b), x)
    y_amm = apply_gather(layer, x)
    # relative RMSE well below 1 (it IS an approximation)
    rel = float(jnp.linalg.norm(y_amm - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.45, rel


@pytest.mark.slow
def test_amm_soft_path_low_temp_matches_hard(small_layer):
    _, _, calib, layer = small_layer
    x = jnp.asarray(calib[:32])
    hard = apply_gather(layer, x)
    soft = apply_soft(layer, x, temperature=1e-4)
    # points can sit exactly on a learned threshold (sigmoid ties → 0.5/0.5
    # leaf split), so compare in aggregate, not elementwise-exactly
    diff = np.abs(np.asarray(soft) - np.asarray(hard))
    assert np.median(diff) < 1e-5
    assert diff.max() < 0.1


@pytest.mark.slow
def test_refine_improves_hard_error():
    """Paper §4.4: backprop re-aligns tables when the clustering is stale.

    With mean centroids and a linear teacher, the initial LUT is already
    conditionally optimal — so to exercise refinement we fit the trees on a
    SHIFTED calibration distribution (a deployment-drift scenario) and let
    backprop re-align thresholds + LUT against the true data.
    """
    from repro.core.finetune import hard_mse, refine

    rng = np.random.default_rng(17)
    d, n, s = 16, 8, 4096
    w = rng.normal(size=(d, n)).astype(np.float32) / np.sqrt(d)
    b = rng.normal(size=(n,)).astype(np.float32)
    stale = (rng.normal(size=(s, d)) * 2.0 + 1.5).astype(np.float32)  # drifted
    true = rng.normal(size=(s, d)).astype(np.float32)
    layer = init_pegasus_linear(w, b, stale, group_size=4, depth=4, lut_bits=None)
    x = jnp.asarray(true)
    y_teacher = dense_reference(jnp.asarray(w), jnp.asarray(b), x)
    before = hard_mse(layer, x, y_teacher)
    refined = refine(layer, x, y_teacher, steps=150, lr=3e-3)
    after = hard_mse(refined, x, y_teacher)
    assert after < 0.9 * before, (before, after)


def test_build_matmul_lut_shapes():
    cents = jnp.ones((4, 16, 2))
    w = jnp.ones((8, 5))
    lut = build_matmul_lut(cents, w, 2)
    assert lut.shape == (4, 16, 5)
    np.testing.assert_allclose(np.asarray(lut), 2.0)
