"""Docs-examples lane (ISSUE 6): execute every fenced ```python block in
README.md and docs/*.md headless, so the documentation cannot rot.

Each snippet runs via exec() against a COPY of one seeded fixture
namespace — the names the docs are written against (tiny trained models:
``mlp``/``calib``/``mlp_banks``, ``peg_rnn``, ``ae_banks``, inputs
``x``/``x_stats``/``x_seq``/``feats``/``bursts``). The copy keeps
snippets independent: names one snippet binds (``plan``, ``server``) are
invisible to the next, so every snippet must be self-contained — exactly
the property that makes it honest documentation. Snippets that are not
meant to execute (shell commands, stats schemas, pseudo-code) must use a
non-python fence (```bash, ```text).

The fixture trains at throwaway step counts (the snippets demonstrate
APIs, not accuracy), so the whole module is fast-lane material.
"""

import re
from functools import partial
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$", re.M | re.S)


def _snippets():
    """Every ```python fence, id'd by file + first code line number."""
    out = []
    for path in DOC_FILES:
        if not path.exists():
            continue
        text = path.read_text()
        for m in _FENCE.finditer(text):
            first_line = text[: m.end(0) - len(m.group(0))].count("\n") + 2
            out.append(pytest.param(
                str(path), m.group(1),
                id=f"{path.name}:{first_line}"))
    return out


_PARAMS = _snippets()


@pytest.mark.docs
def test_docs_have_python_snippets():
    """The lane is pointless if extraction silently matches nothing — pin
    that README plus both docs pages contribute executable snippets."""
    files = {p.id.split(":")[0] for p in _PARAMS}
    assert "README.md" in files, files
    assert "SERVING.md" in files, files
    assert len(_PARAMS) >= 4, [p.id for p in _PARAMS]


@pytest.fixture(scope="module")
def docs_ns():
    """The namespace the docs snippets are written against.

    ``pegasusify_mlp`` is re-exported with ``refine_steps=0`` so snippets
    that lower a model inline stay seconds-cheap; the call signature the
    docs show is unchanged.
    """
    from repro.data.synthetic_traffic import make_dataset
    from repro.nets.autoencoder import (
        anomaly_features, pegasusify_ae, train_autoencoder,
    )
    from repro.nets.mlp import pegasusify_mlp, train_mlp
    from repro.nets.rnn import pegasusify_rnn, train_rnn

    ds = make_dataset("peerrush", flows_per_class=48)
    calib = ds.train["stats"].astype(np.float32)
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                    steps=5)
    peg_mlp = partial(pegasusify_mlp, depth=3, refine_steps=0)
    mlp_banks = peg_mlp(mlp, calib)

    rnn = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                    steps=5)
    peg_rnn = pegasusify_rnn(rnn, ds.train["seq"], depth=4)

    flat = ds.train["seq"].reshape(len(ds.train["label"]), -1)
    ae = train_autoencoder(flat, steps=5)
    ae_banks = pegasusify_ae(ae, flat.astype(np.float32), depth=4)

    x_stats = jnp.asarray(ds.test["stats"][:16], jnp.float32)
    test_flat = ds.test["seq"][:16].reshape(16, -1)
    return {
        "np": np,
        "jnp": jnp,
        "mlp": mlp,
        "calib": calib,
        "x": x_stats,
        "pegasusify_mlp": peg_mlp,
        "mlp_banks": mlp_banks,
        "peg_rnn": peg_rnn,
        "ae_banks": ae_banks,
        "x_stats": x_stats,
        "x_seq": jnp.asarray(ds.test["seq"][:16]),
        "feats": jnp.asarray(anomaly_features(test_flat)),
        "bursts": [x_stats[:n] for n in (5, 9, 16)],
    }


@pytest.mark.docs
@pytest.mark.parametrize(("path", "code"), _PARAMS)
def test_docs_snippet_executes(path, code, docs_ns):
    exec(compile(code, path, "exec"), dict(docs_ns))
