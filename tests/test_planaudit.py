"""Tests for repro.analysis.planaudit — the PGA1xx plan auditor.

Every rule gets a seeded-violation fixture: a plan (or tampered plan)
constructed to trip exactly that invariant, plus the clean-plan side
showing the rule stays quiet on healthy builds. PGA101's analytic bound is
validated against brute-force enumeration of every leaf combination.
"""

from __future__ import annotations

import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.planaudit import (AuditConfig, PlanAuditError,
                                      accumulation_grid, audit_plan,
                                      overflow_bound)
from repro.core.amm import init_pegasus_linear
from repro.dataplane.resources import SwitchBudget
from repro.engine import PlanRegistry, build_plan, plan_for
from repro.kernels.fuzzy_lut.quantized import quantize_lut_int8

RNG = np.random.default_rng(20250808)


def _chain_banks(seed, dims=(8, 8, 8, 5), group_size=2, depth=3,
                 row_scale=None):
    """Sequential chaining banks; ``row_scale[g]`` multiplies group ``g``'s
    weight rows of the FIRST bank (seeded per-group amax ladders)."""
    rng = np.random.default_rng(seed)
    banks = []
    for j, (a, b) in enumerate(zip(dims, dims[1:])):
        w = rng.normal(size=(a, b)).astype(np.float32)
        if j == 0 and row_scale is not None:
            for g, s in enumerate(row_scale):
                w[g * group_size:(g + 1) * group_size] *= s
        banks.append(init_pegasus_linear(
            w, rng.normal(size=b).astype(np.float32) * 0.1,
            rng.normal(size=(128, a)).astype(np.float32),
            group_size=group_size, depth=depth, lut_bits=None))
    return banks


def _rules(report, rule):
    return [f for f in report.findings if f.rule == rule]


# ---------------------------------------------------------------------------
# PGA101 — fixed-point overflow
# ---------------------------------------------------------------------------


def test_pga101_bound_matches_brute_force():
    """The separable bound IS the reachable worst case: enumerate every
    (c_1..c_K) leaf combination of a small random table and compare."""
    k, c, n = 3, 4, 2
    q8 = RNG.integers(-127, 128, size=(k, c, n)).astype(np.int64)
    scales = np.abs(RNG.normal(size=k)).astype(np.float64) + 1e-3
    bias = RNG.normal(size=n).astype(np.float64)

    grid = accumulation_grid(scales)
    contrib = np.rint(q8 * (scales[:, None, None] / grid))
    worst = 0.0
    for c0 in range(c):
        for c1 in range(c):
            for c2 in range(c):
                tot = (contrib[0, c0] + contrib[1, c1] + contrib[2, c2]
                       + np.rint(bias / grid))
                worst = max(worst, float(np.abs(tot).max()))
    assert overflow_bound(q8, scales, bias) == pytest.approx(worst)


def test_pga101_grid_flushes_dead_groups():
    """A dead group (scale floored near 1e-8/127) must not drag the grid
    1e7x below the live groups — its whole amplitude rounds to zero there."""
    live = np.array([1e-2, 3e-2, 2e-2])
    dead = np.array([1e-8 / 127.0])
    assert accumulation_grid(np.concatenate([live, dead])) == \
        pytest.approx(live.min())
    # but a gradual ladder (every step < 254x) keeps every group live
    ladder = np.array([1.0, 1e2, 1e4, 1e6])
    assert accumulation_grid(ladder) == pytest.approx(1.0)


def _overflow_banks(seed=3):
    """First bank carries a per-group amax ladder spanning 1e8 in factor-100
    steps — no group flushable, worst-case accumulator ~1e10 >> int32."""
    return _chain_banks(seed, dims=(10, 6), group_size=2,
                        row_scale=[100.0 ** g for g in range(5)])


def test_pga101_overflow_seeded_violation():
    plan = build_plan(_overflow_banks(), audit="off")
    found = _rules(audit_plan(plan), "PGA101")
    assert found and found[0].severity == "error"
    assert found[0].metrics["bound"] > 2**31 - 1
    # healthy chain: quiet
    clean = build_plan(_chain_banks(0), audit="off")
    assert not _rules(audit_plan(clean), "PGA101")


# ---------------------------------------------------------------------------
# PGA102 — quantization fidelity
# ---------------------------------------------------------------------------


def test_pga102_tampered_q8_table():
    plan = build_plan(_chain_banks(1), audit="off")
    assert not _rules(audit_plan(plan), "PGA102")
    # zero one bank's int8 table: dequant error becomes ~the group amax
    plan.banks[1].lut_q8_p = jnp.zeros_like(plan.banks[1].lut_q8_p)
    found = _rules(audit_plan(plan), "PGA102")
    assert found and found[0].severity == "error"
    assert found[0].site == "bank[1]"
    assert found[0].metrics["rel_err"] > 0.5


# ---------------------------------------------------------------------------
# PGA103 — VMEM footprint
# ---------------------------------------------------------------------------


def test_pga103_vmem_budget():
    plan = build_plan(_chain_banks(2), audit="off")
    assert not _rules(audit_plan(plan), "PGA103")      # 16 MiB: plenty
    found = _rules(audit_plan(plan, AuditConfig(vmem_budget_bytes=4096)),
                   "PGA103")
    assert found and all(f.severity == "error" for f in found)
    assert all(f.metrics["bytes"] > 4096 for f in found)
    # warning band: budget between need and margin*need
    need = max(f.metrics["bytes"] for f in found)
    rep = audit_plan(plan, AuditConfig(vmem_budget_bytes=int(need * 1.5)))
    assert any(f.severity == "warning" for f in _rules(rep, "PGA103"))


# ---------------------------------------------------------------------------
# PGA104 — tile / lane alignment
# ---------------------------------------------------------------------------


def test_pga104_hidden_pad_rows_and_mxu_lanes():
    # bucket 384 vs single-bank tile 256: 128 hidden rows per call
    plan = build_plan(_chain_banks(4), fuse=False, block_t=256,
                      bucket_sizes=(8, 384), audit="off")
    found = _rules(audit_plan(plan), "PGA104")
    hidden = [f for f in found if f.metrics.get("hidden_rows")]
    assert hidden and hidden[0].metrics["hidden_rows"] == 128
    assert hidden[0].severity == "warning"
    # power-of-two ladder: no hidden padding
    clean = build_plan(_chain_banks(4), audit="off")
    assert not _rules(audit_plan(clean), "PGA104")
    # mxu strategy with narrow LUT tiles: lane-alignment warnings
    mxu = build_plan(_chain_banks(4), strategy="mxu", fuse=False,
                     audit="off")
    lanes = [f for f in _rules(audit_plan(mxu), "PGA104")
             if "lanes" in f.metrics]
    assert lanes and all(f.metrics["width"] % 128 for f in lanes)


# ---------------------------------------------------------------------------
# PGA105 — fusion-rejection explanations
# ---------------------------------------------------------------------------


def test_pga105_explanations():
    # fully fused chain: nothing to explain
    fused = build_plan(_chain_banks(5), audit="off")
    assert fused.fused_groups == 1
    assert not _rules(audit_plan(fused), "PGA105")

    # fuse=False: compatible pair, fusion disabled
    off = build_plan(_chain_banks(5), fuse=False, audit="off")
    found = _rules(audit_plan(off), "PGA105")
    assert found and all(f.severity == "info" for f in found)
    assert any("fuse=False" in f.message for f in found)

    # nmax_cap balloon split: widths (8, 4) with cap 4
    capped = build_plan(_chain_banks(6, dims=(8, 8, 4)), fuse_nmax_cap=4,
                        audit="off")
    assert capped.fused_groups == 0
    found = _rules(audit_plan(capped), "PGA105")
    assert found and "fuse_nmax_cap=4" in found[0].message

    # v-mismatch: a group_size=4 bank cannot join a v=2 chain
    v2 = _chain_banks(7, dims=(8, 8))
    v4 = _chain_banks(8, dims=(8, 5), group_size=4)
    mixed = build_plan(v2 + v4, audit="off")
    found = _rules(audit_plan(mixed), "PGA105")
    assert found and "partition width v 2 != 4" in found[0].message


def test_pga105_cnn_l_builder_note():
    """The CNN-L b1→b2 chain ROADMAP names: shape-compatible, but the
    builder compiles banks individually — surfaced as a ratchet candidate."""
    b1, b2 = _chain_banks(9, dims=(8, 8, 8))

    class _FakeCNNL:
        bank1, bank2 = b1, b2
        emb_tree = None
        logit_lut = np.zeros((4, 3), np.float32)
        bias = np.zeros(3, np.float32)

    plan = build_plan(_FakeCNNL(), audit="off")
    assert plan.family == "cnn_l"
    found = _rules(audit_plan(plan), "PGA105")
    assert found and found[0].site == "bank[0]→bank[1]"
    assert "fusion ratchet candidate" in found[0].message


# ---------------------------------------------------------------------------
# PGA106 — dataplane resource fit
# ---------------------------------------------------------------------------


def test_pga106_dataplane_target():
    plan = build_plan(_chain_banks(10), audit="off")
    # no target declared: rule is off entirely
    assert not _rules(audit_plan(plan), "PGA106")
    # tofino2: this toy plan fits — one info finding with utilization
    rep = audit_plan(plan, AuditConfig(target="tofino2"))
    found = _rules(rep, "PGA106")
    assert [f.severity for f in found] == ["info"]
    assert found[0].metrics["sram_pct"] < 100
    # a tiny budget: validate() errors + recirculation warning
    tiny = SwitchBudget(stages=2, sram_bits_per_stage=2048,
                        tcam_bits_per_stage=64, action_bus_bits=64,
                        phv_bits=256)
    found = _rules(audit_plan(plan, AuditConfig(target=tiny)), "PGA106")
    sev = [f.severity for f in found]
    assert "error" in sev and "warning" in sev
    with pytest.raises(ValueError, match="unknown dataplane target"):
        audit_plan(plan, AuditConfig(target="tofino9"))


# ---------------------------------------------------------------------------
# lifecycle: build_plan audit modes, registry caching, stats surfaces
# ---------------------------------------------------------------------------


def test_build_plan_audit_modes():
    bad = _overflow_banks()
    with pytest.raises(PlanAuditError, match="PGA101"):
        build_plan(bad, audit="error")
    with pytest.warns(UserWarning, match="plan audit"):
        plan = build_plan(bad, audit="warn")
    assert plan.audit_report is not None
    assert plan.audit_report.counts["error"] == 1
    off = build_plan(bad, audit="off")
    assert off.audit_report is None
    with pytest.raises(ValueError, match="audit must be"):
        build_plan(_chain_banks(11), audit="loud")


def test_clean_build_attaches_report_without_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = build_plan(_chain_banks(12))           # default audit="warn"
    assert plan.audit_report is not None and plan.audit_report.ok
    st = plan.compile_stats()
    assert st["audit"] == {"error": 0, "warning": 0, "info": 0}
    assert build_plan(_chain_banks(12), audit="off").compile_stats()[
        "audit"] is None


def test_suppress_and_report_shape():
    plan = build_plan(_overflow_banks(), audit="off")
    rep = audit_plan(plan, AuditConfig(suppress=("PGA101",)))
    assert not _rules(rep, "PGA101") and rep.ok
    rep = audit_plan(plan)
    doc = rep.to_dict()
    assert doc["counts"]["error"] == 1 and doc["ok"] is False
    assert doc["summary"]["family"] == "sequential"
    assert json.dumps(doc)                            # JSON-serializable
    assert "PGA101" in str(rep)


def test_registry_audit_kwarg_and_lazy_report():
    banks = _chain_banks(13)
    # audit mode must NOT fork the memo key
    assert plan_for(banks) is plan_for(banks, audit="off")
    reg = PlanRegistry()
    reg.register("m", banks, backend="gather", audit="off")
    assert reg.get("m").audit_report is None
    rep = reg.audit_report("m")                       # lazy, then cached
    assert rep.ok and reg.get("m").audit_report is rep
    assert reg.stats()["m"]["audit"] == rep.counts


# ---------------------------------------------------------------------------
# satellite: quantize_lut_int8 round-trip property test
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(5))
def test_quantize_lut_int8_roundtrip_properties(seed):
    """Per-group symmetric int8: |q| ≤ 127, sign-symmetric, and every
    dequantized element within half a quantization step of the source."""
    rng = np.random.default_rng(seed)
    k, c, n = (int(rng.integers(1, 6)), 2 ** int(rng.integers(1, 4)),
               int(rng.integers(1, 9)))
    lut = (rng.normal(size=(k, c, n)) * 10.0 ** rng.integers(-3, 3)
           ).astype(np.float32)
    q, scale = quantize_lut_int8(jnp.asarray(lut))
    q, scale = np.asarray(q, np.int64), np.asarray(scale, np.float64)
    assert q.dtype == np.int64 and np.abs(q).max() <= 127
    assert scale.shape == (k,) and (scale > 0).all()
    err = np.abs(lut - q * scale[:, None, None])
    # round-to-nearest: err ≤ scale/2 per element, with fp32 slack
    assert (err <= scale[:, None, None] * 0.5 + 1e-6).all()
    # symmetric: quantizing -lut flips the codes, same scales
    q_neg, scale_neg = quantize_lut_int8(jnp.asarray(-lut))
    np.testing.assert_array_equal(np.asarray(q_neg, np.int64), -q)
    np.testing.assert_allclose(np.asarray(scale_neg, np.float64), scale)


def test_quantize_lut_int8_degenerate_group():
    """An all-zero group floors its scale instead of dividing by zero."""
    lut = np.zeros((2, 4, 3), np.float32)
    lut[1] = 5.0
    q, scale = quantize_lut_int8(jnp.asarray(lut))
    assert np.asarray(q)[0].max() == 0
    assert float(np.asarray(scale)[0]) == pytest.approx(1e-8 / 127.0)
    assert np.asarray(q)[1].max() == 127


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_json_smoke(capsys, tmp_path):
    """One small family end-to-end through the CLI: exit 0, JSON parses,
    every rule documented, report file written."""
    from repro.analysis.planaudit import main

    out = tmp_path / "audit.json"
    rc = main(["--families", "mlp", "--backends", "gather", "--flows", "16",
               "--steps", "2", "--json", "--out", str(out)])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert doc["totals"]["error"] == 0 and doc["totals"]["warning"] == 0
    assert set(doc["plans"]) == {"mlp:gather"}
    assert doc["plans"]["mlp:gather"]["summary"]["family"] == "sequential"
    assert set(doc["rules"]) == {f"PGA10{i}" for i in range(1, 7)}
    assert json.loads(out.read_text())["totals"] == doc["totals"]


def test_cli_suppress_changes_exit_code():
    from repro.analysis.planaudit import main

    # seed an erroring family is expensive; instead check the flag plumbing
    # via AuditConfig: suppressed rules vanish from the report entirely
    plan = build_plan(_overflow_banks(), audit="off")
    assert not audit_plan(plan).ok
    assert audit_plan(plan, AuditConfig(suppress=("PGA101",))).ok
    assert callable(main)
