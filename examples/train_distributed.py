"""Distributed training driver: fault-tolerant loop with checkpoint/restart.

Trains a reduced-config LM on all local devices with the production sharding
rules (FSDP × TP), checkpoints periodically, and demonstrates crash recovery
by construction: re-running the same command resumes from the last
checkpoint.

Run:  PYTHONPATH=src python examples/train_distributed.py \
          [--arch granite_20b] [--steps 30] [--ckpt /tmp/pegasus_ckpt]
"""

import argparse

import jax
import numpy as np

from repro.configs.registry import smoke_config
from repro.launch.train import TrainLoop, synthetic_batches
from repro.train import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_20b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/pegasus_ckpt")
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    n = len(jax.devices())
    mesh = jax.make_mesh((1, n), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch={args.arch} (smoke), microbatches={args.microbatches}")

    prev = ckpt.latest_step(args.ckpt)
    if prev is not None:
        print(f"found checkpoint at step {prev} — resuming (crash recovery)")

    loop = TrainLoop(cfg, mesh, ckpt_dir=args.ckpt, ckpt_every=10,
                     microbatches=args.microbatches)
    batches = synthetic_batches(cfg, args.batch, args.seq)
    # fast-forward the data stream on resume (deterministic replay)
    for _ in range(loop.start_step):
        next(batches)
    metrics = loop.run(batches, steps=args.steps)
    print(f"finished at step {int(metrics['step'])}: "
          f"loss={float(metrics['loss']):.4f} "
          f"median step time {np.median(loop.step_times):.3f}s")
    print(f"checkpoints: {ckpt.latest_steps(args.ckpt)}")


if __name__ == "__main__":
    main()
