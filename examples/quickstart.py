"""Quickstart: the paper's pipeline end-to-end in ~60 seconds on CPU.

1. Train a dense MLP-B on synthetic traffic (stats features).
2. Lower it to Pegasus form: fuzzy trees + fused LUT banks (+ backprop refine).
3. Compile to the Tofino-2 MAT emulator; run packets through integer tables.
4. Compare accuracies + print the Table-6-style resource report.
5. Serve the model through the typed request API — an ``InferRequest``
   carrying a deadline and a priority, answered by an ``InferResult`` —
   across the host's device streams (simulate several on CPU with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.dataplane.compile import compile_model
from repro.launch.serve import InferRequest, MultiModelServer
from repro.nets.common import macro_f1
from repro.nets.mlp import mlp_apply, pegasusify_mlp, pegasus_mlp_apply, train_mlp


def main():
    print("== 1. data + dense teacher ==")
    ds = make_dataset("peerrush", flows_per_class=600)
    stats, y = ds.train["stats"], ds.train["label"]
    mlp = train_mlp(stats, y, ds.num_classes, steps=400)
    dense_pred = np.asarray(
        mlp_apply(mlp, jnp.asarray(ds.test["stats"]))).argmax(-1)
    f1_dense = macro_f1(dense_pred, ds.test["label"], ds.num_classes)
    print(f"dense MLP-B macro-F1: {f1_dense:.4f}")

    print("== 2. pegasusify (Partition → fuzzy Map → SumReduce) ==")
    banks = pegasusify_mlp(mlp, stats.astype(np.float32), refine_steps=60)
    peg_pred = np.asarray(
        pegasus_mlp_apply(banks, jnp.asarray(ds.test["stats"], jnp.float32))
    ).argmax(-1)
    f1_peg = macro_f1(peg_pred, ds.test["label"], ds.num_classes)
    print(f"pegasus MLP-B macro-F1: {f1_peg:.4f}  (delta {f1_dense - f1_peg:+.4f})")

    print("== 3. compile to the MAT pipeline (integer tables) ==")
    pipe = compile_model(banks, stateful_bits_per_flow=80)
    out = pipe.run_batch(ds.test["stats"][:32].astype(np.float32))
    int_pred = out.argmax(-1)
    agree = (int_pred == peg_pred[:32]).mean()
    print(f"integer pipeline agrees with float tables on {agree:.0%} of packets")

    print("== 4. switch resource report (Table 6 columns) ==")
    rep = pipe.report()
    print(f"{'model':<14} {'bits/flow':>6} {'SRAM':>7} {'TCAM':>8} {'Bus':>8}")
    print(rep.table6_row("MLP-B"))
    print("constraint violations:", rep.validate() or "none — deployable")

    print("== 5. serve it (typed request API, per-device streams) ==")
    ndev = min(jax.device_count(), 4)
    server = MultiModelServer({"mlp": banks},
                              devices=ndev if ndev > 1 else None)
    try:
        x = jnp.asarray(ds.test["stats"], jnp.float32)
        reqs = [InferRequest("mlp", x[:48], deadline_ms=5000.0,
                             priority="high"),
                InferRequest("mlp", x[48:65], priority="low")]
        for req, res in zip(reqs, server.serve(reqs)):
            wait = (f"{res.queue_wait_ms:.2f}" if res.queue_wait_ms
                    is not None else "n/a")
            print(f"  {req.priority:6s} request: {res.flows:3d} flows → "
                  f"{tuple(res.output.shape)} (queue wait {wait} ms)")
        dev = server.stats()["devices"]
        flows = [d["dispatched_flows"] for d in dev["per_device"]]
        print(f"  {dev['count']} device stream(s)"
              f"{f', flows per stream {flows}' if flows else ''}")
    finally:
        server.close()


if __name__ == "__main__":
    main()
