"""Multi-model serving demo: many Pegasus models behind ONE server.

The paper's pitch is a *shared* dataplane — one switch serving many traffic
classes and models at once (Quark runs whole CNNs on one data plane; FENIX
multiplexes DNN workloads through one pipeline). This demo is the host-side
analog: an MLP classifier, an RNN classifier and an AutoEncoder anomaly
scorer are trained on synthetic traffic, compiled into ExecutionPlans, and
registered under names in one ``MultiModelServer``. A mixed burst of
``(model_name, inputs)`` requests of assorted sizes is then coalesced into
bucket-aligned micro-batches, scheduled round-robin across the models, and
drained — followed by the per-model serving/compile-cache stats.

Run:  PYTHONPATH=src python examples/serve_batched.py [--backend kernel]
"""

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.launch.serve import MultiModelServer
from repro.nets.autoencoder import anomaly_features, pegasusify_ae, train_autoencoder
from repro.nets.mlp import pegasusify_mlp, train_mlp
from repro.nets.rnn import pegasusify_rnn, train_rnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="onehot",
                    choices=["gather", "onehot", "kernel", "kernel_q8"])
    ap.add_argument("--steps", type=int, default=120, help="teacher train steps")
    ap.add_argument("--rounds", type=int, default=3, help="timed burst rounds")
    args = ap.parse_args()

    ds = make_dataset("peerrush", flows_per_class=200)   # test split: 90 flows
    flat = ds.train["seq"].reshape(len(ds.train["label"]), -1)

    print(f"== training 3 teachers (steps={args.steps}) ==")
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                    steps=args.steps)
    rnn = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                    steps=args.steps)
    ae = train_autoencoder(flat, steps=args.steps)

    print(f"== compiling + registering (backend={args.backend}) ==")
    server = MultiModelServer(backend=args.backend)
    t0 = time.perf_counter()
    server.add_model("mlp-stats", pegasusify_mlp(
        mlp, ds.train["stats"].astype(np.float32), refine_steps=0))
    server.add_model("rnn-seq", pegasusify_rnn(rnn, ds.train["seq"], depth=4))
    server.add_model("ae-anomaly", pegasusify_ae(ae, flat.astype(np.float32)))
    print(f"3 plans compiled in {(time.perf_counter() - t0) * 1e3:.0f} ms: "
          f"{server.models()}")

    # a mixed burst: three models × assorted request sizes
    x_stats = jnp.asarray(ds.test["stats"], jnp.float32)
    x_seq = jnp.asarray(ds.test["seq"])
    x_feat = jnp.asarray(anomaly_features(
        ds.test["seq"].reshape(len(ds.test["label"]), -1)))
    sizes = (48, 17, 80)

    def burst():
        for s in sizes:
            server.submit("mlp-stats", x_stats[:s])
            server.submit("rnn-seq", x_seq[:s])
            server.submit("ae-anomaly", x_feat[:s])
        return server.drain()

    burst()  # warmup: traces one XLA computation per (model, bucket)
    t0 = time.perf_counter()
    log_before = server.batches_dispatched
    for _ in range(args.rounds):
        out = burst()
    dt = (time.perf_counter() - t0) / args.rounds
    flows = sum(sizes) * 3
    per_burst = (server.batches_dispatched - log_before) // args.rounds
    print(f"\nserved {len(sizes) * 3} requests ({flows} flows) per burst in "
          f"{dt * 1e3:.1f} ms → {flows / dt:.0f} flows/s aggregate")
    print(f"schedule (fair round-robin, {per_burst} micro-batches/burst): "
          f"{list(server.schedule_log)[-per_burst:]}")
    for name, outs in out.items():
        print(f"  {name:11s} → {len(outs)} outputs, shapes "
              f"{[tuple(o.shape) for o in outs]}")

    print("\nper-model serving stats:")
    st = server.stats()
    for name, s in st["models"].items():
        print(f"  {name:11s} requests={s['requests_served']:3d} "
              f"batches={s['batches_run']:3d} flows={s['flows_served']:5d} "
              f"traces={s['traces']} bucket_hits={s['bucket_hits']} "
              f"build={s['plan_build_ms']:.0f} ms "
              f"tables={s['table_bytes'] / 1024:.0f} KiB")
    print(f"registry: {st['cache']}")


if __name__ == "__main__":
    main()
