"""Multi-model ASYNC serving demo: many Pegasus models behind ONE server.

The paper's pitch is a *shared* dataplane — one switch serving many traffic
classes and models at once (Quark runs whole CNNs on one data plane; FENIX
multiplexes DNN workloads through one pipeline). This demo is the host-side
analog: an MLP classifier (high priority), an RNN classifier and an
AutoEncoder anomaly scorer (low priority) are trained on synthetic
traffic, compiled into ExecutionPlans, and registered under names in one
``AsyncMultiModelServer``. A mixed burst of :class:`InferRequest`s is
submitted from the caller's thread as futures; the background drain loop
coalesces same-model requests into bucket-aligned micro-batches and
schedules the models by weighted fair queueing (deficit round-robin — the
4x-weighted MLP gets 4x the flow share and dispatches first each round).
Every request also carries a per-request ``priority``: "high" requests
jump ahead of "normal"/"low" ones *within* their model's queue, on top of
the cross-model WFQ share. The wrap-up prints the consolidated nested
``stats()`` — serving counters, compile-cache state, queue-wait
percentiles, and (with ``--devices``) the per-device stream utilization.

With ``--deadline-ms B`` every request carries an end-to-end latency
budget: requests the scheduler predicts (or observes) missing it are shed
— async futures fail with ``DeadlineExceededError`` and the client counts
them instead of crashing; the sync flavor reads the per-model shed tally
off ``server.last_shed`` after ``drain()``. The wrap-up then also prints
the per-model SLO counters (admitted/rejected/shed/goodput — see
docs/SERVING.md for the field reference).

With ``--devices K`` the server feeds K per-device executor streams
(chunks placed on the least-loaded device); simulate devices on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Run:  PYTHONPATH=src python examples/serve_batched.py [--backend kernel]
      add --sync for the synchronous submit+drain flavor
      add --deadline-ms 150 for the deadline-bearing client
      add --devices 4 for multi-device serving (see XLA_FLAGS above)
"""

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import make_dataset
from repro.launch.serve import (
    AsyncMultiModelServer, DeadlineExceededError, InferRequest,
    MultiModelServer,
)
from repro.nets.autoencoder import anomaly_features, pegasusify_ae, train_autoencoder
from repro.nets.mlp import pegasusify_mlp, train_mlp
from repro.nets.rnn import pegasusify_rnn, train_rnn

# per-REQUEST priority (queue-jump within a model's own queue) — layered on
# top of the per-MODEL WFQ weight set at add_model time
REQUEST_PRIORITY = {"mlp-stats": "high", "rnn-seq": "normal",
                    "ae-anomaly": "low"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="onehot",
                    choices=["gather", "onehot", "kernel", "kernel_q8"])
    ap.add_argument("--steps", type=int, default=120, help="teacher train steps")
    ap.add_argument("--rounds", type=int, default=3, help="timed burst rounds")
    ap.add_argument("--sync", action="store_true",
                    help="use the synchronous submit+drain path instead of "
                         "the async background loop")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="attach this latency budget (ms) to every request; "
                         "requests that cannot make it are shed with "
                         "DeadlineExceededError instead of served late")
    ap.add_argument("--devices", type=int, default=None,
                    help="serve through this many per-device executor "
                         "streams (simulate on CPU via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    args = ap.parse_args()

    ds = make_dataset("peerrush", flows_per_class=200)   # test split: 90 flows
    flat = ds.train["seq"].reshape(len(ds.train["label"]), -1)

    print(f"== training 3 teachers (steps={args.steps}) ==")
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                    steps=args.steps)
    rnn = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                    steps=args.steps)
    ae = train_autoencoder(flat, steps=args.steps)

    print(f"== compiling + registering (backend={args.backend}"
          f"{f', devices={args.devices}' if args.devices else ''}) ==")
    cls = MultiModelServer if args.sync else AsyncMultiModelServer
    server = cls(backend=args.backend, devices=args.devices)
    t0 = time.perf_counter()
    server.add_model("mlp-stats", pegasusify_mlp(
        mlp, ds.train["stats"].astype(np.float32), refine_steps=0),
        priority="high")             # inline classifier: 4x WFQ weight
    server.add_model("rnn-seq", pegasusify_rnn(rnn, ds.train["seq"], depth=4))
    server.add_model("ae-anomaly", pegasusify_ae(ae, flat.astype(np.float32)),
                     priority="low")  # background anomaly sweep: 0.25x
    sched = server.stats()["scheduler"]["models"]
    print(f"3 plans compiled in {(time.perf_counter() - t0) * 1e3:.0f} ms: "
          f"{server.models()} (weights "
          f"{ {n: c['weight'] for n, c in sched.items()} })")

    # a mixed burst: three models × assorted request sizes, every request a
    # typed InferRequest carrying its own deadline + priority
    x_stats = jnp.asarray(ds.test["stats"], jnp.float32)
    x_seq = jnp.asarray(ds.test["seq"])
    x_feat = jnp.asarray(anomaly_features(
        ds.test["seq"].reshape(len(ds.test["label"]), -1)))
    sizes = (48, 17, 80)
    flows = sum(sizes) * 3

    shed = {"count": 0}           # deadline sheds seen by this client

    def submit_burst():
        futs = []
        for s in sizes:
            for name, xb in (("mlp-stats", x_stats[:s]),
                             ("rnn-seq", x_seq[:s]),
                             ("ae-anomaly", x_feat[:s])):
                req = InferRequest(name, xb, deadline_ms=args.deadline_ms,
                                   priority=REQUEST_PRIORITY[name])
                try:
                    futs.append((name, server.submit(req)))
                except DeadlineExceededError:
                    # admission control: the backlog already predicts a
                    # miss, so the submit is refused before queueing
                    shed["count"] += 1
        return futs

    if args.sync:
        def burst():
            submit_burst()
            out = server.drain()
            # sync submits carry no future; drain() tallies their sheds
            shed["count"] += sum(server.last_shed.values())
            return out
    else:
        server.start()            # background drain loop: always-on serving

        def burst():
            futs = submit_burst()           # thread-safe, returns futures
            by_model: dict = {}
            for name, f in futs:
                try:
                    # typed submits resolve to InferResult (output + flows
                    # + measured queue_wait_ms)
                    res = f.result(timeout=600)
                    by_model.setdefault(name, []).append(res.output)
                except DeadlineExceededError:
                    shed["count"] += 1      # served late is worthless: skip
            return by_model

    burst()  # warmup: traces one XLA computation per (model, bucket)
    server.reset_latency_stats()
    t0 = time.perf_counter()
    log_before = server.batches_dispatched
    for _ in range(args.rounds):
        out = burst()
    dt = (time.perf_counter() - t0) / args.rounds
    per_burst = (server.batches_dispatched - log_before) // args.rounds
    mode = "sync drain" if args.sync else "async loop"
    print(f"\nserved {len(sizes) * 3} requests ({flows} flows) per burst in "
          f"{dt * 1e3:.1f} ms via {mode} → {flows / dt:.0f} flows/s aggregate")
    if args.deadline_ms is not None:
        print(f"deadline budget {args.deadline_ms:.0f} ms: {shed['count']} "
              f"request(s) shed across all rounds — handled by the client, "
              f"served work stayed within budget")
    print(f"schedule (WFQ deficit round-robin, {per_burst} micro-batches/"
          f"burst): {list(server.schedule_log)[-per_burst:]}")
    for name, outs in out.items():
        print(f"  {name:11s} → {len(outs)} outputs, shapes "
              f"{[tuple(o.shape) for o in outs]}")
    if not args.sync:
        server.stop()

    # consolidated nested stats: serving / engine / scheduler / slo / devices
    print("\nper-model serving stats:")
    st = server.stats()
    for name, s in st["serving"]["models"].items():
        em = st["engine"]["models"][name]
        lat = st["scheduler"]["latency"].get(name, {}).get("queue_wait_ms", {})
        wait = (f"p50_wait={lat['p50']:.2f} ms p99={lat['p99']:.2f} ms"
                if lat else "")
        print(f"  {name:11s} requests={s['requests_served']:3d} "
              f"batches={s['batches_run']:3d} flows={s['flows_served']:5d} "
              f"traces={em['traces']} bucket_hits={em['bucket_hits']} "
              f"build={em['plan_build_ms']:.0f} ms "
              f"tables={em['table_bytes'] / 1024:.0f} KiB {wait}")
        slo = st["slo"]["models"].get(name)
        if args.deadline_ms is not None and slo:
            print(f"  {'':11s}   slo: admitted={slo['admitted']} "
                  f"rejected={slo['rejected']} shed={slo['shed']} "
                  f"goodput_flows={slo['goodput_flows']} "
                  f"late_flows={slo['late_flows']} "
                  f"max_wait={slo['max_wait_ms']:.1f} ms")
    print(f"registry: {st['engine']['cache']}")
    print(f"scheduler: {st['scheduler']['models']}")
    dev = st["devices"]
    print(f"devices: {dev['count']} stream(s)")
    for d in dev["per_device"]:
        print(f"  {d['device']:16s} chunks={d['dispatched_chunks']:4d} "
              f"flows={d['dispatched_flows']:6d} "
              f"util={d['utilization']:.0%} pending={d['pending_flows']}")

    server.close()


if __name__ == "__main__":
    main()
