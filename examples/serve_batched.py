"""End-to-end serving driver (the paper's kind is inference): batched greedy
decoding of a small LM with sharded KV caches, with and without the Pegasus
LUT path on its FFNs.

Reports tokens/s and the LUT-vs-dense FFN output error — the LM-scale analog
of the paper's accuracy-vs-throughput tradeoff (Fig. 9).

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch hymba_1_5b]
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.launch.serve import Server
from repro.models.pegasus_layer import (
    dense_ffn_bytes, lut_bytes, pegasus_ffn_apply, pegasusify_ffn_layer,
)
from repro.models.layers import activation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_coder_33b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    print(f"== serving {args.arch} (smoke config) batch={args.batch} ==")
    server = Server(cfg, mesh, kv_len=64, batch_size=args.batch)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (args.batch, 1)).astype(np.int32)
    server.generate(prompts, max_new=2)  # warmup/compile
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape[0]}×{out.shape[1]} tokens in {dt:.2f}s "
          f"→ {args.batch * args.max_new / dt:.1f} tok/s")

    print("== Pegasus LUT path on one FFN layer ==")
    layer0 = jax.tree.map(lambda x: x[0], server.params["layers"])
    if "ffn" not in layer0:
        print("(arch has no dense FFN — skipping LUT demo)")
        return
    rng = np.random.default_rng(1)
    calib = rng.normal(size=(4096, cfg.d_model)).astype(np.float32) * 0.5
    # v=1, depth=8: per-scalar 2^8-entry tables — the paper's 8-bit
    # fixed-point activation scheme; EXACT for the linear part, so the only
    # error is the 256-level activation quantization.
    peg = pegasusify_ffn_layer(cfg, layer0["ffn"], calib,
                               group_size=1, depth=8)
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)).astype(np.float32) * 0.5)
    act = activation(cfg.act)
    p = layer0["ffn"]
    xin = x @ p["w_in"].astype(jnp.float32)
    dense = (act(x @ p["w_gate"].astype(jnp.float32)) * xin if "w_gate" in p
             else act(xin)) @ p["w_out"].astype(jnp.float32)
    lut = pegasus_ffn_apply(peg, x)
    rel = float(jnp.linalg.norm(lut - dense) / jnp.linalg.norm(dense))
    print(f"LUT-FFN relative error vs dense: {rel:.3f}")

    from repro.configs.registry import get_config
    full = get_config(args.arch)
    if full.d_ff:
        d = dense_ffn_bytes(full)
        l8 = lut_bytes(full, group_size=16, depth=4, lut_dtype_bytes=1)
        print(f"full-size FFN bytes/layer: dense bf16 {d/2**20:.0f} MiB vs "
              f"int8 LUT (v=16, C=16) {l8/2**20:.0f} MiB → {d/l8:.1f}x fewer "
              f"bytes at decode (the §Perf lever)")


if __name__ == "__main__":
    main()
