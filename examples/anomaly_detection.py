"""Unsupervised malicious-traffic detection on the dataplane (paper §7.4).

Trains the AutoEncoder on benign traffic only, lowers it to Pegasus tables,
and detects injected malware/DoS flows by MAE reconstruction error — the
zero-day scenario the paper argues only DL (not trees) can handle in-network.

Run:  PYTHONPATH=src python examples/anomaly_detection.py
"""

import numpy as np
import jax.numpy as jnp

from repro.data.synthetic_traffic import anomaly_testset, make_dataset
from repro.nets.autoencoder import (
    auc_score, pegasus_ae_error, pegasusify_ae, train_autoencoder,
)


def main():
    ds = make_dataset("iscxvpn", flows_per_class=500)
    x_train = ds.train["seq"].reshape(len(ds.train["label"]), -1)
    print(f"training AutoEncoder on {len(x_train)} benign flows...")
    ae = train_autoencoder(x_train, steps=600)
    banks = pegasusify_ae(ae, x_train.astype(np.float32))

    for kind in ("malware", "dos"):
        test = anomaly_testset(ds, kind=kind)
        x = test["seq"].reshape(len(test["label"]), -1)
        scores = np.asarray(pegasus_ae_error(banks, jnp.asarray(x, jnp.float32)))
        auc = auc_score(scores, test["label"])
        thr = np.quantile(scores[test["label"] == 0], 0.95)
        caught = (scores[test["label"] == 1] > thr).mean()
        print(f"{kind:<8}: AUC={auc:.3f}; at 5% benign FPR the switch would "
              f"rate-limit {caught:.0%} of attack flows")


if __name__ == "__main__":
    main()
