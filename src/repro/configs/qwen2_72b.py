"""Qwen2-72B [arXiv:2407.10671; hf]: GQA kv=8, QKV bias."""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_72b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064, qkv_bias=True,
)
