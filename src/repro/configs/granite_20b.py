"""Granite-20B code model [arXiv:2405.04324; hf]: llama-arch, MQA (kv=1)."""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="granite_20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, act="gelu",
)
