"""Hymba-1.5B [arXiv:2411.13676; hf]: parallel attn+mamba heads per layer,
sliding-window attention (full attention in a few layers in the original;
we use SWA uniformly + global SSM state → sub-quadratic, runs long_500k)."""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="hymba_1_5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, ssm_state=16, window=1024,
    head_dim=64, subquadratic=True,
)
