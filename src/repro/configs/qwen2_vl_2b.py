"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf]: M-RoPE, dynamic resolution.

Vision frontend is a STUB per assignment: input_specs provides precomputed
patch embeddings; the M-RoPE sectioned rotary structure is implemented.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    rope_kind="mrope", qkv_bias=True, frontend_stub=True, tie_embeddings=True,
)
