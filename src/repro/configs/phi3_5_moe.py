"""Phi-3.5-MoE 42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct]: 16e top-2."""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="phi3_5_moe", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, num_experts=16, top_k=2,
)
