"""xLSTM-1.3B [arXiv:2405.04517]: sLSTM + mLSTM blocks (recurrent, O(1) state).

We interleave (mLSTM, sLSTM) in super-layers (1:1; the paper's 1.3B uses a
mLSTM-dominant mix — noted in DESIGN.md §Arch-applicability). num_layers=24
SUPER-layers = 48 blocks (the published 48L). d_ff=0: blocks carry their own
projections. Sub-quadratic → runs long_500k.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="xlstm_1_3b", family="ssm",
    num_layers=24, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, rope_kind="none", subquadratic=True,
)
