"""Architecture registry: one ArchConfig per assigned architecture.

``get_config(name)`` returns the full published config; ``smoke_config``
shrinks it (same family/topology, tiny dims) for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ArchConfig", "get_config", "smoke_config", "ARCH_IDS", "SHAPES"]

ARCH_IDS = [
    "qwen2_vl_2b",
    "nemotron_4_340b",
    "granite_20b",
    "deepseek_coder_33b",
    "qwen2_72b",
    "xlstm_1_3b",
    "phi3_5_moe",
    "grok_1_314b",
    "hymba_1_5b",
    "whisper_large_v3",
]

# assigned input-shape set (LM family): name → (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // num_heads
    # MoE
    num_experts: int = 0
    top_k: int = 0
    # variants
    act: str = "silu"
    gated_ffn: bool | None = None   # None → gated iff act == "silu"
    qkv_bias: bool = False
    rope_kind: str = "standard"  # standard | mrope | none
    ssm_state: int = 0
    window: int = 0              # sliding-window attention (0 = full)
    encoder_layers: int = 0      # enc-dec (whisper)
    max_decoder_len: int = 448   # whisper decoder envelope
    subquadratic: bool = False   # eligible for long_500k
    frontend_stub: bool = False  # vlm/audio: embeddings provided externally
    tie_embeddings: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_gated_ffn(self) -> bool:
        return self.act == "silu" if self.gated_ffn is None else self.gated_ffn

    def param_count(self) -> int:
        """Approximate N for MODEL_FLOPS = 6·N·D accounting."""
        d, f, l, v = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        fmul = 3 if self.is_gated_ffn else 2
        if self.family == "moe":
            ffn = fmul * d * f * self.num_experts
        elif self.family == "ssm":
            ffn = 0
            attn = 11 * d * d  # mLSTM (5·d²) + sLSTM (6·d²) per super-layer
        else:
            ffn = fmul * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)
        layers = l + self.encoder_layers
        return layers * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """N_active for MoE (6·N_active·D accounting)."""
        if self.family != "moe":
            return self.param_count()
        d, f, l, v = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ffn = (3 if self.is_gated_ffn else 2) * d * f * self.top_k
        emb = v * d * 2
        return l * (attn + ffn) + emb


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    cfg = get_config(name)
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        head_dim=16,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        window=min(cfg.window, 32) if cfg.window else 0,
        max_decoder_len=32,
    )
