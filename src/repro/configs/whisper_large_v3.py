"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec, conv frontend STUBBED
(input_specs provides precomputed frame embeddings). 32 enc + 32 dec layers.

Shape-cell semantics (DESIGN.md §Arch-applicability): seq_len maps to the
ENCODER frame axis (positional embedding extended past the published 1500);
the decoder runs within its published 448-token envelope. long_500k skipped
(full attention).
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="whisper_large_v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866, encoder_layers=32, rope_kind="none",
    act="gelu", frontend_stub=True, max_decoder_len=448,
)
