"""Grok-1 314B [hf:xai-org/grok-1]: 8 experts top-2 MoE."""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="grok_1_314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072, num_experts=8, top_k=2, act="gelu", gated_ffn=True,
)
