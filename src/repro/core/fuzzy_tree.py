"""Fuzzy matching (Pegasus §4.2): greedy SSE axis-aligned clustering trees.

A *fuzzy tree* maps a low-dimensional sub-vector (one Partition group) to the
index of a leaf centroid using only feature/threshold comparisons — the only
operation a dataplane (and, conveniently, a branchless SIMD lane) can do.

Layout: a complete binary tree of depth ``d`` stored in heap order.
Internal node ``n`` (0-based, ``n < 2**d - 1``) holds ``(feature[n],
threshold[n])``; descending left means ``x[feature] <= threshold``.  Leaves
are indexed ``0 .. 2**d - 1`` left-to-right; leaf ``i`` corresponds to heap
node ``(2**d - 1) + i``.  Each leaf stores a centroid (the mean of training
points routed there).

Three entry points:
  * :func:`fit_tree` — numpy, offline, greedy total-SSE splitting (paper Fig. 3).
  * :func:`hard_index` — jnp, branchless descent; used at inference.
  * :func:`soft_index` — jnp, differentiable leaf probabilities (sigmoid
    relaxation, Zhang'21-style matrixized tree) used by backprop refinement
    (paper §4.4 "Backpropagation").
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FuzzyTree", "fit_tree", "hard_index", "soft_index", "leaf_one_hot"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FuzzyTree:
    """Array-form complete clustering tree for one partition group.

    Attributes:
      features:   int32 ``[2**depth - 1]`` — split dimension per internal node.
      thresholds: float32 ``[2**depth - 1]`` — split threshold per internal node.
      centroids:  float32 ``[2**depth, v]`` — leaf centroids.
    """

    features: jax.Array
    thresholds: jax.Array
    centroids: jax.Array

    @property
    def depth(self) -> int:
        return int(np.log2(self.centroids.shape[0]) + 0.5)

    @property
    def num_leaves(self) -> int:
        return self.centroids.shape[0]

    @property
    def group_dim(self) -> int:
        return self.centroids.shape[1]

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.features, self.thresholds, self.centroids), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Offline fitting (numpy — runs once, before deployment)
# ---------------------------------------------------------------------------


def _cluster_sse(x: np.ndarray) -> float:
    """Total SSE of a cluster: sum over dims of squared deviation from mean."""
    if x.shape[0] == 0:
        return 0.0
    return float(((x - x.mean(axis=0, keepdims=True)) ** 2).sum())


def _best_split(x: np.ndarray, max_thresholds: int = 64):
    """Best (feature, threshold) minimizing child-SSE sum for one cluster.

    Exhaustive over features; thresholds are candidate midpoints between
    sorted unique values (subsampled to ``max_thresholds`` for speed).
    Returns (feature, threshold, sse) or None if the cluster cannot split.
    """
    n, v = x.shape
    if n < 2:
        return None
    best = None
    for j in range(v):
        order = np.argsort(x[:, j], kind="stable")
        xs = x[order]
        col = xs[:, j]
        # candidate split positions: between distinct consecutive values
        distinct = np.nonzero(col[1:] > col[:-1])[0]  # split after index i
        if distinct.size == 0:
            continue
        if distinct.size > max_thresholds:
            sel = np.linspace(0, distinct.size - 1, max_thresholds).astype(int)
            distinct = distinct[sel]
        # prefix sums over all dims for O(1) SSE at each split point
        csum = np.cumsum(xs, axis=0)
        csq = np.cumsum(xs * xs, axis=0)
        tot_sum, tot_sq = csum[-1], csq[-1]
        for i in distinct:
            nl = i + 1
            nr = n - nl
            sl, ql = csum[i], csq[i]
            sr, qr = tot_sum - sl, tot_sq - ql
            sse = float((ql - sl * sl / nl).sum() + (qr - sr * sr / nr).sum())
            if best is None or sse < best[2]:
                thr = 0.5 * (col[i] + col[i + 1])
                best = (j, float(thr), sse)
    return best


def fit_tree(data: np.ndarray, depth: int, max_thresholds: int = 64) -> FuzzyTree:
    """Greedy top-down complete-tree clustering (paper §4.2 Parameter Learning).

    Every node at every level is split by the (feature, threshold) that
    minimizes the summed SSE of its two children — the paper's greedy
    strategy, extended to a complete depth-``d`` tree so the leaf index is a
    fixed-width ``d``-bit code (what the MAT/kernel wants).

    Degenerate nodes (too few points / constant data) get ``threshold=+inf``
    so all traffic flows left, and the child centroids replicate the parent
    mean — exactly what a switch table would store.
    """
    data = np.asarray(data, dtype=np.float32)
    assert data.ndim == 2, "fit_tree expects [N, v]"
    n_internal = 2**depth - 1
    features = np.zeros(n_internal, dtype=np.int32)
    thresholds = np.full(n_internal, np.inf, dtype=np.float32)
    centroids = np.zeros((2**depth, data.shape[1]), dtype=np.float32)

    # node -> member rows; start with everything at the root
    members: dict[int, np.ndarray] = {0: data}
    for node in range(n_internal):
        x = members.pop(node, None)
        left, right = 2 * node + 1, 2 * node + 2
        if x is None or x.shape[0] == 0:
            members[left] = np.zeros((0, data.shape[1]), np.float32)
            members[right] = np.zeros((0, data.shape[1]), np.float32)
            continue
        split = _best_split(x, max_thresholds=max_thresholds)
        if split is None:
            # unsplittable: all data goes left (thr=+inf)
            features[node] = 0
            thresholds[node] = np.inf
            members[left], members[right] = x, x[:0]
            continue
        j, thr, _ = split
        features[node] = j
        thresholds[node] = thr
        mask = x[:, j] <= thr
        members[left], members[right] = x[mask], x[~mask]

    global_mean = data.mean(axis=0) if data.shape[0] else np.zeros(data.shape[1])
    for leaf in range(2**depth):
        x = members.get((2**depth - 1) + leaf)
        if x is None or x.shape[0] == 0:
            # inherit: walk up to nearest ancestor with data — global mean is
            # a safe stand-in (leaf unreachable by training distribution).
            centroids[leaf] = global_mean
        else:
            centroids[leaf] = x.mean(axis=0)

    return FuzzyTree(
        features=jnp.asarray(features),
        thresholds=jnp.asarray(thresholds),
        centroids=jnp.asarray(centroids),
    )


# ---------------------------------------------------------------------------
# Inference-time indexing (jnp, branchless)
# ---------------------------------------------------------------------------


def hard_index(tree: FuzzyTree, x: jax.Array) -> jax.Array:
    """Map sub-vectors ``x[..., v]`` to leaf indices ``[...]`` (int32).

    Branchless descent: ``d`` rounds of gather-compare-select, exactly the
    comparator cascade the switch pipeline performs across MAT stages.
    """
    depth = tree.depth
    node = jnp.zeros(x.shape[:-1], dtype=jnp.int32)
    for _ in range(depth):
        feat = tree.features[node]                      # [...]
        thr = tree.thresholds[node]                     # [...]
        val = jnp.take_along_axis(x, feat[..., None], axis=-1)[..., 0]
        go_right = (val > thr).astype(jnp.int32)
        node = 2 * node + 1 + go_right
    return node - (2**depth - 1)


def soft_index(tree: FuzzyTree, x: jax.Array, temperature: float = 1.0) -> jax.Array:
    """Differentiable leaf distribution ``[..., 2**depth]``.

    Each internal decision relaxes to ``sigmoid((x[f] - t) / temperature)``;
    a leaf's probability is the product of its path's branch probabilities.
    As ``temperature → 0`` this converges to the hard one-hot.
    """
    depth = tree.depth
    # probs over nodes at current level, starting with the root (prob 1)
    level_probs = jnp.ones(x.shape[:-1] + (1,), dtype=x.dtype)
    node_base = 0
    for level in range(depth):
        n_nodes = 2**level
        idx = node_base + jnp.arange(n_nodes)
        feat = tree.features[idx]                       # [n_nodes]
        thr = tree.thresholds[idx]                      # [n_nodes]
        vals = x[..., feat]                             # [..., n_nodes]
        # finite-threshold guard: thr=+inf (degenerate node) → always left
        p_right = jax.nn.sigmoid((vals - thr) / temperature)
        p_right = jnp.where(jnp.isfinite(thr), p_right, 0.0)
        p_left = 1.0 - p_right
        # interleave: child order is [L0, R0, L1, R1, ...]
        level_probs = jnp.stack(
            [level_probs * p_left, level_probs * p_right], axis=-1
        ).reshape(x.shape[:-1] + (2 * n_nodes,))
        node_base += n_nodes
    return level_probs


def leaf_one_hot(tree: FuzzyTree, x: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Hard one-hot leaf encoding ``[..., 2**depth]`` (the MXU-side form)."""
    idx = hard_index(tree, x)
    return jax.nn.one_hot(idx, tree.num_leaves, dtype=dtype)


# ---------------------------------------------------------------------------
# Stacked (vmapped) trees — one tree per Partition group, fit offline,
# stored as stacked arrays so the whole Map bank is a single pytree leaf set.
# ---------------------------------------------------------------------------


def stack_trees(trees: list[FuzzyTree]) -> FuzzyTree:
    """Stack K single-group trees into arrays with a leading K axis."""
    return FuzzyTree(
        features=jnp.stack([t.features for t in trees]),
        thresholds=jnp.stack([t.thresholds for t in trees]),
        centroids=jnp.stack([t.centroids for t in trees]),
    )


@partial(jax.jit, static_argnames=())
def hard_index_stacked(stacked: FuzzyTree, x: jax.Array) -> jax.Array:
    """Index with K stacked trees. ``x: [..., K, v]`` → ``[..., K]`` int32."""
    k = stacked.features.shape[0]
    depth = int(np.log2(stacked.centroids.shape[1]) + 0.5)
    node = jnp.zeros(x.shape[:-1], dtype=jnp.int32)      # [..., K]
    karange = jnp.arange(k)
    for _ in range(depth):
        feat = stacked.features[karange, node]           # [..., K]
        thr = stacked.thresholds[karange, node]
        val = jnp.take_along_axis(x, feat[..., None], axis=-1)[..., 0]
        go_right = (val > thr).astype(jnp.int32)
        node = 2 * node + 1 + go_right
    return node - (2**depth - 1)


def soft_index_stacked(
    stacked: FuzzyTree, x: jax.Array, temperature: float = 1.0
) -> jax.Array:
    """Soft leaf distributions for K stacked trees: ``[..., K, C]``."""
    return jax.vmap(
        lambda t_f, t_t, t_c, xs: soft_index(
            FuzzyTree(t_f, t_t, t_c), xs, temperature
        ),
        in_axes=(0, 0, 0, -2),
        out_axes=-2,
    )(stacked.features, stacked.thresholds, stacked.centroids, x)
