"""Adaptive fixed-point quantization (paper §4.4).

The dataplane has no floats: every value crossing a table boundary is a
fixed-point integer. Pegasus stores table *contents* at full precision and
quantizes only the table **outputs** feeding SumReduce — so the quantization
error enters once per fused lookup, not once per arithmetic op.

"Adaptive" = per-edge binary point: each edge (layer boundary) gets its own
fractional-bit count chosen from a calibration pass so the observed range
just fits the register width (paper's example: input range [-100, 100] vs
output range [0, 5] want different binary points).

`quantize` is implemented with a straight-through estimator so the
backprop-refinement stage (core.finetune) can differentiate through it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FixedPointSpec", "choose_qspec", "quantize", "dequantize", "fake_quant"]


@dataclasses.dataclass(frozen=True)
class FixedPointSpec:
    """A fixed-point format: signed two's-complement, ``bits`` total width,
    ``frac_bits`` fractional bits (binary point position)."""

    bits: int
    frac_bits: int

    @property
    def scale(self) -> float:
        return float(2.0**self.frac_bits)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def choose_qspec(calibration: np.ndarray | jax.Array, bits: int = 16) -> FixedPointSpec:
    """Pick the binary point so max|x| fits: the paper's Post-Training Static
    Quantization analogue — ranges are measured once on calibration data."""
    amax = float(jnp.max(jnp.abs(calibration))) if np.size(calibration) else 1.0
    amax = max(amax, 1e-8)
    int_bits = int(np.ceil(np.log2(amax + 1e-12))) + 1  # +1 for sign
    frac = bits - 1 - max(int_bits - 1, 0)
    # clamp: at least 0 fractional bits, at most bits-1
    frac = int(np.clip(frac, 0, bits - 1))
    return FixedPointSpec(bits=bits, frac_bits=frac)


def quantize(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    """Float → int (represented in int32 for arithmetic headroom)."""
    q = jnp.round(x * spec.scale)
    return jnp.clip(q, spec.qmin, spec.qmax).astype(jnp.int32)


def dequantize(q: jax.Array, spec: FixedPointSpec) -> jax.Array:
    return q.astype(jnp.float32) / spec.scale


@jax.custom_vjp
def fake_quant(x: jax.Array, scale: float, qmin: float, qmax: float) -> jax.Array:
    """Quantize-dequantize with straight-through gradient."""
    return jnp.clip(jnp.round(x * scale), qmin, qmax) / scale


def _fq_fwd(x, scale, qmin, qmax):
    return fake_quant(x, scale, qmin, qmax), (x, scale, qmin, qmax)


def _fq_bwd(res, g):
    x, scale, qmin, qmax = res
    # pass-through inside the representable range, zero outside (clip STE)
    inside = (x * scale >= qmin) & (x * scale <= qmax)
    return (jnp.where(inside, g, 0.0), None, None, None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def fake_quant_spec(x: jax.Array, spec: FixedPointSpec) -> jax.Array:
    return fake_quant(x, spec.scale, float(spec.qmin), float(spec.qmax))
