"""Pegasus primitives (paper §4.1): Partition, Map, SumReduce.

Two layers:

1. **Functional forms** (`partition`, `map_apply`, `sum_reduce`) — plain JAX
   ops used by models directly.

2. **PrimitiveGraph IR** — a linear op-list describing a model as a primitive
   program. The fusion passes (`repro.core.fusion`) rewrite this IR; the
   dataplane compiler (`repro.dataplane.compile`) lowers it to MAT stages and
   counts switch resources. The IR deliberately mirrors the paper's Figure 5
   boxes so fusion results can be checked against the paper's worked example.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "partition",
    "unpartition",
    "map_apply",
    "sum_reduce",
    "Prim",
    "PartitionOp",
    "MapOp",
    "SumReduceOp",
    "PrimitiveGraph",
]


# ---------------------------------------------------------------------------
# Functional primitives
# ---------------------------------------------------------------------------


def partition(x: jax.Array, dim: int, stride: int | None = None) -> jax.Array:
    """Partition(X) = {X_1 .. X_k}: split the last axis into groups.

    ``dim`` is the group width; ``stride`` defaults to ``dim`` (disjoint
    groups, the common case). With ``stride < dim`` groups overlap — this is
    how a 1-D convolution's sliding window is expressed as a Partition
    (paper §6.2's ``Partition(meta.input_vec, dim=2, stride=2)``).

    Returns ``[..., K, dim]``.
    """
    stride = dim if stride is None else stride
    d = x.shape[-1]
    k = (d - dim) // stride + 1
    idx = jnp.arange(k)[:, None] * stride + jnp.arange(dim)[None, :]  # [K, dim]
    return x[..., idx]


def unpartition(xg: jax.Array) -> jax.Array:
    """Inverse of disjoint partition: ``[..., K, v] → [..., K*v]``."""
    return xg.reshape(*xg.shape[:-2], xg.shape[-2] * xg.shape[-1])


def map_apply(fns: Sequence[Callable[[jax.Array], jax.Array]] | Callable, xg: jax.Array) -> jax.Array:
    """Map(F, {X_1..X_k}): apply ``fns[i]`` to group ``i`` (last-2 axis).

    ``fns`` may be a single callable (broadcast to all groups, the usual
    elementwise-transform case) or one callable per group (the weighted-
    aggregation case where each group has its own weight slice).
    """
    k = xg.shape[-2]
    if callable(fns):
        fns = [fns] * k
    outs = [fns[i](xg[..., i, :]) for i in range(k)]
    return jnp.stack(outs, axis=-2)


def sum_reduce(xg: jax.Array) -> jax.Array:
    """SumReduce({X_1..X_k}) = sum_i X_i over the group axis (last-2)."""
    return xg.sum(axis=-2)


# ---------------------------------------------------------------------------
# Primitive IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Prim:
    """Base IR node."""

    name: str = dataclasses.field(default="", kw_only=True)


@dataclasses.dataclass
class PartitionOp(Prim):
    """Split last axis into K groups of width ``dim`` (stride ``stride``)."""

    dim: int
    stride: int | None = None


@dataclasses.dataclass
class MapOp(Prim):
    """Per-group function application.

    Attributes:
      fn: the python/jnp callable (group-batched: ``[..., v] → [..., o]``).
      linear: whether ``fn(a + b) == fn(a) + fn(b)`` (enables Linear
        Reordering, paper §4.3(1)). Affine maps are recorded with
        ``linear=True`` plus a ``bias`` so reordering can hoist the constant.
      in_dim / out_dim: per-group widths (for table sizing).
      table_entries: entries a dataplane lookup needs (2**tree_depth under
        fuzzy matching; 2**(8*in_dim) under exhaustive mapping).
    """

    fn: Callable[[jax.Array], jax.Array]
    linear: bool
    in_dim: int
    out_dim: int
    table_entries: int
    bias: Any = None  # constant term hoisted by linear reordering


@dataclasses.dataclass
class SumReduceOp(Prim):
    """Sum over the group axis."""


@dataclasses.dataclass
class PrimitiveGraph:
    """A straight-line primitive program (the paper's Fig. 5 boxes).

    ``ops`` run left-to-right. ``evaluate`` interprets the program on a
    concrete input — the semantic ground truth every fusion pass must
    preserve (checked in tests/test_fusion.py).
    """

    ops: list[Prim]

    def evaluate(self, x: jax.Array) -> jax.Array:
        for op in self.ops:
            if isinstance(op, PartitionOp):
                x = partition(x, op.dim, op.stride)
            elif isinstance(op, MapOp):
                x = op.fn(x)
                if op.bias is not None:
                    x = x + op.bias
            elif isinstance(op, SumReduceOp):
                x = sum_reduce(x)
            else:  # pragma: no cover
                raise TypeError(f"unknown primitive {op!r}")
        return x

    # resource-relevant summary ------------------------------------------------
    def num_lookups(self) -> int:
        """Dataplane table lookups = number of Map ops (paper counts these)."""
        return sum(isinstance(op, MapOp) for op in self.ops)

    def table_entries(self) -> int:
        return sum(op.table_entries for op in self.ops if isinstance(op, MapOp))

    def describe(self) -> str:
        parts = []
        for op in self.ops:
            if isinstance(op, PartitionOp):
                parts.append(f"Partition(dim={op.dim})")
            elif isinstance(op, MapOp):
                tag = "lin" if op.linear else "nonlin"
                parts.append(f"Map[{tag}]({op.name or op.fn.__name__})")
            elif isinstance(op, SumReduceOp):
                parts.append("SumReduce")
        return " -> ".join(parts)
