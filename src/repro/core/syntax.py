"""Pegasus Syntax (paper §6.2, Fig. 6): a declarative model description that
translates to primitives and then to the dataplane.

The paper's C-like snippet

    meta.output_vec = SumReduce(
        Map(
            Partition(meta.input_vec, dim=2, stride=2),
            clustering_depth=4, CNN_dimension=3, ...))

maps 1:1 onto the spec dicts accepted here. The translator resolves output
dimensions automatically (the paper's point: developers declare intent; the
tool sizes tables and allocates stages), builds a ``PrimitiveGraph``, and —
given trained weights + calibration data — emits deployable MapTable banks
via ``repro.dataplane.compile``.

Example:

    spec = program(
        partition(dim=2, stride=2),
        map_op(clustering_depth=4, fn=..., out_dim=16, linear=True),
        sumreduce(),
        map_op(clustering_depth=8, fn=jax.nn.relu, out_dim=16),
    )
    graph = translate(spec, input_dim=16)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from .primitives import MapOp, PartitionOp, Prim, PrimitiveGraph, SumReduceOp

__all__ = ["partition", "map_op", "sumreduce", "program", "translate",
           "SyntaxError_"]


class SyntaxError_(ValueError):
    """Raised when a Pegasus-Syntax program is ill-formed."""


def partition(*, dim: int, stride: int | None = None) -> dict:
    return {"op": "Partition", "dim": dim, "stride": stride}


def map_op(*, clustering_depth: int, fn: Callable, out_dim: int | None = None,
           linear: bool = False, bias: Any = None, name: str = "") -> dict:
    return {"op": "Map", "clustering_depth": clustering_depth, "fn": fn,
            "out_dim": out_dim, "linear": linear, "bias": bias, "name": name}


def sumreduce() -> dict:
    return {"op": "SumReduce"}


def program(*ops: dict) -> list[dict]:
    return list(ops)


def _infer_out_dim(fn: Callable, in_dim: int) -> int:
    """The translator 'automatically calculates the output dimensions'
    (paper §6.2) — by abstract evaluation on a ShapeDtypeStruct."""
    probe = jax.eval_shape(fn, jax.ShapeDtypeStruct((1, 1, in_dim), jnp.float32))
    return int(probe.shape[-1])


def translate(spec: Sequence[dict], *, input_dim: int) -> PrimitiveGraph:
    """Pegasus Syntax → PrimitiveGraph, with dimension/shape checking."""
    ops: list[Prim] = []
    cur_dim = input_dim          # width of the current (per-group) vector
    grouped = False
    for i, node in enumerate(spec):
        kind = node.get("op")
        if kind == "Partition":
            if grouped:
                raise SyntaxError_(f"op {i}: nested Partition is not supported")
            dim, stride = node["dim"], node["stride"] or node["dim"]
            if (cur_dim - dim) % stride != 0:
                raise SyntaxError_(
                    f"op {i}: Partition(dim={dim}, stride={stride}) does not "
                    f"tile an input of width {cur_dim}")
            ops.append(PartitionOp(dim=dim, stride=node["stride"]))
            cur_dim = dim
            grouped = True
        elif kind == "Map":
            depth = node["clustering_depth"]
            if not (1 <= depth <= 16):
                raise SyntaxError_(f"op {i}: clustering_depth {depth} out of range")
            out_dim = node["out_dim"] or _infer_out_dim(node["fn"], cur_dim)
            ops.append(MapOp(
                fn=node["fn"], linear=node["linear"], in_dim=cur_dim,
                out_dim=out_dim, table_entries=2**depth, bias=node["bias"],
                name=node["name"] or f"map{i}"))
            cur_dim = out_dim
        elif kind == "SumReduce":
            if not grouped:
                raise SyntaxError_(f"op {i}: SumReduce before any Partition")
            ops.append(SumReduceOp())
            grouped = False
        else:
            raise SyntaxError_(f"op {i}: unknown op {kind!r}")
    return PrimitiveGraph(ops)
