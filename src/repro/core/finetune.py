"""Backprop refinement of fuzzy-tree parameters (paper §4.4 "Backpropagation").

The hard clustering tree is relaxed into matrix operations (soft, sigmoid-
temperature routing — the Zhang'21 construction), so thresholds, centroids
and LUT contents become differentiable. We minimize the distillation MSE
between the Pegasus layer's soft output and the full-precision teacher
output over calibration data, annealing the temperature so the soft routing
converges to the hard one actually deployed.

This is intentionally a small, dependency-free Adam loop — it runs offline
(deployment-time), never on the serving path.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .amm import PegasusLinear, apply_gather, apply_soft
from .fuzzy_tree import FuzzyTree

__all__ = ["refine"]


def _adam_update(g, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * (g * g)
    mhat = m / (1 - b1**step)
    vhat = v / (1 - b2**step)
    return lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def refine(
    layer: PegasusLinear,
    x_calib: jax.Array,
    y_teacher: jax.Array,
    *,
    steps: int = 200,
    lr: float = 3e-3,
    temp_start: float = 0.5,
    temp_end: float = 0.05,
    batch_size: int = 512,
    seed: int = 0,
) -> PegasusLinear:
    """Fine-tune thresholds, centroids and LUT against the teacher output.

    Features (discrete) stay fixed; thresholds/centroids/LUT/bias float.
    Returns a new PegasusLinear whose HARD forward better matches teacher.
    """
    params = {
        "thresholds": layer.trees.thresholds,
        "lut": layer.lut.astype(jnp.float32),
        "bias": (jnp.zeros(layer.out_features) if layer.bias is None else layer.bias),
    }

    feats = layer.trees.features
    centroids = layer.trees.centroids
    gsize = layer.group_size
    n = x_calib.shape[0]
    key = jax.random.PRNGKey(seed)

    def rebuild(p):
        return PegasusLinear(
            trees=FuzzyTree(feats, p["thresholds"], centroids),
            lut=p["lut"],
            bias=p["bias"],
            group_size=gsize,
        )

    def loss_fn(p, xb, yb, temp):
        out = apply_soft(rebuild(p), xb, temperature=temp)
        return jnp.mean((out - yb) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    for step in range(1, steps + 1):
        key, sub = jax.random.split(key)
        ix = jax.random.randint(sub, (min(batch_size, n),), 0, n)
        xb, yb = x_calib[ix], y_teacher[ix]
        frac = step / steps
        temp = float(temp_start * (temp_end / temp_start) ** frac)
        _, grads = grad_fn(params, xb, yb, temp)
        new_params = {}
        for name in params:
            upd, m[name], v[name] = _adam_update(
                grads[name], m[name], v[name], step, lr
            )
            new_params[name] = params[name] - upd
        params = new_params

    refined = rebuild(params)
    # keep the original storage dtype for the LUT
    refined = PegasusLinear(
        trees=refined.trees,
        lut=refined.lut.astype(layer.lut.dtype),
        bias=refined.bias,
        group_size=gsize,
    )
    return refined


def hard_mse(layer: PegasusLinear, x: jax.Array, y_teacher: jax.Array) -> float:
    """Deployment-form error: hard routing, as the switch/kernel executes."""
    return float(jnp.mean((apply_gather(layer, x) - y_teacher) ** 2))
