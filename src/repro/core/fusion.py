"""Primitive Fusion (paper §4.3).

Basic Primitive Fusion — semantics-preserving rewrites:
  (1) *Linear Reordering*: ``SumReduce ∘ Map_f`` with linear ``f`` commutes to
      ``Map_f ∘ SumReduce`` — or, in the direction fusion wants it,
      ``Map_f(SumReduce(xs)) == SumReduce(Map_f(xs))``, letting the f-lookup
      merge into the per-group lookups that precede the SumReduce.
      Affine maps (linear + bias) hoist the bias: it is added once, after the
      reduce, rather than per group.
  (2) *Map Merging*: consecutive Maps compose into one Map (one lookup).

Advanced Primitive Fusion — architecture-modifying rewrites:
  (a) *Nonlinear Removal*: delete nonlinear Maps; everything collapses to a
      single linear lookup (fast, but a linear model — accuracy drops).
  (b) *SumReduce Reduction* (NAM form): keep only the FINAL SumReduce. Each
      partition group becomes an independent sub-model folded into ONE Map
      (one lookup per group), and the single trailing SumReduce mixes them —
      the Neural-Additive-Model structure the paper adopts for CNN-M/L and
      the AutoEncoder.

Every pass takes and returns a :class:`PrimitiveGraph`; tests assert
``fused.evaluate(x) ≈ original.evaluate(x)`` for Basic fusion, and assert the
structural lookup counts for Advanced fusion (which intentionally changes
semantics, so equivalence is checked against a *retrained* NAM instead).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .primitives import MapOp, PartitionOp, Prim, PrimitiveGraph, SumReduceOp

__all__ = [
    "fuse_basic",
    "merge_consecutive_maps",
    "linear_reorder",
    "advanced_remove_nonlinear",
    "advanced_nam",
]


def identity(x):
    """Marker fn for pure bias-add ops (constant adds are actions, not lookups)."""
    return x


def _compose(outer: MapOp, inner: MapOp) -> MapOp:
    """Map merging: outer(inner(x) + b_i) as one table (one lookup).

    If ``outer`` is linear, the inner bias hoists:
    ``fo(fi(x) + b_i) = fo(fi(x)) + fo(b_i)`` — keeping the fused op's
    linearity flag honest (fn strictly linear, constants in ``bias``).
    """
    fi, fo = inner.fn, outer.fn
    bi = inner.bias

    if outer.linear and bi is not None:
        def fused(x):
            return fo(fi(x))

        hoisted = fo(bi)
        bias = hoisted if outer.bias is None else hoisted + outer.bias
        lin = inner.linear  # fn part is fo∘fi: linear iff both are
    else:
        def fused(x):
            y = fi(x)
            if bi is not None:
                y = y + bi
            return fo(y)

        bias = outer.bias
        lin = outer.linear and inner.linear and bi is None

    return MapOp(
        fn=fused,
        linear=lin,
        in_dim=inner.in_dim,
        out_dim=outer.out_dim,
        # the fused table is indexed by the INNER input → inner's entry count
        table_entries=inner.table_entries,
        bias=bias,
        name=f"{outer.name or 'map'}∘{inner.name or 'map'}",
    )


def merge_consecutive_maps(graph: PrimitiveGraph) -> PrimitiveGraph:
    """Basic fusion (2): collapse runs of Maps into single Maps."""
    ops: list[Prim] = []
    for op in graph.ops:
        if isinstance(op, MapOp) and ops and isinstance(ops[-1], MapOp):
            ops[-1] = _compose(op, ops[-1])
        else:
            ops.append(dataclasses.replace(op) if isinstance(op, MapOp) else op)
    return PrimitiveGraph(ops)


def linear_reorder(graph: PrimitiveGraph) -> PrimitiveGraph:
    """Basic fusion (1): swap ``SumReduce ; Map_linear`` → ``Map ; SumReduce``.

    After the swap the Map sits next to whatever produced the groups and a
    later `merge_consecutive_maps` absorbs it into the per-group tables.
    The bias of an affine map must NOT be distributed over k groups (it would
    be added k times); it is hoisted to a post-reduce constant instead, which
    the evaluator applies via the group-Map's ``bias`` on a SumReduce
    successor — here we emulate by dividing bias by k is WRONG, so we keep a
    dedicated affine-bias Map after the reduce only when a bias exists.
    """
    ops: list[Prim] = []
    i = 0
    while i < len(graph.ops):
        op = graph.ops[i]
        nxt = graph.ops[i + 1] if i + 1 < len(graph.ops) else None
        if (
            isinstance(op, SumReduceOp)
            and isinstance(nxt, MapOp)
            and nxt.linear
            and nxt.fn is not identity  # pure bias-adds don't benefit
        ):
            moved = dataclasses.replace(nxt, bias=None, name=(nxt.name or "map") + "<swap")
            ops.append(moved)
            ops.append(SumReduceOp())
            if nxt.bias is not None:
                # bias applied once, after the reduce
                ops.append(
                    MapOp(
                        fn=identity,
                        linear=True,
                        in_dim=nxt.out_dim,
                        out_dim=nxt.out_dim,
                        table_entries=0,  # constant add: action, not a lookup
                        bias=nxt.bias,
                        name="bias",
                    )
                )
            i += 2
        else:
            ops.append(op)
            i += 1
    return PrimitiveGraph(ops)


def _drop_trailing_noops(graph: PrimitiveGraph) -> PrimitiveGraph:
    return graph


def fuse_basic(graph: PrimitiveGraph, max_iters: int = 10) -> PrimitiveGraph:
    """Iterate linear-reorder + map-merge to a fixed point (paper Fig. 5 ①)."""
    prev = -1
    g = graph
    for _ in range(max_iters):
        g = merge_consecutive_maps(linear_reorder(g))
        n = len(g.ops)
        if n == prev:
            break
        prev = n
    return _drop_trailing_noops(g)


# ---------------------------------------------------------------------------
# Advanced fusion (architecture-modifying)
# ---------------------------------------------------------------------------


def advanced_remove_nonlinear(graph: PrimitiveGraph) -> PrimitiveGraph:
    """Advanced fusion (a): delete every nonlinear Map, then basic-fuse.

    The result is a purely linear pipeline — a single lookup once basic
    fusion runs. Accuracy consequences are the model designer's problem
    (paper Fig. 5 ②: "may significantly drop").
    """
    ops = [
        op
        for op in graph.ops
        if not (isinstance(op, MapOp) and not op.linear)
    ]
    return fuse_basic(PrimitiveGraph(ops))


def advanced_nam(
    graph: PrimitiveGraph, sub_model_fns=None
) -> PrimitiveGraph:
    """Advanced fusion (b): NAM reduction (paper Fig. 5 ③).

    Structure: ``Partition → Map(sub-model per group) → SumReduce``. All
    intermediate SumReduces are removed; each group's whole computation chain
    becomes one fused Map. Because dropping inner SumReduces changes
    semantics, the per-group sub-model is either supplied by the caller
    (``sub_model_fns`` — typically a retrained per-group network) or derived
    by restricting the original chain to a single group's slice.
    """
    part = next((op for op in graph.ops if isinstance(op, PartitionOp)), None)
    if part is None:
        raise ValueError("NAM reduction needs a leading Partition")
    first_map = next(op for op in graph.ops if isinstance(op, MapOp))
    out_dim = graph.ops[-1].out_dim if isinstance(graph.ops[-1], MapOp) else None

    if sub_model_fns is None:
        # default: run the original post-partition chain on each group alone,
        # treating inner SumReduces as identity (the structural NAM surrogate
        # that is then refined by backprop — core.finetune).
        inner = [
            op
            for op in graph.ops
            if isinstance(op, MapOp)
        ]

        def sub_model(xg):
            y = xg
            for op in inner:
                y = op.fn(y)
                if op.bias is not None:
                    y = y + op.bias
            return y

        fn = sub_model
        entries = first_map.table_entries
    else:
        fn = sub_model_fns
        entries = first_map.table_entries

    fused_map = MapOp(
        fn=fn,
        linear=False,
        in_dim=part.dim,
        out_dim=out_dim or first_map.out_dim,
        table_entries=entries,
        name="nam-submodel",
    )
    return PrimitiveGraph([part, fused_map, SumReduceOp()])
