"""LUT construction: precompute Map results at leaf centroids (paper §4.2/§4.4).

A Map's table stores ``f(centroid_c)`` for each leaf ``c`` of the group's
fuzzy tree, computed **with full-precision weights** offline; only the stored
outputs are (optionally) fixed-point quantized — this is the paper's
"full-precision weights, fixed-point activations" accuracy design.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .fuzzy_tree import FuzzyTree
from .quantization import FixedPointSpec, choose_qspec, dequantize, quantize

__all__ = ["build_lut", "build_matmul_lut", "quantize_lut"]


def build_lut(tree: FuzzyTree, fn: Callable[[jax.Array], jax.Array]) -> jax.Array:
    """Table of ``fn`` evaluated at every centroid: ``[C, out_dim]``.

    ``fn`` must be batched over centroids (pure jnp) — it is evaluated once,
    offline, at full precision.
    """
    out = fn(tree.centroids)  # [C, v] -> [C, o]
    if out.ndim == 1:
        out = out[:, None]
    return out


def build_matmul_lut(
    trees_centroids: jax.Array, weight: jax.Array, group_size: int
) -> jax.Array:
    """Weighted-aggregation LUT bank for an approximate matmul.

    Args:
      trees_centroids: ``[K, C, v]`` stacked leaf centroids (one tree per
        partition group).
      weight: ``[D, N]`` full-precision weight, ``D = K * v``.
      group_size: ``v``.

    Returns ``[K, C, N]`` where ``lut[k, c] = centroids[k, c] @ W[kv:(k+1)v]``.
    The model's output is then ``sum_k lut[k, idx_k] (+ bias)`` — Map followed
    by SumReduce, with the matmul folded away at full precision.
    """
    k, c, v = trees_centroids.shape
    d, n = weight.shape
    assert d == k * v, f"weight rows {d} != K*v = {k * v}"
    w_groups = weight.reshape(k, v, n)
    return jnp.einsum("kcv,kvn->kcn", trees_centroids, w_groups)


def quantize_lut(lut: jax.Array, bits: int = 16) -> tuple[jax.Array, FixedPointSpec]:
    """Fixed-point-quantize stored outputs (adaptive binary point, §4.4)."""
    spec = choose_qspec(lut, bits=bits)
    return quantize(lut, spec), spec


def dequantize_lut(qlut: jax.Array, spec: FixedPointSpec) -> jax.Array:
    return dequantize(qlut, spec)
