"""PegasusLinear — the paper's MatMul-as-primitives, TPU-native form.

Weighted Aggregation (paper §5) decomposes a matmul ``y = x @ W + b`` as:

    Partition:  x  →  {x_1 .. x_K}           (groups of ``v`` features)
    Map:        x_k →  LUT_k[fuzzy_index(x_k)]   where LUT_k[c] = c_k,c @ W_k
    SumReduce:  y  =  Σ_k  (+ b)

All multiplications happen OFFLINE when the LUT is built at full precision;
inference is comparisons + lookups + adds — on a switch: MAT stages; on TPU:
a branchless tree descent + gather/one-hot-matmul.

Arithmetic/bytes bookkeeping (drives the §Roofline analysis):
  dense:    flops = 2·T·D·N          bytes(weights) = D·N·s
  pegasus:  flops ≈ T·K·depth (cmp)  bytes(tables)  = K·C·N·s  = (C/v)·D·N·s
so with ``C = 2**depth`` < ``v`` … the LUT is *larger* than W unless N is
shared across groups; the real wins are (a) all matmul FLOPs removed —
decode-time compute drops to gathers, and (b) with int8 LUTs, bytes halve vs
bf16 weights at C=16, v=8 → (16/8)·0.5 = 1.0× — break-even bytes but
zero-FLOP. See EXPERIMENTS.md §Perf for measured terms; the hillclimb uses
(v, depth, LUT dtype) as its search axes.

Three apply paths, all semantics-identical (tested against each other):
  * ``apply_gather``  — take_along_axis reference (ref.py oracle calls this)
  * ``apply_onehot``  — one-hot × LUT matmul (MXU-friendly XLA path)
  * kernels.fuzzy_lut — fused Pallas kernel (tree descent + LUT accumulate)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .fuzzy_tree import FuzzyTree, fit_tree, hard_index_stacked, soft_index_stacked, stack_trees
from .lut import build_matmul_lut
from .quantization import FixedPointSpec, choose_qspec, fake_quant_spec

__all__ = ["PegasusLinear", "init_pegasus_linear", "pegasus_linear_apply"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PegasusLinear:
    """Parameters of one Pegasus-approximated linear layer.

    Attributes:
      trees: stacked fuzzy trees — features ``[K, 2^d - 1]`` int32,
        thresholds ``[K, 2^d - 1]`` f32, centroids ``[K, C, v]`` f32.
      lut: ``[K, C, N]`` precomputed partial products (full precision or
        quantize-dequantized to the activation fixed-point grid).
      bias: ``[N]`` or None.
    """

    trees: FuzzyTree
    lut: jax.Array
    bias: jax.Array | None

    # static metadata
    group_size: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def num_groups(self) -> int:
        return self.lut.shape[0]

    @property
    def num_centroids(self) -> int:
        return self.lut.shape[1]

    @property
    def out_features(self) -> int:
        return self.lut.shape[2]

    @property
    def in_features(self) -> int:
        return self.num_groups * self.group_size

    def tree_flatten(self):
        return (self.trees, self.lut, self.bias), (self.group_size,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, group_size=aux[0])

    def compile(self, *, backend: str = "onehot", **kw):
        """Compile this layer into a single-bank ExecutionPlan
        (`repro.engine`): kernel layouts + int8 LUT precomputed once, the
        backend bound globally. Preferred over repeated ``path="kernel*"``
        calls on the serving hot path."""
        from repro.engine import build_plan

        return build_plan(self, backend=backend, **kw)


def init_pegasus_linear(
    weight: np.ndarray,
    bias: np.ndarray | None,
    calibration: np.ndarray,
    *,
    group_size: int = 4,
    depth: int = 4,
    lut_bits: int | None = 16,
    lut_dtype=jnp.float32,
    act_fn: Callable | None = None,
) -> PegasusLinear:
    """Build a PegasusLinear from a trained dense layer + calibration acts.

    Args:
      weight: ``[D, N]`` full-precision trained weight.
      bias: ``[N]`` or None.
      calibration: ``[S, D]`` representative activations (training set pass).
      group_size: Partition width ``v`` (paper uses 2–8 on the switch).
      depth: fuzzy-tree depth ``d``; ``C = 2**d`` centroids per group.
      lut_bits: fixed-point width for stored outputs (None = keep float —
        the TPU default where we use dtype, not bit tricks).
      lut_dtype: storage dtype of the LUT on TPU (bf16/int8 are the
        memory-roofline levers; fp32 is the accuracy reference).
      act_fn: optional elementwise nonlinearity applied to centroids BEFORE
        the matmul — this is Basic Primitive Fusion folding the preceding
        activation Map into this layer's tables (`LUT = act(c) @ W`). The
        calibration data must then be the PRE-activation values.
    """
    weight = np.asarray(weight, np.float32)
    calibration = np.asarray(calibration, np.float32)
    d, n = weight.shape
    assert d % group_size == 0, f"D={d} not divisible by group v={group_size}"
    k = d // group_size

    trees = []
    for g in range(k):
        sl = calibration[:, g * group_size : (g + 1) * group_size]
        trees.append(fit_tree(sl, depth))
    stacked = stack_trees(trees)

    cents = stacked.centroids
    if act_fn is not None:
        cents = act_fn(cents)
    lut = build_matmul_lut(cents, jnp.asarray(weight), group_size)
    if lut_bits is not None:
        spec = choose_qspec(lut, bits=lut_bits)
        lut = fake_quant_spec(lut, spec)  # store on the fixed-point grid
    lut = lut.astype(lut_dtype)

    return PegasusLinear(
        trees=stacked,
        lut=lut,
        bias=None if bias is None else jnp.asarray(bias, jnp.float32),
        group_size=group_size,
    )


def init_pegasus_bank(
    fn: Callable[[jax.Array], jax.Array],
    calibration: np.ndarray,
    *,
    group_size: int,
    depth: int,
    bias: np.ndarray | None = None,
) -> PegasusLinear:
    """Generic table bank: LUT rows are ``fn`` of the stacked centroids.

    ``fn: [K, C, v] → [K, C, N]`` may be ANY offline computation — e.g. a
    whole per-window sub-network for Advanced-Fusion/NAM banks (paper Fig. 5
    ③), or a post-matmul nonlinearity fold for single-group banks
    (``K == 1`` ⇒ the SumReduce is trivial, so ``relu(c@W+b)`` may live in
    the rows directly).
    """
    calibration = np.asarray(calibration, np.float32)
    d = calibration.shape[1]
    assert d % group_size == 0, f"D={d} not divisible by group v={group_size}"
    k = d // group_size
    trees = [
        fit_tree(calibration[:, g * group_size : (g + 1) * group_size], depth)
        for g in range(k)
    ]
    stacked = stack_trees(trees)
    lut = fn(stacked.centroids)
    assert lut.ndim == 3 and lut.shape[:2] == (k, 2**depth), lut.shape
    return PegasusLinear(
        trees=stacked,
        lut=jnp.asarray(lut),
        bias=None if bias is None else jnp.asarray(bias, jnp.float32),
        group_size=group_size,
    )


# ---------------------------------------------------------------------------
# Apply paths
# ---------------------------------------------------------------------------


def _group(x: jax.Array, k: int, v: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], k, v)


def apply_gather(p: PegasusLinear, x: jax.Array) -> jax.Array:
    """Reference path: hard index + take_along_axis + sum."""
    xg = _group(x, p.num_groups, p.group_size)
    idx = hard_index_stacked(p.trees, xg)                      # [..., K]
    # lut: [K, C, N]; gather leaf rows per group then reduce over K
    gathered = jnp.take_along_axis(
        p.lut[None],  # [1, K, C, N] broadcast over batch
        idx.reshape(-1, p.num_groups)[:, :, None, None],
        axis=2,
    )[:, :, 0, :]                                              # [B, K, N]
    y = gathered.sum(axis=1).reshape(*x.shape[:-1], p.out_features)
    y = y.astype(jnp.float32)
    if p.bias is not None:
        y = y + p.bias
    return y


def apply_onehot(p: PegasusLinear, x: jax.Array) -> jax.Array:
    """MXU path: SumReduce(Map(...)) as ONE matmul.

    ``onehot(idx): [..., K, C]`` flattened to ``[..., K·C]`` times
    ``LUT: [K·C, N]`` computes the gather AND the sum-over-groups in a single
    dense contraction — Map+SumReduce fusion in MXU form.
    """
    xg = _group(x, p.num_groups, p.group_size)
    idx = hard_index_stacked(p.trees, xg)                      # [..., K]
    oh = jax.nn.one_hot(idx, p.num_centroids, dtype=p.lut.dtype)
    oh = oh.reshape(*x.shape[:-1], p.num_groups * p.num_centroids)
    y = oh @ p.lut.reshape(-1, p.out_features).astype(p.lut.dtype)
    y = y.astype(jnp.float32)
    if p.bias is not None:
        y = y + p.bias
    return y


def apply_soft(p: PegasusLinear, x: jax.Array, temperature: float = 0.1) -> jax.Array:
    """Differentiable path for backprop refinement (paper §4.4)."""
    xg = _group(x, p.num_groups, p.group_size)
    probs = soft_index_stacked(p.trees, xg, temperature)       # [..., K, C]
    y = jnp.einsum("...kc,kcn->...n", probs, p.lut.astype(jnp.float32))
    if p.bias is not None:
        y = y + p.bias
    return y


def pegasus_linear_apply(
    p: PegasusLinear, x: jax.Array, *, path: str = "onehot"
) -> jax.Array:
    if path == "gather":
        return apply_gather(p, x)
    if path == "onehot":
        return apply_onehot(p, x)
    if path == "soft":
        return apply_soft(p, x)
    if path == "kernel":
        from repro.kernels.fuzzy_lut import ops as _k

        return _k.fuzzy_lut_matmul(p, x)
    if path == "kernel_q8":
        from repro.kernels.fuzzy_lut import ops as _k

        return _k.fuzzy_lut_matmul_q8(p, x)
    raise ValueError(f"unknown path {path}")


def dense_reference(weight: jax.Array, bias: jax.Array | None, x: jax.Array) -> jax.Array:
    y = x @ weight
    if bias is not None:
        y = y + bias
    return y
