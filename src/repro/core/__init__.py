"""Pegasus core: primitives, fuzzy matching, fusion, quantization, AMM."""

from .primitives import (
    partition,
    unpartition,
    map_apply,
    sum_reduce,
    PartitionOp,
    MapOp,
    SumReduceOp,
    PrimitiveGraph,
)
from .fuzzy_tree import FuzzyTree, fit_tree, hard_index, soft_index, stack_trees
from .fusion import (
    fuse_basic,
    merge_consecutive_maps,
    linear_reorder,
    advanced_remove_nonlinear,
    advanced_nam,
)
from .quantization import FixedPointSpec, choose_qspec, quantize, dequantize, fake_quant_spec
from .lut import build_lut, build_matmul_lut, quantize_lut
from .amm import PegasusLinear, init_pegasus_bank, init_pegasus_linear, pegasus_linear_apply
from .syntax import map_op, partition as syntax_partition, program, sumreduce, translate

__all__ = [
    "partition", "unpartition", "map_apply", "sum_reduce",
    "PartitionOp", "MapOp", "SumReduceOp", "PrimitiveGraph",
    "FuzzyTree", "fit_tree", "hard_index", "soft_index", "stack_trees",
    "fuse_basic", "merge_consecutive_maps", "linear_reorder",
    "advanced_remove_nonlinear", "advanced_nam",
    "FixedPointSpec", "choose_qspec", "quantize", "dequantize", "fake_quant_spec",
    "build_lut", "build_matmul_lut", "quantize_lut",
    "PegasusLinear", "init_pegasus_bank", "init_pegasus_linear", "pegasus_linear_apply",
    "map_op", "syntax_partition", "program", "sumreduce", "translate",
]
