"""Consecutive Range Coding (paper §6.1, after NetBeacon [58]).

PISA switches have no multi-level comparator; the fuzzy-tree descent is
realized by *range matching*: each leaf of the clustering tree owns an
axis-aligned box of the input space, and each box is encoded as TCAM
ternary rules (value/mask pairs) per dimension.

`range_to_ternary` implements the classic prefix-expansion of an integer
interval [lo, hi] into minimal ternary (prefix) rules; a leaf's TCAM cost is
the product over dimensions of its per-dimension rule counts (rules are
crossed-producted into a single wide key, which is how a single-lookup MAT
stage matches a multi-dimensional box).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TernaryRule", "range_to_ternary", "tree_leaf_boxes", "leaf_tcam_rules"]


@dataclasses.dataclass(frozen=True)
class TernaryRule:
    """value/mask pair over ``bits`` bits: matches x iff x & mask == value."""

    value: int
    mask: int
    bits: int

    def matches(self, x: int) -> bool:
        return (x & self.mask) == self.value

    def __repr__(self) -> str:  # e.g. 0b10** for bits=4
        s = []
        for b in reversed(range(self.bits)):
            if (self.mask >> b) & 1:
                s.append(str((self.value >> b) & 1))
            else:
                s.append("*")
        return "0b" + "".join(s)


def range_to_ternary(lo: int, hi: int, bits: int) -> list[TernaryRule]:
    """Minimal prefix expansion of the inclusive integer range [lo, hi]."""
    assert 0 <= lo <= hi < 2**bits, (lo, hi, bits)
    rules: list[TernaryRule] = []

    def emit(prefix_val: int, prefix_len: int):
        mask = ((1 << prefix_len) - 1) << (bits - prefix_len) if prefix_len else 0
        rules.append(TernaryRule(value=prefix_val << (bits - prefix_len), mask=mask, bits=bits))

    def recurse(lo: int, hi: int, prefix_val: int, prefix_len: int):
        if lo > hi:
            return
        span_lo = prefix_val << (bits - prefix_len)
        span_hi = span_lo + (1 << (bits - prefix_len)) - 1
        if lo <= span_lo and span_hi <= hi:
            emit(prefix_val, prefix_len)
            return
        if prefix_len == bits:
            return
        mid = span_lo + (1 << (bits - prefix_len - 1))
        recurse(lo, min(hi, mid - 1), prefix_val << 1, prefix_len + 1)
        recurse(max(lo, mid), hi, (prefix_val << 1) | 1, prefix_len + 1)

    recurse(lo, hi, 0, 0)
    return rules


def tree_leaf_boxes(features: np.ndarray, thresholds: np.ndarray, depth: int,
                    group_dim: int, bits: int = 8) -> list[list[tuple[int, int]]]:
    """Per-leaf axis-aligned integer boxes implied by the clustering tree.

    Values are assumed pre-quantized to unsigned ``bits``-bit fixed point (the
    dataplane representation). Returns, for each leaf, a list of (lo, hi)
    inclusive ranges — one per input dimension.
    """
    vmax = 2**bits - 1
    boxes = []

    def walk(node: int, box: list[tuple[int, int]], level: int):
        if level == depth:
            boxes.append([tuple(r) for r in box])
            return
        f, t = int(features[node]), float(thresholds[node])
        t_int = int(np.floor(t)) if np.isfinite(t) else vmax
        t_int = int(np.clip(t_int, -1, vmax))
        lo, hi = box[f]
        # left: x[f] <= t
        left_box = [list(r) for r in box]
        left_box[f] = [lo, min(hi, t_int)]
        # right: x[f] > t
        right_box = [list(r) for r in box]
        right_box[f] = [max(lo, t_int + 1), hi]
        walk(2 * node + 1, left_box, level + 1)
        walk(2 * node + 2, right_box, level + 1)

    walk(0, [[0, vmax] for _ in range(group_dim)], 0)
    return boxes


def leaf_tcam_rules(box: list[tuple[int, int]], bits: int = 8) -> int:
    """TCAM rules to match one leaf box = Π_dims |prefix-expansion(range)|.

    Empty ranges (unreachable leaves) cost 0 rules.
    """
    total = 1
    for lo, hi in box:
        if lo > hi:
            return 0
        total *= len(range_to_ternary(lo, hi, bits))
    return total
