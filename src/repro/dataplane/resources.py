"""Tofino-2-like switch resource model (paper §2, Table 6).

Budget constants from the paper's description of Barefoot Tofino 2:
20 MAT stages/pipeline, 10 Mb SRAM + 0.5 Mb TCAM per stage, 1024-bit Action
Data Bus, 4096-bit PHV. The emulator charges each compiled table against
these budgets and reports the same utilization columns as Table 6.
"""

from __future__ import annotations

import dataclasses

__all__ = ["SwitchBudget", "ResourceReport", "TOFINO2"]


@dataclasses.dataclass(frozen=True)
class SwitchBudget:
    stages: int = 20
    sram_bits_per_stage: int = 10 * 1024 * 1024       # 10 Mb
    tcam_bits_per_stage: int = 512 * 1024             # 0.5 Mb
    action_bus_bits: int = 1024                       # per stage
    phv_bits: int = 4096
    stateful_sram_bits: int = 20 * 1024 * 1024 * 10   # shared pool for per-flow regs


TOFINO2 = SwitchBudget()


@dataclasses.dataclass
class ResourceReport:
    """Accumulated usage for one compiled model."""

    budget: SwitchBudget = dataclasses.field(default_factory=lambda: TOFINO2)
    stages_used: int = 0
    sram_bits: int = 0
    tcam_bits: int = 0
    action_bus_bits_peak: int = 0
    phv_bits_peak: int = 0
    stateful_bits_per_flow: int = 0

    # -- percentages as reported in Table 6 ---------------------------------
    @property
    def sram_pct(self) -> float:
        return 100.0 * self.sram_bits / (self.budget.stages * self.budget.sram_bits_per_stage)

    @property
    def tcam_pct(self) -> float:
        return 100.0 * self.tcam_bits / (self.budget.stages * self.budget.tcam_bits_per_stage)

    @property
    def bus_pct(self) -> float:
        return 100.0 * self.action_bus_bits_peak / self.budget.action_bus_bits

    def validate(self) -> list[str]:
        """Return a list of violated constraints (empty = deployable)."""
        errs = []
        # >20 stages ⇒ recirculation passes (throughput/pass tradeoff), not a
        # correctness violation; reported via ``recirculations``.
        if self.sram_pct > 100:
            errs.append(f"SRAM {self.sram_pct:.1f}% > 100%")
        if self.tcam_pct > 100:
            errs.append(f"TCAM {self.tcam_pct:.1f}% > 100%")
        if self.action_bus_bits_peak > self.budget.action_bus_bits:
            errs.append(
                f"action bus {self.action_bus_bits_peak} > {self.budget.action_bus_bits}"
            )
        if self.phv_bits_peak > self.budget.phv_bits:
            errs.append(f"PHV {self.phv_bits_peak} > {self.budget.phv_bits}")
        return errs

    @property
    def recirculations(self) -> int:
        import math
        return max(0, math.ceil(self.stages_used / self.budget.stages) - 1)

    def table6_row(self, name: str) -> str:
        return (
            f"{name:<14} {self.stateful_bits_per_flow:>6} "
            f"{self.sram_pct:>6.2f}% {self.tcam_pct:>7.2f}% {self.bus_pct:>7.2f}%"
        )
