"""Lower fused Pegasus layers to the MAT pipeline (paper §6).

One fused PegasusLinear ⇒ one *logical* stage of K parallel MapTables
(fuzzy TCAM match → SRAM result row), summed by the action ALUs. Physical
stage placement (the 20-stage / per-stage SRAM / 1024-bit-bus bin packing)
happens in :func:`place_physical` and feeds the Table-6-style report.

Numerics: the dataplane is integer-only. Each layer's result rows are
fixed-point quantized with an adaptive binary point (core.quantization);
the next layer's thresholds are rescaled into that integer domain, so the
whole pipeline runs end-to-end in int32 exactly like the switch would.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.amm import PegasusLinear
from repro.core.quantization import FixedPointSpec, choose_qspec

from .mat import MapTable, MatPipeline, MatStage
from .resources import SwitchBudget, TOFINO2

__all__ = ["compile_layer", "compile_model", "place_physical"]


def compile_layer(
    layer: PegasusLinear,
    *,
    in_scale: float = 1.0,
    out_bits: int = 16,
    in_bits: int = 8,
    name: str = "",
) -> tuple[list[MapTable], FixedPointSpec]:
    """Lower one fused PegasusLinear to K MapTables.

    ``in_scale`` is the fixed-point scale of this layer's INPUT domain
    (1.0 for raw 8-bit packet fields); learned float thresholds are mapped
    into the integer domain by multiplying with it.
    """
    k, v = layer.num_groups, layer.group_size
    lut = np.asarray(layer.lut, np.float32)                 # [K, C, N]
    bias = None if layer.bias is None else np.asarray(layer.bias, np.float32)
    spec = choose_qspec(lut if bias is None else np.concatenate([lut.ravel(), bias]), bits=out_bits)

    feats = np.asarray(layer.trees.features)
    thrs = np.asarray(layer.trees.thresholds) * in_scale

    tables = []
    for g in range(k):
        rows = np.round(lut[g] * spec.scale).astype(np.int64)
        if bias is not None and g == 0:
            rows = rows + np.round(bias * spec.scale).astype(np.int64)
        rows = np.clip(rows, spec.qmin, spec.qmax).astype(np.int32)
        tables.append(
            MapTable(
                features=feats[g],
                thresholds=thrs[g],
                results=rows,
                in_bits=in_bits,
                out_bits=out_bits,
                key_dims=list(range(g * v, (g + 1) * v)),
                name=f"{name}/g{g}",
            )
        )
    return tables, spec


def compile_model(
    layers: list[PegasusLinear],
    *,
    stateful_bits_per_flow: int = 0,
    out_bits: int = 16,
    in_bits: int = 8,
    budget: SwitchBudget = TOFINO2,
    names: list[str] | None = None,
) -> MatPipeline:
    """Lower a stack of fused Pegasus layers to one logical-stage pipeline.

    Layer i+1's thresholds are rescaled into layer i's output integer
    domain; its ``in_bits`` widens to the accumulated word width.
    """
    pipe = MatPipeline(stages=[], stateful_bits_per_flow=stateful_bits_per_flow, budget=budget)
    scale = 1.0
    bits = in_bits
    for i, layer in enumerate(layers):
        nm = names[i] if names else f"L{i}"
        tables, spec = compile_layer(
            layer, in_scale=scale, out_bits=out_bits, in_bits=bits, name=nm
        )
        pipe.stages.append(MatStage(tables=tables))
        scale = spec.scale
        bits = out_bits
    return pipe


def place_physical(pipe: MatPipeline) -> int:
    """Bin-pack logical stages onto physical MAT stages.

    Within one logical stage, tables may spread over several physical stages
    (partial sums carry in the PHV); consecutive logical stages are
    dependent, so they never share a physical stage. Constraints per
    physical stage: SRAM, TCAM, action-bus width.
    """
    b = pipe.budget
    total = 0
    for stage in pipe.stages:
        sram = tcam = bus = 0
        phys = 1
        for t in stage.tables:
            ts, tt, tb = t.sram_bits(), t.tcam_bits(), t.action_bus_bits()
            if (
                sram + ts > b.sram_bits_per_stage
                or tcam + tt > b.tcam_bits_per_stage
                or bus + tb > b.action_bus_bits
            ):
                phys += 1
                sram = tcam = bus = 0
            sram += ts
            tcam += tt
            bus += tb
        total += phys
    return total
