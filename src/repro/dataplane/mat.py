"""MAT pipeline emulator: execute a compiled Pegasus program stage-by-stage.

The emulator models what the switch actually does per packet:
  * extract fields from the PHV (Partition),
  * match them against a table (exact SRAM or ternary TCAM range rules)
    to fetch a precomputed result row (Map, via fuzzy index),
  * apply integer actions — adds only — to accumulate results (SumReduce).

Everything is integer fixed-point (the dataplane has no floats). The
emulator exists to (a) check bit-exactness of the quantized pipeline against
the JAX fixed-point model, and (b) account resources the way Table 6 does.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .crc import leaf_tcam_rules, tree_leaf_boxes
from .resources import ResourceReport, SwitchBudget, TOFINO2

__all__ = ["MapTable", "MatStage", "MatPipeline"]


@dataclasses.dataclass
class MapTable:
    """One fuzzy-matching Map table: tree → fuzzy index → SRAM result row.

    Attributes:
      features/thresholds: int arrays of the (quantized) clustering tree.
      results: ``[C, out_width_words]`` int32 — fixed-point action data.
      in_bits: bit width of each input field (8 in the paper's models).
      out_bits: bit width of each output word.
      key_dims: which PHV fields this table matches on.
    """

    features: np.ndarray
    thresholds: np.ndarray
    results: np.ndarray
    in_bits: int
    out_bits: int
    key_dims: Sequence[int]
    name: str = ""

    @property
    def depth(self) -> int:
        return int(np.log2(self.results.shape[0]) + 0.5)

    def lookup(self, fields: np.ndarray) -> np.ndarray:
        """Per-packet fuzzy index + result fetch. fields: [n_key_dims] ints."""
        node = 0
        n_internal = len(self.features)
        for _ in range(self.depth):
            f, t = self.features[node], self.thresholds[node]
            node = 2 * node + 1 + int(fields[f] > t)
        return self.results[node - n_internal]

    # -- resource accounting -------------------------------------------------
    def tcam_rule_count(self) -> int:
        """One-shot CRC encoding: cross-product of per-dim prefix rules."""
        boxes = tree_leaf_boxes(
            self.features, self.thresholds, self.depth, len(self.key_dims), self.in_bits
        )
        return sum(leaf_tcam_rules(b, self.in_bits) for b in boxes)

    def staged_tcam_bits(self) -> int:
        """Staged encoding: one narrow range-match per tree LEVEL.

        Each level's table is keyed by (current node id, one feature value):
        2 range rules per internal node, key = node-id bits + in_bits. No
        cross-product — this is how deep/multi-dim trees actually compile
        (one comparison per MAT stage), at the cost of ``depth`` extra
        pipeline stages.
        """
        n_internal = len(self.features)
        node_bits = max(1, (n_internal).bit_length())
        key_bits = node_bits + self.in_bits
        return n_internal * 2 * key_bits * 2  # 2 rules/node, value+mask

    def tcam_bits(self) -> int:
        """Compiler picks the cheaper encoding (one-shot vs staged)."""
        key_bits = len(self.key_dims) * self.in_bits
        one_shot = self.tcam_rule_count() * key_bits * 2
        return min(one_shot, self.staged_tcam_bits())

    def sram_bits(self) -> int:
        return int(self.results.shape[0] * self.results.shape[1] * self.out_bits)

    def action_bus_bits(self) -> int:
        return int(self.results.shape[1] * self.out_bits)


@dataclasses.dataclass
class MatStage:
    """Tables co-resident in one physical stage (must share its budgets)."""

    tables: list[MapTable] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class MatPipeline:
    """A sequence of MAT stages implementing one Pegasus model."""

    stages: list[MatStage] = dataclasses.field(default_factory=list)
    stateful_bits_per_flow: int = 0
    budget: SwitchBudget = dataclasses.field(default_factory=lambda: TOFINO2)

    def run_packet(self, fields: np.ndarray) -> np.ndarray:
        """Execute the pipeline on one packet's PHV fields.

        Per stage: all tables look up in parallel; their result rows are
        summed (the SumReduce action) to form the next stage's fields.
        """
        x = np.asarray(fields)
        for stage in self.stages:
            if not stage.tables:
                continue
            acc = None
            for tbl in stage.tables:
                row = tbl.lookup(x[list(tbl.key_dims)])
                acc = row if acc is None else acc + row
            x = acc
        return x

    def run_batch(self, batch: np.ndarray) -> np.ndarray:
        return np.stack([self.run_packet(p) for p in batch])

    def report(self) -> ResourceReport:
        """Resource accounting AFTER physical placement.

        Tables of one logical stage spread across physical stages (partial
        sums carried in the PHV), so the action-bus peak is the max over
        PHYSICAL stages — placement packs to the 1024-bit budget, and a
        single table wider than the bus is the only way to exceed it.
        """
        from .compile import place_physical

        rep = ResourceReport(budget=self.budget)
        rep.stages_used = place_physical(self)
        b = self.budget
        for stage in self.stages:
            sram = tcam = bus = 0
            for tbl in stage.tables:
                ts, tt, tb = tbl.sram_bits(), tbl.tcam_bits(), tbl.action_bus_bits()
                rep.sram_bits += ts
                rep.tcam_bits += tt
                if (
                    sram + ts > b.sram_bits_per_stage
                    or tcam + tt > b.tcam_bits_per_stage
                    or bus + tb > b.action_bus_bits
                ):
                    rep.action_bus_bits_peak = max(rep.action_bus_bits_peak, bus)
                    sram = tcam = bus = 0
                sram += ts
                tcam += tt
                bus += tb
            rep.action_bus_bits_peak = max(rep.action_bus_bits_peak, bus)
        rep.stateful_bits_per_flow = self.stateful_bits_per_flow
        # PHV peak: widest inter-stage accumulator vector (one layer's output)
        widths = [
            max((t.results.shape[1] * t.out_bits for t in s.tables), default=0)
            for s in self.stages
        ]
        rep.phv_bits_peak = max(widths, default=0)
        return rep
