"""Pallas TPU kernel: fused fuzzy-index + LUT accumulate (Pegasus Map+SumReduce).

TPU-native realization of the paper's table lookup (DESIGN.md §2):

  * The clustering-tree descent is *gather-free*: feature selection becomes a
    tiny one-hot einsum (``feat_oh`` is precomputed offline), node selection
    a one-hot reduction — every step is VPU compare/select or MXU matmul, so
    the whole "fuzzy match" is branchless and systolic-friendly.
  * The Map (leaf→row lookup) + SumReduce (Σ over groups) pair is ONE MXU
    matmul: ``onehot(leaf): [Tt, Kt·C] @ LUT-block: [Kt·C, Nt]`` — the same
    primitive-fusion insight as the paper's, re-expressed for a systolic
    array instead of a MAT stage.

Single-bank tiling (BlockSpec, all VMEM):
  grid = (T/Tt, N/Nt, K/Kt);   K innermost → output block accumulates.
    x        [T, K, v]   → block (Tt, Kt, v)      index (i, k, 0)
    feat_oh  [K, I, v]   → block (Kt, I, v)       index (k, 0, 0)   I = 2^d - 1
    thr      [K, I]      → block (Kt, I)          index (k, 0)
    lut      [K, C, N]   → block (Kt, C, Nt)      index (k, 0, j)
    out      [T, N]      → block (Tt, Nt)         index (i, j)

VMEM working set ≈ Tt·Kt·v + Kt·I·v + Kt·C·Nt + Tt·Nt floats.
Defaults (Tt=256, Kt=128, Nt=256, C=16, v=8): ≈ 2.6 MB ≪ 128 MB VMEM, and
the MXU contraction dims (Kt·C = 2048, Nt = 256) are 128-aligned.

Stacked-layer variant (:func:`fuzzy_lut_stack_pallas` — Cross-bank Primitive
Fusion): a compatible run of L banks executes as ONE kernel invocation. The
grid tiles ONLY the batch; every per-layer operand rides whole (stacked along
a leading L axis) so the inter-bank activation never leaves VMEM — the
re-partition (``[Tt, N] → [Tt, K, v]``), bias add, and (q8 path) in-register
dequantization all happen inside the per-layer loop:

  grid = (T/Tt,)
    x        [T, K₀, v]        → block (Tt, K₀, v)       index (i, 0, 0)
    feat_oh  [L, Kmax, I, v]   → whole                    index 0
    thr      [L, Kmax, I]      → whole                    index 0
    lut      [L, Kmax, C, Nmax]→ whole                    index 0
    bias     [L, Nmax]         → whole                    index 0
    out      [T, n_out]        → block (Tt, n_out)        index (i, 0)

Banks are padded to the group's (Kmax, Nmax) at PLAN BUILD (zero LUT rows
and +inf thresholds: padded groups descend to leaf 0 and contribute 0), so
warm calls pad nothing but the batch. VMEM working set ≈
Tt·Kmax·v·2 (x + repartitioned h) + L·Kmax·I·(v+1) + L·Kmax·C·Nmax (LUT)
+ L·Nmax + Tt·Nmax floats. The MLP-B shape (L=4, Kmax=16, I=63, C=64,
Nmax=32, v=2, Tt=1024) is ≈ 1.0 MB — the LUT stack and the Tt·Nmax tiles
dominate, so cap L (or shrink Tt via ``block_t``) when their sum approaches
the VMEM budget.

Both entry points raise ``ValueError`` (never ``assert``, which dies
silently under ``python -O``) when a dimension is not block-divisible, so
the engine can catch mis-padded operands and fall back to the per-bank path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["default_interpret", "fuzzy_lut_kernel", "fuzzy_lut_pallas",
           "fuzzy_lut_stack_pallas", "resolve_strategy"]

# Batch tile of the stacked-layer kernel. Larger than the single-bank default
# (256): the stack's grid has no N/K axes, so the only per-tile overhead is
# the interpreter's operand slicing — fewer, fatter tiles win on CPU (A/B
# swept 256/512/1024 at batch 1024), and the VMEM working set stays ≈1 MB
# for every shipped bank geometry (see module docstring).
STACK_BLOCK_T = 1024


def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached.

    This is THE static gate for the non-interpret path: callers pass
    ``interpret=None`` and get the Mosaic-compiled kernel on TPU, the
    traceable interpreter everywhere else (CPU CI, tests). The flag is a
    static jit arg throughout, so both modes live in separate compile-cache
    entries and can coexist in one process.
    """
    return jax.default_backend() != "tpu"


def _tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """dimension_semantics plumbing across pallas API versions."""
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except (AttributeError, TypeError):  # older API
        return dict(mosaic=dict(dimension_semantics=dimension_semantics))


def _tree_leaf(x, feat_oh, thr, *, depth: int, strategy: str):
    """Shared descent: [Tt, Kt, v] activations → [Tt, Kt] leaf indices.

    Both strategies compute the SAME bits (identical fp compare); they differ
    in how much of the tree they touch:
      ``mxu``    — score EVERY internal node up front (one-hot einsum for the
                   feature select, one-hot reduction per level): branchless
                   and gather-free — what the systolic/VPU path wants.
      ``lookup`` — walk only the ``depth`` visited nodes, one flat-index
                   gather per level (features recovered from the one-hot via
                   argmax, [Kt, I] — tiny): no [Tt, Kt, I] intermediates at
                   all, which is what the interpreter/CPU wants (the dense
                   form materializes I/d ≈ 10× more values than the walk
                   reads).
    """
    tt, kt = x.shape[0], x.shape[1]
    n_internal = thr.shape[-1]
    node = jnp.zeros((tt, kt), dtype=jnp.int32)
    if strategy == "lookup":
        # sparse walk: gather (feature, threshold) of the CURRENT node only
        feat_flat = jnp.argmax(feat_oh, axis=-1).astype(jnp.int32).reshape(-1)
        thr_flat = thr.reshape(-1)
        base = (jnp.arange(kt, dtype=jnp.int32) * n_internal)[None]  # [1, Kt]
        for _ in range(depth):
            idx = node + base                                 # [Tt, Kt]
            f_sel = jnp.take(feat_flat, idx)
            t_sel = jnp.take(thr_flat, idx)
            val = jnp.take_along_axis(x, f_sel[:, :, None], axis=2)[..., 0]
            node = 2 * node + 1 + (val > t_sel).astype(jnp.int32)
        return node - n_internal                  # [Tt, Kt] in [0, C)

    # dense scoring: vals[t,k,n] = x[t,k,feat[k,n]] as an einsum against the
    # precomputed one-hot (gather-free), then one-hot-select per level.
    vals = jax.lax.dot_general(
        x,
        feat_oh,
        # contract v; batch over k
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )                                             # [Kt, Tt, I]
    vals = vals.transpose(1, 0, 2)                # [Tt, Kt, I]
    bits = (vals > thr[None]).astype(jnp.int32)   # decision at every node
    iota_nodes = jax.lax.broadcasted_iota(jnp.int32, (tt, kt, n_internal), 2)
    for _ in range(depth):
        node_oh = (iota_nodes == node[:, :, None]).astype(jnp.int32)
        bit = jnp.sum(bits * node_oh, axis=-1)    # [Tt, Kt]
        node = 2 * node + 1 + bit
    return node - n_internal                      # [Tt, Kt] in [0, C)


def _lut_contrib(leaf, lut, *, strategy: str, scale=None):
    """Map + SumReduce over one tile: [Tt, Kt] leaves × [Kt, C, Nt] LUT →
    [Tt, Nt] contributions. ``scale`` ([Kt] per-group dequant factors, q8
    path) folds in exactly — it is constant within a group, and both
    realizations sum over (group, centroid).

      ``mxu``    — onehot(leaf) [Tt, Kt·C] @ lut [Kt·C, Nt]: one systolic
                   matmul, gather-free.
      ``lookup`` — flat-index gather-sum (rows picked from the [Kt·C, Nt]
                   table view): O(T·K·N) instead of the matmul's O(T·K·C·N);
                   the interpreter/CPU-fast form.
    """
    tt, kt = leaf.shape
    c = lut.shape[1]
    if strategy == "lookup":
        base = (jnp.arange(kt, dtype=jnp.int32) * c)[None]    # [1, Kt]
        rows = jnp.take(lut.reshape(kt * c, -1), leaf + base,
                        axis=0)                   # [Tt, Kt, Nt]
        if scale is not None:
            rows = rows * scale[None, :, None]
        return rows.sum(axis=1)                   # [Tt, Nt]
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (tt, kt, c), 2)
    onehot = (iota_c == leaf[:, :, None]).astype(jnp.float32)
    if scale is not None:
        onehot = onehot * scale[None, :, None]
    return jax.lax.dot_general(
        onehot.reshape(tt, kt * c),
        lut.reshape(kt * c, -1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # [Tt, Nt]


def fuzzy_lut_kernel(
    x_ref, feat_oh_ref, thr_ref, lut_ref, out_ref, *, depth: int,
    strategy: str = "mxu",
):
    """One (Tt, Nt, Kt) tile: descend trees, accumulate LUT rows into out."""
    x = x_ref[...].astype(jnp.float32)            # [Tt, Kt, v]
    feat_oh = feat_oh_ref[...].astype(jnp.float32)  # [Kt, I, v]
    thr = thr_ref[...].astype(jnp.float32)        # [Kt, I]

    leaf = _tree_leaf(x, feat_oh, thr, depth=depth, strategy=strategy)
    lut = lut_ref[...].astype(jnp.float32)        # [Kt, C, Nt]
    contrib = _lut_contrib(leaf, lut, strategy=strategy)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] += contrib


def _check_divisible(where: str, **dims: tuple[int, int]) -> None:
    """Raise ``ValueError`` naming every dim not divisible by its block.

    A raised error (not ``assert``) so (a) ``python -O`` can't silently skip
    the check and (b) the engine's fused-path fallback can catch a mis-padded
    operand stack and dispatch per-bank instead of dying.
    """
    bad = [f"{name}={size} % block {blk} != 0"
           for name, (size, blk) in dims.items() if size % blk != 0]
    if bad:
        raise ValueError(
            f"{where}: {'; '.join(bad)} — operands must be pre-padded to "
            "block multiples (CompiledBank / ops.py layout prep does this)")


def resolve_strategy(strategy: str, interpret: bool) -> str:
    """``auto`` → ``lookup`` under the interpreter (CPU executes the one-hot
    matmul's C× redundant work serially), ``mxu`` on compiled TPU (systolic
    arrays eat dense matmuls; gathers don't vectorize). Both strategies are
    semantics-identical and parity-tested against each other."""
    if strategy == "auto":
        return "lookup" if interpret else "mxu"
    if strategy not in ("mxu", "lookup"):
        raise ValueError(f"unknown strategy {strategy!r}; expected auto|mxu|lookup")
    return strategy


@functools.partial(
    jax.jit,
    static_argnames=("depth", "block_t", "block_n", "block_k", "interpret",
                     "strategy"),
)
def fuzzy_lut_pallas(
    x: jax.Array,          # [T, K, v]
    feat_oh: jax.Array,    # [K, I, v] one-hot of split features (offline)
    thresholds: jax.Array, # [K, I]
    lut: jax.Array,        # [K, C, N]
    *,
    depth: int,
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
    strategy: str = "auto",
) -> jax.Array:
    """Pallas-tiled fused Pegasus matmul. Returns [T, N] f32 (no bias).

    Fully traceable inside an outer ``jax.jit`` (the engine jits whole-plan
    forwards through here); ``interpret`` and ``strategy`` are static args,
    ``None``/``"auto"`` resolve via :func:`default_interpret` /
    :func:`resolve_strategy`.
    """
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k, v = x.shape
    _, c, n = lut.shape
    bt, bn, bk = min(block_t, t), min(block_n, n), min(block_k, k)
    _check_divisible("fuzzy_lut_pallas", T=(t, bt), N=(n, bn), K=(k, bk))
    n_internal = c - 1

    grid = (t // bt, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(fuzzy_lut_kernel, depth=depth, strategy=strategy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk, v), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bk, n_internal, v), lambda i, j, kk: (kk, 0, 0)),
            pl.BlockSpec((bk, n_internal), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((bk, c, bn), lambda i, j, kk: (kk, 0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        compiler_params=_tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, feat_oh, thresholds, lut)


# ---------------------------------------------------------------------------
# Stacked-layer variant: L compatible banks in ONE kernel invocation
# ---------------------------------------------------------------------------


def _stack_layers(h, feat_oh, thr, lut, bias, scales, *, depth: int,
                  ks: tuple[int, ...], v: int, strategy: str):
    """Run the whole bank stack over one batch tile, all in registers/VMEM.

    ``h``: [Tt, K₀, v] activations; stacked operands carry a leading L axis
    (see module docstring). Between layers the activation is re-partitioned
    ``[Tt, N] → [Tt, ks[l+1], v]`` and zero-padded back to Kmax — padded
    groups hold +inf thresholds and zero LUT rows, so they descend to leaf 0
    and contribute nothing. ``scales`` (q8 path) dequantizes each layer's
    int8 LUT in-VMEM via the per-group factors; ``None`` on the fp path.
    """
    nlayers, kmax = lut.shape[0], lut.shape[1]
    tt = h.shape[0]
    if h.shape[1] < kmax:
        h = jnp.pad(h, ((0, 0), (0, kmax - h.shape[1]), (0, 0)))
    y = None
    for l in range(nlayers):
        leaf = _tree_leaf(h, feat_oh[l].astype(jnp.float32),
                          thr[l].astype(jnp.float32),
                          depth=depth, strategy=strategy)
        tab = lut[l].astype(jnp.float32)
        if scales is not None:
            # q8 dequant in-VMEM, scales folded into the TABLE (exact: the
            # factor is constant per group) — K·C·N multiplies once per tile
            # instead of T·K·N on every gathered row
            tab = tab * scales[l].astype(jnp.float32)[:, None, None]
        y = _lut_contrib(leaf, tab, strategy=strategy)
        y = y + bias[l].astype(jnp.float32)
        if l + 1 < nlayers:
            nk = ks[l + 1]
            h = y[:, : nk * v].reshape(tt, nk, v)
            if nk < kmax:
                h = jnp.pad(h, ((0, 0), (0, kmax - nk), (0, 0)))
    return y


def fuzzy_lut_stack_kernel(x_ref, feat_oh_ref, thr_ref, lut_ref, bias_ref,
                           out_ref, *, depth: int, ks: tuple[int, ...],
                           v: int, n_out: int, strategy: str):
    """One batch tile through ALL L fused banks (fp32 LUT stack)."""
    y = _stack_layers(
        x_ref[...].astype(jnp.float32), feat_oh_ref, thr_ref, lut_ref,
        bias_ref, None, depth=depth, ks=ks, v=v, strategy=strategy)
    out_ref[...] = y[:, :n_out]


@functools.partial(
    jax.jit,
    static_argnames=("depth", "ks", "n_out", "block_t", "interpret",
                     "strategy"),
)
def fuzzy_lut_stack_pallas(
    x: jax.Array,          # [T, K₀, v]
    feat_oh: jax.Array,    # [L, Kmax, I, v]
    thr: jax.Array,        # [L, Kmax, I]
    lut: jax.Array,        # [L, Kmax, C, Nmax] f32
    bias: jax.Array,       # [L, Nmax] (zeros where a bank has no bias)
    *,
    depth: int,
    ks: tuple[int, ...],   # true group count per layer (≤ Kmax)
    n_out: int,            # true out_features of the LAST layer (≤ Nmax)
    block_t: int = STACK_BLOCK_T,
    interpret: bool | None = None,
    strategy: str = "auto",
) -> jax.Array:
    """Cross-bank Primitive Fusion: L banks, ONE ``pallas_call``.

    Returns ``[T, n_out]`` f32 — bias already applied (it must be: every
    non-final layer's bias feeds the next layer's tree descent in-VMEM).
    Operand stacks must be pre-padded to (Kmax, Nmax) at plan build; only
    the batch axis is tiled, so T is the only dim with a divisibility
    constraint (``ValueError`` otherwise — catchable, see module docstring).
    """
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k0, v = x.shape
    nlayers, kmax, c, nmax = lut.shape
    n_internal = thr.shape[2]
    if len(ks) != nlayers:
        raise ValueError(f"ks has {len(ks)} entries for {nlayers} stacked layers")
    if k0 != ks[0]:
        raise ValueError(f"x carries K={k0} groups; ks[0]={ks[0]}")
    bt = min(block_t, t)
    _check_divisible("fuzzy_lut_stack_pallas", T=(t, bt))

    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(fuzzy_lut_stack_kernel, depth=depth, ks=ks, v=v,
                          n_out=n_out, strategy=strategy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, k0, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((nlayers, kmax, n_internal, v), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((nlayers, kmax, n_internal), lambda i: (0, 0, 0)),
            pl.BlockSpec((nlayers, kmax, c, nmax), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((nlayers, nmax), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), jnp.float32),
        compiler_params=_tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(x, feat_oh, thr, lut, bias)
