"""Pallas TPU kernel: fused fuzzy-index + LUT accumulate (Pegasus Map+SumReduce).

TPU-native realization of the paper's table lookup (DESIGN.md §2):

  * The clustering-tree descent is *gather-free*: feature selection becomes a
    tiny one-hot einsum (``feat_oh`` is precomputed offline), node selection
    a one-hot reduction — every step is VPU compare/select or MXU matmul, so
    the whole "fuzzy match" is branchless and systolic-friendly.
  * The Map (leaf→row lookup) + SumReduce (Σ over groups) pair is ONE MXU
    matmul: ``onehot(leaf): [Tt, Kt·C] @ LUT-block: [Kt·C, Nt]`` — the same
    primitive-fusion insight as the paper's, re-expressed for a systolic
    array instead of a MAT stage.

Tiling (BlockSpec, all VMEM):
  grid = (T/Tt, N/Nt, K/Kt);   K innermost → output block accumulates.
    x        [T, K, v]   → block (Tt, Kt, v)      index (i, k, 0)
    feat_oh  [K, I, v]   → block (Kt, I, v)       index (k, 0, 0)   I = 2^d - 1
    thr      [K, I]      → block (Kt, I)          index (k, 0)
    lut      [K, C, N]   → block (Kt, C, Nt)      index (k, 0, j)
    out      [T, N]      → block (Tt, Nt)         index (i, j)

VMEM working set ≈ Tt·Kt·v + Kt·I·v + Kt·C·Nt + Tt·Nt floats.
Defaults (Tt=256, Kt=128, Nt=256, C=16, v=8): ≈ 2.6 MB ≪ 128 MB VMEM, and
the MXU contraction dims (Kt·C = 2048, Nt = 256) are 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["default_interpret", "fuzzy_lut_kernel", "fuzzy_lut_pallas",
           "resolve_strategy"]


def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached.

    This is THE static gate for the non-interpret path: callers pass
    ``interpret=None`` and get the Mosaic-compiled kernel on TPU, the
    traceable interpreter everywhere else (CPU CI, tests). The flag is a
    static jit arg throughout, so both modes live in separate compile-cache
    entries and can coexist in one process.
    """
    return jax.default_backend() != "tpu"


def _tpu_compiler_params(dimension_semantics: tuple[str, ...]):
    """dimension_semantics plumbing across pallas API versions."""
    try:
        return pltpu.CompilerParams(dimension_semantics=dimension_semantics)
    except (AttributeError, TypeError):  # older API
        return dict(mosaic=dict(dimension_semantics=dimension_semantics))


def _tree_leaf(x, feat_oh, thr, *, depth: int, strategy: str):
    """Shared descent: [Tt, Kt, v] activations → [Tt, Kt] leaf indices.

    Both strategies compute the SAME bits (identical fp compare); they differ
    only in how the per-level bit is *selected*:
      ``mxu``    — one-hot reduction over nodes (branchless, gather-free;
                   what the systolic/VPU path wants)
      ``lookup`` — take_along_axis on the bit tensor (O(T·K) per level; what
                   the interpreter/CPU wants — the one-hot form does C× the
                   work a scalar core has to execute serially)
    """
    # feature values at every internal node: vals[t,k,n] = x[t,k,feat[k,n]]
    # — expressed as an einsum against the precomputed one-hot, not a gather.
    vals = jax.lax.dot_general(
        x,
        feat_oh,
        # contract v; batch over k
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    )                                             # [Kt, Tt, I]
    vals = vals.transpose(1, 0, 2)                # [Tt, Kt, I]
    bits = (vals > thr[None]).astype(jnp.int32)   # decision at every node

    tt, kt = x.shape[0], x.shape[1]
    n_internal = thr.shape[-1]
    node = jnp.zeros((tt, kt), dtype=jnp.int32)
    if strategy == "lookup":
        for _ in range(depth):
            bit = jnp.take_along_axis(bits, node[:, :, None], axis=-1)[..., 0]
            node = 2 * node + 1 + bit
    else:
        # branchless: select this level's bit with a one-hot over nodes
        iota_nodes = jax.lax.broadcasted_iota(jnp.int32, (tt, kt, n_internal), 2)
        for _ in range(depth):
            node_oh = (iota_nodes == node[:, :, None]).astype(jnp.int32)
            bit = jnp.sum(bits * node_oh, axis=-1)  # [Tt, Kt]
            node = 2 * node + 1 + bit
    return node - n_internal                      # [Tt, Kt] in [0, C)


def _lut_contrib(leaf, lut, *, strategy: str, scale=None):
    """Map + SumReduce over one tile: [Tt, Kt] leaves × [Kt, C, Nt] LUT →
    [Tt, Nt] contributions. ``scale`` ([Kt] per-group dequant factors, q8
    path) folds in exactly — it is constant within a group, and both
    realizations sum over (group, centroid).

      ``mxu``    — onehot(leaf) [Tt, Kt·C] @ lut [Kt·C, Nt]: one systolic
                   matmul, gather-free.
      ``lookup`` — take_along_axis gather-sum: O(T·K·N) instead of the
                   matmul's O(T·K·C·N); the interpreter/CPU-fast form.
    """
    tt, kt = leaf.shape
    c = lut.shape[1]
    if strategy == "lookup":
        rows = jnp.take_along_axis(
            lut[None], leaf[:, :, None, None], axis=2
        )[:, :, 0, :]                             # [Tt, Kt, Nt]
        if scale is not None:
            rows = rows * scale[None, :, None]
        return rows.sum(axis=1)                   # [Tt, Nt]
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (tt, kt, c), 2)
    onehot = (iota_c == leaf[:, :, None]).astype(jnp.float32)
    if scale is not None:
        onehot = onehot * scale[None, :, None]
    return jax.lax.dot_general(
        onehot.reshape(tt, kt * c),
        lut.reshape(kt * c, -1),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # [Tt, Nt]


def fuzzy_lut_kernel(
    x_ref, feat_oh_ref, thr_ref, lut_ref, out_ref, *, depth: int,
    strategy: str = "mxu",
):
    """One (Tt, Nt, Kt) tile: descend trees, accumulate LUT rows into out."""
    x = x_ref[...].astype(jnp.float32)            # [Tt, Kt, v]
    feat_oh = feat_oh_ref[...].astype(jnp.float32)  # [Kt, I, v]
    thr = thr_ref[...].astype(jnp.float32)        # [Kt, I]

    leaf = _tree_leaf(x, feat_oh, thr, depth=depth, strategy=strategy)
    lut = lut_ref[...].astype(jnp.float32)        # [Kt, C, Nt]
    contrib = _lut_contrib(leaf, lut, strategy=strategy)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] += contrib


def resolve_strategy(strategy: str, interpret: bool) -> str:
    """``auto`` → ``lookup`` under the interpreter (CPU executes the one-hot
    matmul's C× redundant work serially), ``mxu`` on compiled TPU (systolic
    arrays eat dense matmuls; gathers don't vectorize). Both strategies are
    semantics-identical and parity-tested against each other."""
    if strategy == "auto":
        return "lookup" if interpret else "mxu"
    if strategy not in ("mxu", "lookup"):
        raise ValueError(f"unknown strategy {strategy!r}; expected auto|mxu|lookup")
    return strategy


@functools.partial(
    jax.jit,
    static_argnames=("depth", "block_t", "block_n", "block_k", "interpret",
                     "strategy"),
)
def fuzzy_lut_pallas(
    x: jax.Array,          # [T, K, v]
    feat_oh: jax.Array,    # [K, I, v] one-hot of split features (offline)
    thresholds: jax.Array, # [K, I]
    lut: jax.Array,        # [K, C, N]
    *,
    depth: int,
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
    strategy: str = "auto",
) -> jax.Array:
    """Pallas-tiled fused Pegasus matmul. Returns [T, N] f32 (no bias).

    Fully traceable inside an outer ``jax.jit`` (the engine jits whole-plan
    forwards through here); ``interpret`` and ``strategy`` are static args,
    ``None``/``"auto"`` resolve via :func:`default_interpret` /
    :func:`resolve_strategy`.
    """
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k, v = x.shape
    _, c, n = lut.shape
    bt, bn, bk = min(block_t, t), min(block_n, n), min(block_k, k)
    assert t % bt == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({t},{k},{n}) not divisible by blocks ({bt},{bk},{bn}); "
        "pad in ops.py"
    )
    n_internal = c - 1

    grid = (t // bt, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(fuzzy_lut_kernel, depth=depth, strategy=strategy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk, v), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bk, n_internal, v), lambda i, j, kk: (kk, 0, 0)),
            pl.BlockSpec((bk, n_internal), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((bk, c, bn), lambda i, j, kk: (kk, 0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        compiler_params=_tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, feat_oh, thresholds, lut)
