"""int8-LUT variant of the fused fuzzy-LUT kernel (beyond-paper §Perf D4).

LUT rows are stored int8 with one f32 scale per partition group; the scale
is folded into the one-hot BEFORE the MXU matmul (exact — the matmul sums
over (group, centroid) and the scale is constant within a group):

    y = Σ_k s_k · LUT8[k, idx_k]  ==  (onehot ⊙ s)[T, K·C] @ LUT8[K·C, N]

Wire effect at decode: LUT bytes halve vs bf16; with v=16, C=16 the total
weight-byte cost is 0.5·(C/v)=0.5× the dense bf16 weights — the decode
memory-roofline lever recorded in EXPERIMENTS.md §Perf D4.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .kernel import (
    _lut_contrib,
    _tpu_compiler_params,
    _tree_leaf,
    default_interpret,
    resolve_strategy,
)

__all__ = ["quantize_lut_int8", "fuzzy_lut_q8_pallas", "fuzzy_lut_q8_ref"]


def quantize_lut_int8(lut: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-group symmetric int8 quantization. [K,C,N] → (int8 [K,C,N], f32 [K])."""
    amax = jnp.max(jnp.abs(lut.astype(jnp.float32)), axis=(1, 2))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(lut.astype(jnp.float32) / scale[:, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def fuzzy_lut_q8_ref(x, features, thresholds, lut_q8, scales):
    """Oracle: hard descent + dequantized gather-sum."""
    from .ref import tree_descent_ref

    idx = tree_descent_ref(x, features, thresholds)            # [T, K]
    gathered = jnp.take_along_axis(
        lut_q8[None].astype(jnp.float32), idx[:, :, None, None], axis=2
    )[:, :, 0, :]                                              # [T, K, N]
    return (gathered * scales[None, :, None]).sum(axis=1)


def _q8_kernel(x_ref, feat_oh_ref, thr_ref, lut_ref, scale_ref, out_ref, *,
               depth, strategy: str = "mxu"):
    x = x_ref[...].astype(jnp.float32)
    feat_oh = feat_oh_ref[...].astype(jnp.float32)
    thr = thr_ref[...].astype(jnp.float32)

    leaf = _tree_leaf(x, feat_oh, thr, depth=depth, strategy=strategy)
    contrib = _lut_contrib(
        leaf, lut_ref[...].astype(jnp.float32), strategy=strategy,
        scale=scale_ref[...].astype(jnp.float32))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("depth", "block_t", "block_n", "block_k",
                              "interpret", "strategy"))
def fuzzy_lut_q8_pallas(
    x, feat_oh, thresholds, lut_q8, scales, *,
    depth: int, block_t: int = 256, block_n: int = 256, block_k: int = 128,
    interpret: bool | None = None, strategy: str = "auto",
):
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k, v = x.shape
    _, c, n = lut_q8.shape
    bt, bn, bk = min(block_t, t), min(block_n, n), min(block_k, k)
    assert t % bt == 0 and n % bn == 0 and k % bk == 0
    n_internal = c - 1
    grid = (t // bt, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_q8_kernel, depth=depth, strategy=strategy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk, v), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bk, n_internal, v), lambda i, j, kk: (kk, 0, 0)),
            pl.BlockSpec((bk, n_internal), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((bk, c, bn), lambda i, j, kk: (kk, 0, j)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        compiler_params=_tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, feat_oh, thresholds, lut_q8, scales)
