"""int8-LUT variant of the fused fuzzy-LUT kernel (beyond-paper §Perf D4).

LUT rows are stored int8 with one f32 scale per partition group; the scale
is folded into the one-hot BEFORE the MXU matmul (exact — the matmul sums
over (group, centroid) and the scale is constant within a group):

    y = Σ_k s_k · LUT8[k, idx_k]  ==  (onehot ⊙ s)[T, K·C] @ LUT8[K·C, N]

Wire effect at decode: LUT bytes halve vs bf16; with v=16, C=16 the total
weight-byte cost is 0.5·(C/v)=0.5× the dense bf16 weights — the decode
memory-roofline lever recorded in EXPERIMENTS.md §Perf D4.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .kernel import (
    STACK_BLOCK_T,
    _check_divisible,
    _lut_contrib,
    _stack_layers,
    _tpu_compiler_params,
    _tree_leaf,
    default_interpret,
    resolve_strategy,
)

__all__ = ["quantize_lut_int8", "fuzzy_lut_q8_pallas", "fuzzy_lut_q8_ref",
           "fuzzy_lut_stack_q8_pallas"]


def quantize_lut_int8(lut: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-group symmetric int8 quantization. [K,C,N] → (int8 [K,C,N], f32 [K])."""
    amax = jnp.max(jnp.abs(lut.astype(jnp.float32)), axis=(1, 2))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(lut.astype(jnp.float32) / scale[:, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def fuzzy_lut_q8_ref(x, features, thresholds, lut_q8, scales):
    """Oracle: hard descent + dequantized gather-sum."""
    from .ref import tree_descent_ref

    idx = tree_descent_ref(x, features, thresholds)            # [T, K]
    gathered = jnp.take_along_axis(
        lut_q8[None].astype(jnp.float32), idx[:, :, None, None], axis=2
    )[:, :, 0, :]                                              # [T, K, N]
    return (gathered * scales[None, :, None]).sum(axis=1)


def _q8_kernel(x_ref, feat_oh_ref, thr_ref, lut_ref, scale_ref, out_ref, *,
               depth, strategy: str = "mxu"):
    x = x_ref[...].astype(jnp.float32)
    feat_oh = feat_oh_ref[...].astype(jnp.float32)
    thr = thr_ref[...].astype(jnp.float32)

    leaf = _tree_leaf(x, feat_oh, thr, depth=depth, strategy=strategy)
    contrib = _lut_contrib(
        leaf, lut_ref[...].astype(jnp.float32), strategy=strategy,
        scale=scale_ref[...].astype(jnp.float32))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] += contrib


@functools.partial(
    jax.jit, static_argnames=("depth", "block_t", "block_n", "block_k",
                              "interpret", "strategy"))
def fuzzy_lut_q8_pallas(
    x, feat_oh, thresholds, lut_q8, scales, *,
    depth: int, block_t: int = 256, block_n: int = 256, block_k: int = 128,
    interpret: bool | None = None, strategy: str = "auto",
):
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k, v = x.shape
    _, c, n = lut_q8.shape
    bt, bn, bk = min(block_t, t), min(block_n, n), min(block_k, k)
    _check_divisible("fuzzy_lut_q8_pallas", T=(t, bt), N=(n, bn), K=(k, bk))
    n_internal = c - 1
    grid = (t // bt, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_q8_kernel, depth=depth, strategy=strategy),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk, v), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((bk, n_internal, v), lambda i, j, kk: (kk, 0, 0)),
            pl.BlockSpec((bk, n_internal), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((bk, c, bn), lambda i, j, kk: (kk, 0, j)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        compiler_params=_tpu_compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, feat_oh, thresholds, lut_q8, scales)


def _stack_q8_kernel(x_ref, feat_oh_ref, thr_ref, lut_ref, scale_ref,
                     bias_ref, out_ref, *, depth, ks, v, n_out, strategy):
    """One batch tile through ALL L fused banks; int8 LUT stack dequantized
    in-VMEM via the per-(layer, group) scale factors."""
    y = _stack_layers(
        x_ref[...].astype(jnp.float32), feat_oh_ref, thr_ref, lut_ref,
        bias_ref, scale_ref, depth=depth, ks=ks, v=v, strategy=strategy)
    out_ref[...] = y[:, :n_out]


@functools.partial(
    jax.jit, static_argnames=("depth", "ks", "n_out", "block_t", "interpret",
                              "strategy"))
def fuzzy_lut_stack_q8_pallas(
    x,            # [T, K₀, v]
    feat_oh,      # [L, Kmax, I, v]
    thr,          # [L, Kmax, I]
    lut_q8,       # [L, Kmax, C, Nmax] int8
    scales,       # [L, Kmax] f32 per-(layer, group) dequant factors
    bias,         # [L, Nmax]
    *,
    depth: int,
    ks: tuple[int, ...],
    n_out: int,
    block_t: int = STACK_BLOCK_T,
    interpret: bool | None = None,
    strategy: str = "auto",
):
    """int8 stacked-layer kernel: the fused counterpart of
    :func:`fuzzy_lut_q8_pallas` — LUT bytes stay halved in HBM AND the
    dequantized rows never leave VMEM between banks. Contract mirrors
    :func:`repro.kernels.fuzzy_lut.kernel.fuzzy_lut_stack_pallas`."""
    if interpret is None:
        interpret = default_interpret()
    strategy = resolve_strategy(strategy, interpret)
    t, k0, v = x.shape
    nlayers, kmax, c, nmax = lut_q8.shape
    n_internal = thr.shape[2]
    if len(ks) != nlayers:
        raise ValueError(f"ks has {len(ks)} entries for {nlayers} stacked layers")
    if k0 != ks[0]:
        raise ValueError(f"x carries K={k0} groups; ks[0]={ks[0]}")
    bt = min(block_t, t)
    _check_divisible("fuzzy_lut_stack_q8_pallas", T=(t, bt))

    return pl.pallas_call(
        functools.partial(_stack_q8_kernel, depth=depth, ks=ks, v=v,
                          n_out=n_out, strategy=strategy),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, k0, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((nlayers, kmax, n_internal, v), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((nlayers, kmax, n_internal), lambda i: (0, 0, 0)),
            pl.BlockSpec((nlayers, kmax, c, nmax), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((nlayers, kmax), lambda i: (0, 0)),
            pl.BlockSpec((nlayers, nmax), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n_out), jnp.float32),
        compiler_params=_tpu_compiler_params(("arbitrary",)),
        interpret=interpret,
    )(x, feat_oh, thr, lut_q8, scales, bias)
