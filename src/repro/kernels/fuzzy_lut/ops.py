"""Jit'd public wrapper for the fused fuzzy-LUT matmul kernel.

Handles layout prep (grouping, one-hot features, padding to block multiples)
and exposes a `PegasusLinear`-level entry point used by the serving stack
(`repro.core.amm.pegasus_linear_apply(..., path="kernel")`).
"""

from __future__ import annotations

import weakref

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import fuzzy_lut_pallas

__all__ = [
    "fuzzy_lut_matmul", "fuzzy_lut_matmul_q8", "prepare_feat_onehot",
    "quantized_lut_cached", "QUANT_STATS",
]

# int8-LUT memo: production deployments quantize offline exactly once; the
# convenience wrapper below used to re-quantize the whole bank on EVERY call.
# Keyed on the layer instance id; a weakref finalizer evicts the entry when
# the layer dies so ids can be reused safely.
QUANT_STATS = {"quantize_calls": 0, "cache_hits": 0}
_Q8_MEMO: dict[int, tuple] = {}


def quantized_lut_cached(layer) -> tuple[jax.Array, jax.Array]:
    """(int8 LUT, per-group f32 scales) for a PegasusLinear, memoized."""
    from .quantized import quantize_lut_int8

    key = id(layer)
    entry = _Q8_MEMO.get(key)
    if entry is not None and entry[0]() is layer:
        QUANT_STATS["cache_hits"] += 1
        return entry[1], entry[2]
    lut_q8, scales = quantize_lut_int8(layer.lut.astype(jnp.float32))
    QUANT_STATS["quantize_calls"] += 1
    ref = weakref.ref(layer, lambda _ref, key=key: _Q8_MEMO.pop(key, None))
    _Q8_MEMO[key] = (ref, lut_q8, scales)
    return lut_q8, scales


def prepare_feat_onehot(features: jax.Array, group_size: int) -> jax.Array:
    """Offline: one-hot the per-node split features. [K, I] → [K, I, v]."""
    return jax.nn.one_hot(features, group_size, dtype=jnp.float32)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def fuzzy_lut_matmul(
    layer,  # PegasusLinear (kept duck-typed to avoid import cycle)
    x: jax.Array,
    *,
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply a PegasusLinear via the Pallas kernel. x: [..., D] → [..., N]."""
    k, v = layer.num_groups, layer.group_size
    n = layer.out_features
    lead = x.shape[:-1]
    xg = x.reshape(-1, k, v).astype(jnp.float32)
    t = xg.shape[0]

    feat_oh = prepare_feat_onehot(layer.trees.features, v)
    thr = layer.trees.thresholds
    # +inf thresholds (degenerate nodes) force all-left in fp compare: keep.

    bt = min(block_t, max(8, t))
    # pad T and K to block multiples; padded K groups have zero LUT → no-op
    xg_p = _pad_to(xg, 0, bt)
    xg_p = _pad_to(xg_p, 1, min(block_k, k))
    kp = xg_p.shape[1]
    if kp != k:
        feat_oh = _pad_to(feat_oh, 0, min(block_k, k))
        thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
        lut = _pad_to(layer.lut, 0, min(block_k, k))
    else:
        lut = layer.lut
    lut = _pad_to(lut, 2, min(block_n, n))

    y = fuzzy_lut_pallas(
        xg_p,
        feat_oh,
        thr,
        lut,
        depth=int(np.log2(layer.num_centroids) + 0.5),
        block_t=bt,
        block_n=min(block_n, lut.shape[2]),
        block_k=min(block_k, kp),
        interpret=interpret,
    )
    y = y[:t, :n]
    if layer.bias is not None:
        y = y + layer.bias
    return y.reshape(*lead, n)


def fuzzy_lut_matmul_q8(
    layer, x: jax.Array, *, block_t: int = 256, block_n: int = 256,
    block_k: int = 128, interpret: bool | None = None,
) -> jax.Array:
    """int8-LUT kernel path: quantize the bank once, run the q8 kernel.

    Production deployments quantize offline and keep only the int8 LUT in
    HBM (half the bytes — the decode-roofline lever, EXPERIMENTS §Perf D4);
    the quantization is memoized per layer (``quantized_lut_cached``) so
    repeated calls pay it exactly once.
    """
    from .quantized import fuzzy_lut_q8_pallas

    k, v = layer.num_groups, layer.group_size
    n = layer.out_features
    lead = x.shape[:-1]
    xg = x.reshape(-1, k, v).astype(jnp.float32)
    t = xg.shape[0]

    feat_oh = prepare_feat_onehot(layer.trees.features, v)
    thr = layer.trees.thresholds
    lut_q8, scales = quantized_lut_cached(layer)

    bt = min(block_t, max(8, t))
    xg_p = _pad_to(xg, 0, bt)
    xg_p = _pad_to(xg_p, 1, min(block_k, k))
    kp = xg_p.shape[1]
    if kp != k:
        feat_oh = _pad_to(feat_oh, 0, min(block_k, k))
        thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
        lut_q8 = _pad_to(lut_q8, 0, min(block_k, k))
        scales = jnp.pad(scales, (0, kp - k))
    lut_q8 = _pad_to(lut_q8, 2, min(block_n, n))

    y = fuzzy_lut_q8_pallas(
        xg_p, feat_oh, thr, lut_q8, scales,
        depth=int(np.log2(layer.num_centroids) + 0.5),
        block_t=bt, block_n=min(block_n, lut_q8.shape[2]),
        block_k=min(block_k, kp), interpret=interpret,
    )
    y = y[:t, :n]
    if layer.bias is not None:
        y = y + layer.bias
    return y.reshape(*lead, n)
