"""Jit'd public wrapper for the fused fuzzy-LUT matmul kernel.

Handles layout prep (grouping, one-hot features, padding to block multiples)
and exposes a `PegasusLinear`-level entry point used by the serving stack
(`repro.core.amm.pegasus_linear_apply(..., path="kernel")`).
"""

from __future__ import annotations

import weakref

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import fuzzy_lut_pallas

__all__ = [
    "fuzzy_lut_matmul", "fuzzy_lut_matmul_q8", "padded_layout",
    "prepare_feat_onehot", "quantized_lut_cached", "LAYOUT_STATS",
    "QUANT_STATS",
]

# int8-LUT memo: production deployments quantize offline exactly once; the
# convenience wrapper below used to re-quantize the whole bank on EVERY call.
# Keyed on the layer instance id; a weakref finalizer evicts the entry when
# the layer dies so ids can be reused safely.
QUANT_STATS = {"quantize_calls": 0, "cache_hits": 0}
_Q8_MEMO: dict[int, tuple] = {}

# Static-operand layout memo: the one-hot feature tensor and the
# block-divisibility padding of (lut, thresholds, feat_oh) depend only on the
# layer and block geometry, yet the wrappers below used to rebuild them on
# EVERY call — a pad/copy of the whole table bank per invocation when shapes
# weren't block-divisible. One entry per (layer id, block_k, block_n, q8?),
# weakref-evicted with the layer like the q8 memo. At call time the cached
# layout is shape-CHECKED, never re-padded: only the batch may pad per call.
LAYOUT_STATS = {"layout_builds": 0, "cache_hits": 0}
_LAYOUT_MEMO: dict[tuple, tuple] = {}


def quantized_lut_cached(layer) -> tuple[jax.Array, jax.Array]:
    """(int8 LUT, per-group f32 scales) for a PegasusLinear, memoized."""
    from .quantized import quantize_lut_int8

    key = id(layer)
    entry = _Q8_MEMO.get(key)
    if entry is not None and entry[0]() is layer:
        QUANT_STATS["cache_hits"] += 1
        return entry[1], entry[2]
    lut_q8, scales = quantize_lut_int8(layer.lut.astype(jnp.float32))
    QUANT_STATS["quantize_calls"] += 1
    ref = weakref.ref(layer, lambda _ref, key=key: _Q8_MEMO.pop(key, None))
    _Q8_MEMO[key] = (ref, lut_q8, scales)
    return lut_q8, scales


def prepare_feat_onehot(features: jax.Array, group_size: int) -> jax.Array:
    """Offline: one-hot the per-node split features. [K, I] → [K, I, v]."""
    return jax.nn.one_hot(features, group_size, dtype=jnp.float32)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def padded_layout(layer, *, block_k: int, block_n: int, quant: bool):
    """Block-padded static operands for one PegasusLinear, memoized.

    Returns ``(feat_oh, thr, lut, scales, kp)`` — every tensor padded so the
    kernel's divisibility contract holds (K → ``kp`` with +inf thresholds and
    zero LUT rows, N → a ``block_n`` multiple with zero columns). ``scales``
    is None unless ``quant``. Built exactly once per (layer, geometry); the
    call-path wrappers ASSERT the cached shapes instead of re-padding.
    """
    k, v, n = layer.num_groups, layer.group_size, layer.out_features
    bk, bn = min(block_k, k), min(block_n, n)
    key = (id(layer), bk, bn, quant)
    entry = _LAYOUT_MEMO.get(key)
    if entry is not None and entry[0]() is layer:
        LAYOUT_STATS["cache_hits"] += 1
        if quant:
            # the cached layout embeds the cached quantization — keep the
            # q8 memo's observable hit contract for callers that count it
            QUANT_STATS["cache_hits"] += 1
        return entry[1]
    feat_oh = prepare_feat_onehot(layer.trees.features, v)
    thr = layer.trees.thresholds
    scales = None
    if quant:
        lut, scales = quantized_lut_cached(layer)
    else:
        lut = layer.lut
    kp = k + (-k) % bk
    if kp != k:
        feat_oh = _pad_to(feat_oh, 0, bk)
        thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
        lut = _pad_to(lut, 0, bk)
        if scales is not None:
            scales = jnp.pad(scales, (0, kp - k))
    lut = _pad_to(lut, 2, bn)
    layout = (feat_oh, thr, lut, scales, kp)
    LAYOUT_STATS["layout_builds"] += 1
    ref = weakref.ref(layer, lambda _ref, key=key: _LAYOUT_MEMO.pop(key, None))
    _LAYOUT_MEMO[key] = (ref, layout)
    return layout


def fuzzy_lut_matmul(
    layer,  # PegasusLinear (kept duck-typed to avoid import cycle)
    x: jax.Array,
    *,
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Apply a PegasusLinear via the Pallas kernel. x: [..., D] → [..., N]."""
    k, v = layer.num_groups, layer.group_size
    n = layer.out_features
    lead = x.shape[:-1]
    xg = x.reshape(-1, k, v).astype(jnp.float32)
    t = xg.shape[0]

    # static operands come block-padded from the one-time layout memo
    # (+inf thresholds on padded/degenerate nodes force all-left: keep);
    # the only per-call padding left is the batch itself.
    feat_oh, thr, lut, _, kp = padded_layout(
        layer, block_k=block_k, block_n=block_n, quant=False)
    assert lut.shape[0] == kp and thr.shape[0] == kp, (
        "cached layout shape drifted — rebuild the layout memo")

    bt = min(block_t, max(8, t))
    xg_p = _pad_to(_pad_to(xg, 0, bt), 1, min(block_k, k))

    y = fuzzy_lut_pallas(
        xg_p,
        feat_oh,
        thr,
        lut,
        depth=int(np.log2(layer.num_centroids) + 0.5),
        block_t=bt,
        block_n=min(block_n, lut.shape[2]),
        block_k=min(block_k, kp),
        interpret=interpret,
    )
    y = y[:t, :n]
    if layer.bias is not None:
        y = y + layer.bias
    return y.reshape(*lead, n)


def fuzzy_lut_matmul_q8(
    layer, x: jax.Array, *, block_t: int = 256, block_n: int = 256,
    block_k: int = 128, interpret: bool | None = None,
) -> jax.Array:
    """int8-LUT kernel path: quantize the bank once, run the q8 kernel.

    Production deployments quantize offline and keep only the int8 LUT in
    HBM (half the bytes — the decode-roofline lever, EXPERIMENTS §Perf D4);
    the quantization is memoized per layer (``quantized_lut_cached``) so
    repeated calls pay it exactly once.
    """
    from .quantized import fuzzy_lut_q8_pallas

    k, v = layer.num_groups, layer.group_size
    n = layer.out_features
    lead = x.shape[:-1]
    xg = x.reshape(-1, k, v).astype(jnp.float32)
    t = xg.shape[0]

    feat_oh, thr, lut_q8, scales, kp = padded_layout(
        layer, block_k=block_k, block_n=block_n, quant=True)
    assert lut_q8.shape[0] == kp and scales.shape[0] == kp, (
        "cached layout shape drifted — rebuild the layout memo")

    bt = min(block_t, max(8, t))
    xg_p = _pad_to(_pad_to(xg, 0, bt), 1, min(block_k, k))

    y = fuzzy_lut_q8_pallas(
        xg_p, feat_oh, thr, lut_q8, scales,
        depth=int(np.log2(layer.num_centroids) + 0.5),
        block_t=bt, block_n=min(block_n, lut_q8.shape[2]),
        block_k=min(block_k, kp), interpret=interpret,
    )
    y = y[:t, :n]
    if layer.bias is not None:
        y = y + layer.bias
    return y.reshape(*lead, n)
