"""Pure-jnp oracle for the fused fuzzy-LUT matmul kernel.

Semantics: for grouped input ``x: [T, K, v]``, stacked depth-d trees
(``features: [K, 2^d - 1]`` int32, ``thresholds: [K, 2^d - 1]`` f32) and a
LUT bank ``lut: [K, C, N]`` (C = 2^d):

    idx[t, k] = leaf index of x[t, k] under tree k       (hard descent)
    y[t]      = sum_k lut[k, idx[t, k]]  (+ bias)

This is Partition→Map→SumReduce for Weighted Aggregation (paper §5), i.e.
the Pegasus approximate matmul. All kernel variants must match this oracle
bitwise-closely (fp32) for every shape/dtype in the sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["fuzzy_lut_matmul_ref", "tree_descent_ref"]


def tree_descent_ref(
    x: jax.Array, features: jax.Array, thresholds: jax.Array
) -> jax.Array:
    """Hard tree descent. x: [T, K, v] → leaf idx [T, K] int32."""
    n_internal = features.shape[-1]
    depth = (n_internal + 1).bit_length() - 1
    k = x.shape[-2]
    karange = jnp.arange(k)
    node = jnp.zeros(x.shape[:-1], dtype=jnp.int32)  # [T, K]
    for _ in range(depth):
        feat = features[karange, node]  # [T, K]
        thr = thresholds[karange, node]
        val = jnp.take_along_axis(x, feat[..., None], axis=-1)[..., 0]
        node = 2 * node + 1 + (val > thr).astype(jnp.int32)
    return node - n_internal


def fuzzy_lut_matmul_ref(
    x: jax.Array,
    features: jax.Array,
    thresholds: jax.Array,
    lut: jax.Array,
    bias: jax.Array | None = None,
) -> jax.Array:
    """Oracle: gather leaf rows per group and sum. Returns [T, N] f32."""
    idx = tree_descent_ref(x, features, thresholds)  # [T, K]
    t, k = idx.shape
    gathered = jnp.take_along_axis(
        lut[None].astype(jnp.float32), idx[:, :, None, None], axis=2
    )[:, :, 0, :]  # [T, K, N]
    y = gathered.sum(axis=1)
    if bias is not None:
        y = y + bias
    return y
