"""ExecutionPlan: compile a pegasusified model once, call it many times.

The hand-rolled apply paths in ``repro.nets.*`` re-derived the kernel layout
on every invocation — feature one-hots, block padding, and (for the q8 path)
int8 quantization of the whole LUT bank. Quark-style all-on-dataplane designs
and FENIX's offload pipeline both treat that state as *precompiled*; this
module does the same for the TPU realization:

  * :class:`CompiledBank` — one ``PegasusLinear`` plus every tensor the fused
    Pallas kernel needs, built exactly once (`feat_onehot`, +inf-padded
    thresholds, block-padded LUT, int8 LUT + per-group scales). Registered as
    a jax pytree so a whole plan's banks flow through ``jax.jit`` as traced
    state rather than baked-in constants.
  * :class:`ExecutionPlan` — the whole model: compiled banks + a structural
    forward (sequential stack, windowed CNN, unrolled RNN, two-level NAM)
    that is a *pure function* of ``(state, inputs)`` closed over static
    shapes, so the entire forward traces into ONE jitted XLA computation per
    ``(backend, batch-bucket)``.
  * **Batch bucketing** — request batches are zero-padded up to a bounded
    set of bucket sizes (powers of two by default, multiples of the largest
    bucket beyond it), so varying request sizes hit a warm compile cache
    instead of retracing per shape. ``EngineStats.jit_traces`` counts actual
    XLA traces; the compile-count tests pin the invariants.
  * :func:`build_plan` — compile a model into a plan. Memoization lives in
    :mod:`repro.engine.registry` (:class:`PlanRegistry` / :func:`plan_for`):
    weakref-watched, bounded, explicitly evictable entries. To support that,
    a plan holds a *detached replica* of each bank layer (same arrays, new
    dataclass instance) — compiling a model never pins the caller's model
    objects, so dropping the model lets the registry reclaim its plan.

Backends are semantics-identical up to quantization:
  ``gather``    — take_along_axis reference (XLA)
  ``onehot``    — one-hot × LUT matmul (MXU-friendly XLA)
  ``kernel``    — fused Pallas fuzzy-LUT kernel
  ``kernel_q8`` — fused Pallas kernel over the cached int8 LUT
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, apply_gather, apply_onehot
from repro.core.fuzzy_tree import hard_index
from repro.kernels.fuzzy_lut.kernel import (
    default_interpret,
    fuzzy_lut_pallas,
    resolve_strategy,
)
from repro.kernels.fuzzy_lut.ops import prepare_feat_onehot, quantized_lut_cached
from repro.kernels.fuzzy_lut.quantized import fuzzy_lut_q8_pallas

__all__ = [
    "BACKENDS",
    "DEFAULT_BUCKETS",
    "STATS",
    "CompiledBank",
    "EngineStats",
    "ExecutionPlan",
    "bucket_batch",
    "bucket_chunks",
    "build_plan",
]

BACKENDS = ("gather", "onehot", "kernel", "kernel_q8")

# Bounded bucket set: odd batch sizes round UP to the nearest bucket (zero
# rows are sliced off after the call), so the jit cache holds at most
# ``len(DEFAULT_BUCKETS)`` entries per backend for any batch ≤ the largest
# bucket; beyond it, batches round to multiples of the largest bucket.
DEFAULT_BUCKETS: tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_batch(b: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Round a batch size up to its compile bucket (smallest bucket ≥ b;
    beyond the largest, the next multiple of it)."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    for s in sorted(buckets):
        if b <= s:
            return int(s)
    top = int(max(buckets))
    return -(-b // top) * top


def bucket_chunks(
    total: int,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    max_batch: int | None = None,
) -> list[int]:
    """Split ``total`` coalesced flows into bucket-aligned micro-batch sizes.

    Full chunks are exact bucket sizes (zero pad rows); the tail dispatches
    either as one padded chunk or as an exact bucket plus a smaller padded
    chunk — whichever wastes fewer padded rows. This replaces fixed-stride
    chunking (the old ``max_batch=1024`` slicing), which ignored the bucket
    ladder and could split a 2048-flow batch that has its own exact bucket.
    """
    if total <= 0:
        raise ValueError(f"total must be positive, got {total}")
    bs = sorted(int(b) for b in buckets)
    if max_batch is None:
        top = bs[-1]
    else:
        fits = [b for b in bs if b <= max_batch]
        # max_batch below the smallest bucket cannot bound anything: every
        # dispatch pads up to bs[0] anyway, so sub-bucket chunking would
        # only multiply padded work — clamp to one smallest-bucket chunk
        top = fits[-1] if fits else bs[0]
    sizes = []
    remaining = total
    while remaining > top:
        sizes.append(top)
        remaining -= top
    if remaining:
        fit = max((b for b in bs if b <= remaining), default=0)
        if 0 < fit < remaining:
            pad_whole = bucket_batch(remaining, bs) - remaining
            rest = remaining - fit
            pad_split = bucket_batch(rest, bs) - rest
            if pad_split < pad_whole:
                sizes.append(fit)
                remaining = rest
        sizes.append(remaining)
    return sizes


@dataclasses.dataclass
class EngineStats:
    """Global counters — the parity/caching tests assert layout work happens
    at plan-build time only, and whole-plan XLA traces happen at most once
    per (backend, batch-bucket), never per call."""

    layout_builds: int = 0   # CompiledBank layout preparations
    plan_builds: int = 0     # ExecutionPlan compilations
    plan_cache_hits: int = 0  # plan_for() served from the memo
    bank_calls: int = 0      # CompiledBank.apply invocations (eager or trace)
    jit_traces: int = 0      # whole-plan forward traces (one per compile)
    jit_calls: int = 0       # jitted plan dispatches (hits = calls - traces)

    def reset(self) -> None:
        self.layout_builds = 0
        self.plan_builds = 0
        self.plan_cache_hits = 0
        self.bank_calls = 0
        self.jit_traces = 0
        self.jit_calls = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


STATS = EngineStats()


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


@jax.tree_util.register_pytree_node_class
class CompiledBank:
    """One PegasusLinear with its kernel layout precomputed and frozen.

    All layout work (one-hot of split features, +inf threshold padding,
    block padding of the LUT along K and N, int8 quantization + scales)
    happens in ``__init__``; ``apply`` only pads the activations.

    Pytree protocol: the tensors are leaves, the block geometry is static
    aux data — so banks can ride through ``jax.jit`` as arguments (shared
    across every compiled bucket) instead of being re-embedded as XLA
    constants in each executable.

    ``self.layer`` is a *detached replica* of the source layer (same arrays,
    fresh dataclass instance): a compiled bank must never pin the caller's
    model object, or the registry's drop-the-model-evict-the-plan weakref
    scheme could never fire (the registry keeps its own weakrefs to the
    source layers for staleness checks).
    """

    def __init__(
        self,
        layer: PegasusLinear,
        *,
        block_t: int = 256,
        block_n: int = 256,
        block_k: int = 128,
        interpret: bool | None = None,
        strategy: str = "auto",
    ):
        # q8 memo keyed on the ORIGINAL layer id (shared across rebuilds of
        # the same model); the replica below is what the bank retains.
        lut_q8, scales = quantized_lut_cached(layer)
        self.layer = dataclasses.replace(layer)
        self.block_t = block_t
        self.interpret = default_interpret() if interpret is None else interpret
        self.strategy = resolve_strategy(strategy, self.interpret)

        k, v, n = layer.num_groups, layer.group_size, layer.out_features
        self.depth = int(np.log2(layer.num_centroids) + 0.5)

        # -- layout prep: done ONCE here, never on the call path -----------
        bk = min(block_k, k)
        kp = k + (-k) % bk
        feat_oh = prepare_feat_onehot(layer.trees.features, v)
        thr = layer.trees.thresholds
        lut = layer.lut
        if kp != k:
            feat_oh = _pad_to(feat_oh, 0, bk)
            thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
            lut = _pad_to(lut, 0, bk)
            lut_q8 = _pad_to(lut_q8, 0, bk)
            scales = jnp.pad(scales, (0, kp - k))
        bn = min(block_n, n)
        self.feat_oh = feat_oh
        self.thr = thr
        self.lut_p = _pad_to(lut, 2, bn)
        self.lut_q8_p = _pad_to(lut_q8, 2, bn)
        self.scales = scales
        self.kp = kp
        self.block_n = min(block_n, self.lut_p.shape[2])
        self.block_k = min(block_k, kp)
        STATS.layout_builds += 1

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten(self):
        children = (self.layer, self.feat_oh, self.thr,
                    self.lut_p, self.lut_q8_p, self.scales)
        aux = (self.block_t, self.block_n, self.block_k,
               self.depth, self.kp, self.interpret, self.strategy)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        # bypass __init__: no layout work, no STATS increment — this path
        # runs on every jit flatten/unflatten round-trip
        obj = object.__new__(cls)
        (obj.layer, obj.feat_oh, obj.thr,
         obj.lut_p, obj.lut_q8_p, obj.scales) = children
        (obj.block_t, obj.block_n, obj.block_k,
         obj.depth, obj.kp, obj.interpret, obj.strategy) = aux
        return obj

    # -- backend dispatch ---------------------------------------------------

    def apply(self, x: jax.Array, backend: str) -> jax.Array:
        STATS.bank_calls += 1
        if backend == "gather":
            return apply_gather(self.layer, x)
        if backend == "onehot":
            return apply_onehot(self.layer, x)
        if backend == "kernel":
            return self._apply_kernel(x, self.lut_p, None)
        if backend == "kernel_q8":
            return self._apply_kernel(x, self.lut_q8_p, self.scales)
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")

    def _apply_kernel(self, x, lut, scales) -> jax.Array:
        p = self.layer
        k, v, n = p.num_groups, p.group_size, p.out_features
        lead = x.shape[:-1]
        xg = x.reshape(-1, k, v).astype(jnp.float32)
        t = xg.shape[0]
        bt = min(self.block_t, max(8, t))
        xg = _pad_to(_pad_to(xg, 0, bt), 1, self.block_k)
        if scales is None:
            y = fuzzy_lut_pallas(
                xg, self.feat_oh, self.thr, lut,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
                strategy=self.strategy,
            )
        else:
            y = fuzzy_lut_q8_pallas(
                xg, self.feat_oh, self.thr, lut, scales,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
                strategy=self.strategy,
            )
        y = y[:t, :n]
        if p.bias is not None:
            y = y + p.bias
        return y.reshape(*lead, n)


# ---------------------------------------------------------------------------
# ExecutionPlan + per-family structural forwards
# ---------------------------------------------------------------------------


class _PlanCounters:
    """Per-plan trace instrumentation, held OUTSIDE the plan so the jitted
    forward's closure never references the plan itself (see ExecutionPlan)."""

    __slots__ = ("traces", "buckets")

    def __init__(self):
        self.traces = 0
        self.buckets: set[tuple[str, int]] = set()


class ExecutionPlan:
    """Compiled model: banks + structural forward, backend bound globally.

    The forward is a pure function ``forward(apply, state, *inputs)`` where
    ``state`` is a jax pytree (banks + any captured arrays) and every other
    degree of freedom (window length, NAM flag, block geometry, interpret
    mode) is a static Python value closed over at plan-build. ``__call__``
    pads the batch up to its bucket, dispatches the jitted forward, and
    slices the padding back off — so the whole model is ONE XLA computation
    per ``(backend, bucket)`` and repeated calls at any batch size that maps
    to a warm bucket perform zero Python-per-bank dispatch and zero retraces.
    """

    def __init__(
        self,
        banks: Sequence[CompiledBank],
        forward: Callable[..., jax.Array],
        state: Any,
        *,
        backend: str = "onehot",
        family: str = "sequential",
        bucket_sizes: Sequence[int] | None = None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        self.banks = list(banks)
        self._forward = forward
        self._state = state
        self.backend = backend
        self.family = family
        self.buckets = tuple(sorted(bucket_sizes)) if bucket_sizes else DEFAULT_BUCKETS
        # compile-cache instrumentation (per plan; STATS mirrors globally).
        # The counters live in a detached holder: _pure must not close over
        # `self`, or plan ↔ jit-closure would form a reference cycle and an
        # evicted plan's executables/tensors would linger until a gen-2 GC
        # pass instead of freeing on the registry's refcount drop.
        self._ctr = ctr = _PlanCounters()
        self.jit_calls = 0

        def _pure(state, *inputs, backend):
            # body runs at TRACE time only — this is the retrace counter the
            # bucketing tests assert on
            STATS.jit_traces += 1
            ctr.traces += 1
            ctr.buckets.add((backend, int(inputs[0].shape[0])))
            return forward(lambda bank, x: bank.apply(x, backend), state, *inputs)

        self._jit = jax.jit(_pure, static_argnames=("backend",))
        STATS.plan_builds += 1

    @property
    def trace_count(self) -> int:
        return self._ctr.traces

    @property
    def compiled_buckets(self) -> set:
        return self._ctr.buckets

    def __call__(
        self, *inputs: jax.Array, backend: str | None = None, jit: bool = True
    ) -> jax.Array:
        be = self.backend if backend is None else backend
        if be not in BACKENDS:
            raise ValueError(f"unknown backend {be!r}; expected one of {BACKENDS}")
        if not jit:
            return self._forward(
                lambda bank, x: bank.apply(x, be), self._state, *inputs)
        b = int(np.shape(inputs[0])[0])
        bucket = bucket_batch(b, self.buckets)
        padded = tuple(self._pad_batch(x, bucket) for x in inputs)
        STATS.jit_calls += 1
        self.jit_calls += 1
        y = self._jit(self._state, *padded, backend=be)
        return y if bucket == b else y[:b]

    @staticmethod
    def _pad_batch(x: jax.Array, bucket: int) -> jax.Array:
        if not isinstance(x, jax.Array):   # jnp.asarray on a device array
            x = jnp.asarray(x)             # still costs ~0.1 ms in dtype checks
        b = x.shape[0]
        if b == bucket:
            return x
        pad = [(0, bucket - b)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, pad)

    def compile_stats(self) -> dict:
        """Per-plan jit-cache counters (the serving stats surface)."""
        return {
            "traces": self.trace_count,
            "jit_calls": self.jit_calls,
            "bucket_hits": self.jit_calls - self.trace_count,
            "buckets": sorted(self.compiled_buckets),
        }

    @property
    def num_banks(self) -> int:
        return len(self.banks)

    def bank_inputs(self, *inputs: jax.Array, backend: str = "gather") -> list:
        """Forward once (eagerly), recording the first activation each bank
        receives — a debugging/parity-test aid (None for unreached banks)."""
        rec: dict[int, jax.Array] = {}

        def apply(bank: CompiledBank, x: jax.Array) -> jax.Array:
            rec.setdefault(id(bank), x)
            return bank.apply(x, backend)

        self._forward(apply, self._state, *inputs)
        return [rec.get(id(b)) for b in self.banks]

    def table_bytes(self) -> int:
        """Total LUT bytes held by the plan (fp + q8 layouts)."""
        total = 0
        for b in self.banks:
            total += b.lut_p.size * b.lut_p.dtype.itemsize
            total += b.lut_q8_p.size * b.lut_q8_p.dtype.itemsize
        return total


def _compile_banks(layers: Sequence[PegasusLinear], **kw) -> list[CompiledBank]:
    return [CompiledBank(l, **kw) for l in layers]


def _sequential_plan(layers, backend, kw, buckets) -> ExecutionPlan:
    banks = _compile_banks(layers, **kw)

    def forward(apply, state, x):
        h = x.astype(jnp.float32)
        for bank in state["banks"]:
            h = apply(bank, h)
        return h

    return ExecutionPlan(banks, forward, {"banks": banks}, backend=backend,
                         family="sequential", bucket_sizes=buckets)


def _rnn_plan(model, backend, kw, buckets) -> ExecutionPlan:
    x_banks = _compile_banks(model.x_banks, **kw)
    h_banks = _compile_banks(model.h_banks, **kw)
    out_bank = CompiledBank(model.out_bank, **kw)
    window = int(model.window)   # static: the unroll length is frozen into
    # the plan (bank swaps after compilation are caught by plan_for's
    # _model_banks identity check, which rebuilds the plan)

    def forward(apply, state, x):
        xf = x.astype(jnp.float32)
        h_pre = apply(state["x"][0], xf[:, 0])
        for t in range(1, window):
            h_pre = apply(state["x"][t], xf[:, t]) + apply(state["h"][t - 1], h_pre)
        return apply(state["out"], h_pre)

    state = {"x": x_banks, "h": h_banks, "out": out_bank}
    return ExecutionPlan(x_banks + h_banks + [out_bank], forward, state,
                         backend=backend, family="rnn", bucket_sizes=buckets)


def _cnn_plan(model, backend, kw, buckets) -> ExecutionPlan:
    from repro.nets.cnn import _windows  # structural helper, no cycle at call time

    window_bank = CompiledBank(model.window_bank, **kw)
    head_banks = _compile_banks(model.head_banks, **kw)
    nam = bool(model.nam)        # static branch selector
    state = {
        "window": window_bank,
        "heads": head_banks,
        "out_bias": None if model.out_bias is None else jnp.asarray(model.out_bias),
    }

    def forward(apply, state, x):
        win = _windows(x.astype(jnp.float32))          # [B, P, KERNEL*f]
        b, pcount, wdim = win.shape
        contrib = apply(state["window"], win.reshape(-1, wdim)).reshape(b, pcount, -1)
        if nam:
            return contrib.sum(axis=1) + state["out_bias"]  # single SumReduce
        h = contrib.mean(axis=1)                       # rows already ReLU'd
        for bank in state["heads"]:
            h = apply(bank, h)
        return h

    return ExecutionPlan([window_bank] + head_banks, forward, state,
                         backend=backend, family="cnn", bucket_sizes=buckets)


def _cnn_l_plan(model, backend, kw, buckets) -> ExecutionPlan:
    from repro.nets.cnn import _packet_feats

    bank1 = CompiledBank(model.bank1, **kw)
    bank2 = CompiledBank(model.bank2, **kw)
    state = {
        "b1": bank1,
        "b2": bank2,
        "emb_tree": model.emb_tree,                    # FuzzyTree is a pytree
        "logit_lut": jnp.asarray(model.logit_lut),
        "bias": jnp.asarray(model.bias),
    }

    def forward(apply, state, seq, payload):
        x = _packet_feats(seq, payload) * 255.0        # [B, W, 62]
        b, w, d = x.shape
        h_pre = apply(state["b1"], x.reshape(-1, d))
        e_pre = apply(state["b2"], h_pre)
        emb = jnp.tanh(e_pre)
        idx = hard_index(state["emb_tree"], emb)
        contrib = state["logit_lut"][idx].reshape(b, w, -1)
        return contrib.sum(axis=1) + state["bias"]

    return ExecutionPlan([bank1, bank2], forward, state, backend=backend,
                         family="cnn_l", bucket_sizes=buckets)


def build_plan(
    model: Any,
    *,
    backend: str = "onehot",
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
    strategy: str = "auto",
    bucket_sizes: Sequence[int] | None = None,
) -> ExecutionPlan:
    """Compile any pegasusified model into an ExecutionPlan.

    Dispatch is structural (no imports of the net modules at module scope):
      * list/tuple of PegasusLinear  → sequential stack (MLP, AutoEncoder)
      * ``.x_banks``/``.h_banks``    → PegasusRNN
      * ``.window_bank``             → PegasusCNN (B and M/NAM)
      * ``.emb_tree``/``.logit_lut`` → PegasusCNNL (two-level NAM)

    ``interpret=None`` resolves via :func:`default_interpret` (Pallas
    interpret mode everywhere except a real TPU backend); ``bucket_sizes``
    overrides the batch-bucket ladder (default :data:`DEFAULT_BUCKETS`).

    The plan freezes ALL model state at build time — banks and non-bank
    attributes alike (RNN window, CNN nam/out_bias, CNN-L
    emb_tree/logit_lut/bias). Mutating the model afterwards does NOT affect
    a plan you hold: rebuild it, or go through :func:`plan_for`, whose memo
    detects bank swaps and non-bank reassignment and recompiles.
    """
    kw = dict(block_t=block_t, block_n=block_n, block_k=block_k,
              interpret=default_interpret() if interpret is None else interpret,
              strategy=strategy)
    if isinstance(model, PegasusLinear):
        plan = _sequential_plan([model], backend, kw, bucket_sizes)
    elif isinstance(model, (list, tuple)):
        if not all(isinstance(l, PegasusLinear) for l in model):
            raise TypeError("bank list must contain only PegasusLinear")
        plan = _sequential_plan(model, backend, kw, bucket_sizes)
    elif hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        plan = _rnn_plan(model, backend, kw, bucket_sizes)
    elif hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        plan = _cnn_l_plan(model, backend, kw, bucket_sizes)
    elif hasattr(model, "window_bank"):
        plan = _cnn_plan(model, backend, kw, bucket_sizes)
    else:
        raise TypeError(f"don't know how to compile {type(model).__name__} into a plan")
    # the non-bank state the plan froze at build — plan_for compares this
    # against the live model to catch attribute reassignment (see _model_aux)
    plan._aux_token = _model_aux(model)
    return plan


# ---------------------------------------------------------------------------
# Model-structure helpers shared with the registry (repro.engine.registry),
# which owns all plan memoization: weakref-watched, bounded, evictable.
# ---------------------------------------------------------------------------


def _model_key(model: Any, interpret: bool, kw: dict) -> tuple:
    if isinstance(model, (list, tuple)):
        ids: tuple = tuple(id(l) for l in model)
    else:
        ids = (id(model),)
    return (*ids, interpret, tuple(sorted(kw.items())))


def _model_aux(model: Any) -> tuple:
    """Non-bank model state a compiled plan froze at build time (window
    length, NAM flag, out-bias, embedding tree, logit LUT). The registry
    must rebuild when any of it is reassigned — the forwards no longer read
    these attributes live, so a stale memo hit would silently serve outputs
    from the pre-mutation tensors."""
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return (int(model.window),)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return (model.emb_tree, model.logit_lut, model.bias)
    if hasattr(model, "window_bank"):
        return (bool(model.nam), model.out_bias)
    return ()


def _aux_matches(a: tuple, b: tuple) -> bool:
    """Identity for array-like entries (``==`` on jax arrays is elementwise),
    equality for plain scalars."""
    return len(a) == len(b) and all(
        x is y or (isinstance(x, (bool, int)) and isinstance(y, (bool, int))
                   and x == y)
        for x, y in zip(a, b))


def _model_banks(model: Any) -> tuple:
    """Current bank layers of a model, in plan construction order — used to
    detect in-place mutation (e.g. ``peg.window_bank = refine(...)``) that
    would otherwise hit the memo with a stale compiled plan."""
    if isinstance(model, PegasusLinear):
        return (model,)
    if isinstance(model, (list, tuple)):
        return tuple(model)
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return (*model.x_banks, *model.h_banks, model.out_bank)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return (model.bank1, model.bank2)
    if hasattr(model, "window_bank"):
        return (model.window_bank, *model.head_banks)
    return ()
