"""ExecutionPlan: compile a pegasusified model once, call it many times.

The hand-rolled apply paths in ``repro.nets.*`` re-derived the kernel layout
on every invocation — feature one-hots, block padding, and (for the q8 path)
int8 quantization of the whole LUT bank. Quark-style all-on-dataplane designs
and FENIX's offload pipeline both treat that state as *precompiled*; this
module does the same for the TPU realization:

  * :class:`CompiledBank` — one ``PegasusLinear`` plus every tensor the fused
    Pallas kernel needs, built exactly once (`feat_onehot`, +inf-padded
    thresholds, block-padded LUT, int8 LUT + per-group scales).
  * :class:`ExecutionPlan` — the whole model: compiled banks + a structural
    forward (sequential stack, windowed CNN, unrolled RNN, two-level NAM)
    with the backend chosen globally instead of per-layer-call.
  * :func:`build_plan` / :func:`plan_for` — compile, or fetch the memoized
    plan for a model object (bounded cache, strong refs pin ids).

Backends are semantics-identical up to quantization:
  ``gather``    — take_along_axis reference (XLA)
  ``onehot``    — one-hot × LUT matmul (MXU-friendly XLA)
  ``kernel``    — fused Pallas fuzzy-LUT kernel
  ``kernel_q8`` — fused Pallas kernel over the cached int8 LUT
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, apply_gather, apply_onehot
from repro.core.fuzzy_tree import hard_index
from repro.kernels.fuzzy_lut.kernel import fuzzy_lut_pallas
from repro.kernels.fuzzy_lut.ops import prepare_feat_onehot, quantized_lut_cached
from repro.kernels.fuzzy_lut.quantized import fuzzy_lut_q8_pallas

__all__ = [
    "BACKENDS",
    "STATS",
    "CompiledBank",
    "EngineStats",
    "ExecutionPlan",
    "build_plan",
    "plan_for",
    "reset_plan_cache",
]

BACKENDS = ("gather", "onehot", "kernel", "kernel_q8")


@dataclasses.dataclass
class EngineStats:
    """Global counters — the parity/caching tests assert layout work happens
    at plan-build time only, never on the call path."""

    layout_builds: int = 0   # CompiledBank layout preparations
    plan_builds: int = 0     # ExecutionPlan compilations
    plan_cache_hits: int = 0  # plan_for() served from the memo
    bank_calls: int = 0      # CompiledBank.apply invocations

    def reset(self) -> None:
        self.layout_builds = 0
        self.plan_builds = 0
        self.plan_cache_hits = 0
        self.bank_calls = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


STATS = EngineStats()


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


class CompiledBank:
    """One PegasusLinear with its kernel layout precomputed and frozen.

    All layout work (one-hot of split features, +inf threshold padding,
    block padding of the LUT along K and N, int8 quantization + scales)
    happens in ``__init__``; ``apply`` only pads the activations.
    """

    def __init__(
        self,
        layer: PegasusLinear,
        *,
        block_t: int = 256,
        block_n: int = 256,
        block_k: int = 128,
        interpret: bool = True,
    ):
        self.layer = layer
        self.block_t = block_t
        self.interpret = interpret

        k, v, n = layer.num_groups, layer.group_size, layer.out_features
        self.depth = int(np.log2(layer.num_centroids) + 0.5)

        # -- layout prep: done ONCE here, never on the call path -----------
        bk = min(block_k, k)
        kp = k + (-k) % bk
        feat_oh = prepare_feat_onehot(layer.trees.features, v)
        thr = layer.trees.thresholds
        lut = layer.lut
        lut_q8, scales = quantized_lut_cached(layer)
        if kp != k:
            feat_oh = _pad_to(feat_oh, 0, bk)
            thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
            lut = _pad_to(lut, 0, bk)
            lut_q8 = _pad_to(lut_q8, 0, bk)
            scales = jnp.pad(scales, (0, kp - k))
        bn = min(block_n, n)
        self.feat_oh = feat_oh
        self.thr = thr
        self.lut_p = _pad_to(lut, 2, bn)
        self.lut_q8_p = _pad_to(lut_q8, 2, bn)
        self.scales = scales
        self.kp = kp
        self.block_n = min(block_n, self.lut_p.shape[2])
        self.block_k = min(block_k, kp)
        STATS.layout_builds += 1

    # -- backend dispatch ---------------------------------------------------

    def apply(self, x: jax.Array, backend: str) -> jax.Array:
        STATS.bank_calls += 1
        if backend == "gather":
            return apply_gather(self.layer, x)
        if backend == "onehot":
            return apply_onehot(self.layer, x)
        if backend == "kernel":
            return self._apply_kernel(x, self.lut_p, None)
        if backend == "kernel_q8":
            return self._apply_kernel(x, self.lut_q8_p, self.scales)
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")

    def _apply_kernel(self, x, lut, scales) -> jax.Array:
        p = self.layer
        k, v, n = p.num_groups, p.group_size, p.out_features
        lead = x.shape[:-1]
        xg = x.reshape(-1, k, v).astype(jnp.float32)
        t = xg.shape[0]
        bt = min(self.block_t, max(8, t))
        xg = _pad_to(_pad_to(xg, 0, bt), 1, self.block_k)
        if scales is None:
            y = fuzzy_lut_pallas(
                xg, self.feat_oh, self.thr, lut,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
            )
        else:
            y = fuzzy_lut_q8_pallas(
                xg, self.feat_oh, self.thr, lut, scales,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
            )
        y = y[:t, :n]
        if p.bias is not None:
            y = y + p.bias
        return y.reshape(*lead, n)


# ---------------------------------------------------------------------------
# ExecutionPlan + per-family structural forwards
# ---------------------------------------------------------------------------


class ExecutionPlan:
    """Compiled model: banks + structural forward, backend bound globally."""

    def __init__(
        self,
        banks: Sequence[CompiledBank],
        forward: Callable[..., jax.Array],
        *,
        backend: str = "onehot",
        family: str = "sequential",
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        self.banks = list(banks)
        self._forward = forward
        self.backend = backend
        self.family = family
        STATS.plan_builds += 1

    def __call__(self, *inputs: jax.Array, backend: str | None = None) -> jax.Array:
        be = self.backend if backend is None else backend
        if be not in BACKENDS:
            raise ValueError(f"unknown backend {be!r}; expected one of {BACKENDS}")
        return self._forward(lambda bank, x: bank.apply(x, be), *inputs)

    @property
    def num_banks(self) -> int:
        return len(self.banks)

    def bank_inputs(self, *inputs: jax.Array, backend: str = "gather") -> list:
        """Forward once, recording the first activation each bank receives —
        a debugging/parity-test aid (None for banks the input never reaches)."""
        rec: dict[int, jax.Array] = {}

        def apply(bank: CompiledBank, x: jax.Array) -> jax.Array:
            rec.setdefault(id(bank), x)
            return bank.apply(x, backend)

        self._forward(apply, *inputs)
        return [rec.get(id(b)) for b in self.banks]

    def table_bytes(self) -> int:
        """Total LUT bytes held by the plan (fp + q8 layouts)."""
        total = 0
        for b in self.banks:
            total += b.lut_p.size * b.lut_p.dtype.itemsize
            total += b.lut_q8_p.size * b.lut_q8_p.dtype.itemsize
        return total


def _compile_banks(layers: Sequence[PegasusLinear], **kw) -> list[CompiledBank]:
    return [CompiledBank(l, **kw) for l in layers]


def _sequential_plan(layers, backend, kw) -> ExecutionPlan:
    banks = _compile_banks(layers, **kw)

    def forward(apply, x):
        h = x.astype(jnp.float32)
        for bank in banks:
            h = apply(bank, h)
        return h

    return ExecutionPlan(banks, forward, backend=backend, family="sequential")


def _rnn_plan(model, backend, kw) -> ExecutionPlan:
    x_banks = _compile_banks(model.x_banks, **kw)
    h_banks = _compile_banks(model.h_banks, **kw)
    out_bank = CompiledBank(model.out_bank, **kw)

    # non-bank attrs are read from ``model`` LIVE at call time, so attribute
    # updates after compilation are honored (banks themselves are guarded by
    # plan_for's _model_banks identity check)
    def forward(apply, x):
        xf = x.astype(jnp.float32)
        h_pre = apply(x_banks[0], xf[:, 0])
        for t in range(1, model.window):
            h_pre = apply(x_banks[t], xf[:, t]) + apply(h_banks[t - 1], h_pre)
        return apply(out_bank, h_pre)

    return ExecutionPlan(
        x_banks + h_banks + [out_bank], forward, backend=backend, family="rnn"
    )


def _cnn_plan(model, backend, kw) -> ExecutionPlan:
    from repro.nets.cnn import _windows  # structural helper, no cycle at call time

    window_bank = CompiledBank(model.window_bank, **kw)
    head_banks = _compile_banks(model.head_banks, **kw)

    def forward(apply, x):
        win = _windows(x.astype(jnp.float32))          # [B, P, KERNEL*f]
        b, pcount, wdim = win.shape
        contrib = apply(window_bank, win.reshape(-1, wdim)).reshape(b, pcount, -1)
        if model.nam:
            return contrib.sum(axis=1) + model.out_bias  # single SumReduce
        h = contrib.mean(axis=1)                       # rows already ReLU'd
        for bank in head_banks:
            h = apply(bank, h)
        return h

    return ExecutionPlan(
        [window_bank] + head_banks, forward, backend=backend, family="cnn"
    )


def _cnn_l_plan(model, backend, kw) -> ExecutionPlan:
    from repro.nets.cnn import _packet_feats

    bank1 = CompiledBank(model.bank1, **kw)
    bank2 = CompiledBank(model.bank2, **kw)

    def forward(apply, seq, payload):
        x = _packet_feats(seq, payload) * 255.0        # [B, W, 62]
        b, w, d = x.shape
        h_pre = apply(bank1, x.reshape(-1, d))
        e_pre = apply(bank2, h_pre)
        emb = jnp.tanh(e_pre)
        idx = hard_index(model.emb_tree, emb)
        contrib = model.logit_lut[idx].reshape(b, w, -1)
        return contrib.sum(axis=1) + model.bias

    return ExecutionPlan([bank1, bank2], forward, backend=backend, family="cnn_l")


def build_plan(
    model: Any,
    *,
    backend: str = "onehot",
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool = True,
) -> ExecutionPlan:
    """Compile any pegasusified model into an ExecutionPlan.

    Dispatch is structural (no imports of the net modules at module scope):
      * list/tuple of PegasusLinear  → sequential stack (MLP, AutoEncoder)
      * ``.x_banks``/``.h_banks``    → PegasusRNN
      * ``.window_bank``             → PegasusCNN (B and M/NAM)
      * ``.emb_tree``/``.logit_lut`` → PegasusCNNL (two-level NAM)
    """
    kw = dict(block_t=block_t, block_n=block_n, block_k=block_k, interpret=interpret)
    if isinstance(model, PegasusLinear):
        return _sequential_plan([model], backend, kw)
    if isinstance(model, (list, tuple)):
        if not all(isinstance(l, PegasusLinear) for l in model):
            raise TypeError("bank list must contain only PegasusLinear")
        return _sequential_plan(model, backend, kw)
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return _rnn_plan(model, backend, kw)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return _cnn_l_plan(model, backend, kw)
    if hasattr(model, "window_bank"):
        return _cnn_plan(model, backend, kw)
    raise TypeError(f"don't know how to compile {type(model).__name__} into a plan")


# ---------------------------------------------------------------------------
# Plan memo — serving/benchmark call sites reuse one plan per model object.
# ---------------------------------------------------------------------------

# key → (model, plan): the entry pins the MODEL object itself, so a live
# entry's id() can never be reused by a different model (CPython id reuse
# only happens after the object is freed).
_PLAN_CACHE: dict[tuple, tuple[Any, ExecutionPlan]] = {}
_PLAN_CACHE_MAX = 64


def _model_key(model: Any, interpret: bool, kw: dict) -> tuple:
    if isinstance(model, (list, tuple)):
        ids: tuple = tuple(id(l) for l in model)
    else:
        ids = (id(model),)
    return (*ids, interpret, tuple(sorted(kw.items())))


def _model_banks(model: Any) -> tuple:
    """Current bank layers of a model, in plan construction order — used to
    detect in-place mutation (e.g. ``peg.window_bank = refine(...)``) that
    would otherwise hit the memo with a stale compiled plan."""
    if isinstance(model, PegasusLinear):
        return (model,)
    if isinstance(model, (list, tuple)):
        return tuple(model)
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return (*model.x_banks, *model.h_banks, model.out_bank)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return (model.bank1, model.bank2)
    if hasattr(model, "window_bank"):
        return (model.window_bank, *model.head_banks)
    return ()


def plan_for(model: Any, *, interpret: bool = True, **kw) -> ExecutionPlan:
    """Memoized build_plan. Plans are backend-agnostic here — pass the
    backend per call (``plan(x, backend=...)``); binding a default belongs
    to explicit build_plan. Block-size overrides participate in the key."""
    key = _model_key(model, interpret, kw)
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        cached_model, cached_plan = hit
        if isinstance(model, (list, tuple)) and isinstance(cached_model, (list, tuple)):
            same = len(cached_model) == len(model) and all(
                a is b for a, b in zip(cached_model, model))
        else:
            same = cached_model is model
        # reject hits whose compiled banks no longer match the model's
        # current banks (in-place mutation like ``peg.out_bank = refine(...)``)
        banks_now = _model_banks(model)
        same = same and len(banks_now) == len(cached_plan.banks) and all(
            cb.layer is l for cb, l in zip(cached_plan.banks, banks_now))
        if same:
            STATS.plan_cache_hits += 1
            return cached_plan
        del _PLAN_CACHE[key]
    plan = build_plan(model, interpret=interpret, **kw)
    if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    _PLAN_CACHE[key] = (model, plan)
    return plan


def reset_plan_cache() -> None:
    _PLAN_CACHE.clear()
