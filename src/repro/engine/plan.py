"""ExecutionPlan: compile a pegasusified model once, call it many times.

The hand-rolled apply paths in ``repro.nets.*`` re-derived the kernel layout
on every invocation — feature one-hots, block padding, and (for the q8 path)
int8 quantization of the whole LUT bank. Quark-style all-on-dataplane designs
and FENIX's offload pipeline both treat that state as *precompiled*; this
module does the same for the TPU realization:

  * :class:`CompiledBank` — one ``PegasusLinear`` plus every tensor the fused
    Pallas kernel needs, built exactly once (`feat_onehot`, +inf-padded
    thresholds, block-padded LUT, int8 LUT + per-group scales). Registered as
    a jax pytree so a whole plan's banks flow through ``jax.jit`` as traced
    state rather than baked-in constants.
  * :class:`FusedBankStack` / :func:`fuse_banks` — Cross-bank Primitive
    Fusion: a maximal run of shape-compatible consecutive banks (same group
    width and centroid count, each bank's output feeding the next's input)
    compiles into ONE stacked Pallas kernel invocation
    (``fuzzy_lut_stack_pallas`` / ``..._q8``) — operands stacked to
    ``[L, Kmax, C, Nmax]`` at plan build, the inter-bank re-partition +
    bias (+ q8 dequant) folded into the kernel loop so activations never
    leave VMEM between banks. Incompatible runs, the ``gather``/``onehot``
    backends, and the RNN/CNN structural steps fall back to the per-bank
    path; ``fuse=False`` on :func:`build_plan` disables grouping entirely
    (the fusion config participates in plan_for's memo key).
  * :class:`ExecutionPlan` — the whole model: compiled banks + a structural
    forward (sequential stack, windowed CNN, unrolled RNN, two-level NAM)
    that is a *pure function* of ``(state, inputs)`` closed over static
    shapes, so the entire forward traces into ONE jitted XLA computation per
    ``(backend, batch-bucket)``.
  * **Batch bucketing** — request batches are zero-padded up to a bounded
    set of bucket sizes (powers of two by default, multiples of the largest
    bucket beyond it), so varying request sizes hit a warm compile cache
    instead of retracing per shape. ``EngineStats.jit_traces`` counts actual
    XLA traces; the compile-count tests pin the invariants.
  * :func:`build_plan` — compile a model into a plan. Memoization lives in
    :mod:`repro.engine.registry` (:class:`PlanRegistry` / :func:`plan_for`):
    weakref-watched, bounded, explicitly evictable entries. To support that,
    a plan holds a *detached replica* of each bank layer (same arrays, new
    dataclass instance) — compiling a model never pins the caller's model
    objects, so dropping the model lets the registry reclaim its plan.

Backends are semantics-identical up to quantization:
  ``gather``    — take_along_axis reference (XLA)
  ``onehot``    — one-hot × LUT matmul (MXU-friendly XLA)
  ``kernel``    — fused Pallas fuzzy-LUT kernel
  ``kernel_q8`` — fused Pallas kernel over the cached int8 LUT
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.analysis.sanitizer import make_lock

from repro.core.amm import PegasusLinear, apply_gather, apply_onehot
from repro.core.fuzzy_tree import hard_index
from repro.kernels.fuzzy_lut.kernel import (
    STACK_BLOCK_T,
    default_interpret,
    fuzzy_lut_pallas,
    fuzzy_lut_stack_pallas,
    resolve_strategy,
)
from repro.kernels.fuzzy_lut.ops import prepare_feat_onehot, quantized_lut_cached
from repro.kernels.fuzzy_lut.quantized import (
    fuzzy_lut_q8_pallas,
    fuzzy_lut_stack_q8_pallas,
)

__all__ = [
    "BACKENDS",
    "DEFAULT_BUCKETS",
    "DEFAULT_FUSE_NMAX_CAP",
    "STATS",
    "CompiledBank",
    "EngineStats",
    "ExecutionPlan",
    "FusedBankStack",
    "bucket_batch",
    "bucket_chunks",
    "build_plan",
    "fuse_banks",
    "resolve_devices",
]

# Per-group cap on a fused stack's padded output width: the stacked operands
# are [L, Kmax, C, Nmax], so one wide bank joining a narrow run multiplies
# EVERY member's LUT rows (and the kernel's VMEM working set) by Nmax/N.
# Groups split rather than pad past this; equal-width wide banks may still
# fuse above it because they add no padding (see fuse_banks). 2048 clears
# every paper-scale head (N ≤ a few hundred) while bounding worst-case
# stack VMEM to a few MiB at C=32.
DEFAULT_FUSE_NMAX_CAP = 2048

BACKENDS = ("gather", "onehot", "kernel", "kernel_q8")

# The jitted forwards donate their (plan-owned) input buffers so XLA may
# recycle the storage. When a model's output is smaller than its input —
# most classifiers — no alias exists and jax warns per executable; that is
# the expected shape here, not an error worth one warning per compile.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

# Bounded bucket set: odd batch sizes round UP to the nearest bucket (zero
# rows are sliced off after the call), so the jit cache holds at most
# ``len(DEFAULT_BUCKETS)`` entries per backend for any batch ≤ the largest
# bucket; beyond it, batches round to multiples of the largest bucket.
DEFAULT_BUCKETS: tuple[int, ...] = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_batch(b: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Round a batch size up to its compile bucket (smallest bucket ≥ b;
    beyond the largest, the next multiple of it)."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    for s in sorted(buckets):
        if b <= s:
            return int(s)
    top = int(max(buckets))
    return -(-b // top) * top


def bucket_chunks(
    total: int,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
    max_batch: int | None = None,
) -> list[int]:
    """Split ``total`` coalesced flows into bucket-aligned micro-batch sizes.

    Full chunks are exact bucket sizes (zero pad rows); the tail dispatches
    either as one padded chunk or as an exact bucket plus a smaller padded
    chunk — whichever wastes fewer padded rows. This replaces fixed-stride
    chunking (the old ``max_batch=1024`` slicing), which ignored the bucket
    ladder and could split a 2048-flow batch that has its own exact bucket.
    """
    if total <= 0:
        raise ValueError(f"total must be positive, got {total}")
    bs = sorted(int(b) for b in buckets)
    if max_batch is None:
        top = bs[-1]
    else:
        fits = [b for b in bs if b <= max_batch]
        # max_batch below the smallest bucket cannot bound anything: every
        # dispatch pads up to bs[0] anyway, so sub-bucket chunking would
        # only multiply padded work — clamp to one smallest-bucket chunk
        top = fits[-1] if fits else bs[0]
    sizes = []
    remaining = total
    while remaining > top:
        sizes.append(top)
        remaining -= top
    if remaining:
        fit = max((b for b in bs if b <= remaining), default=0)
        if 0 < fit < remaining:
            pad_whole = bucket_batch(remaining, bs) - remaining
            rest = remaining - fit
            pad_split = bucket_batch(rest, bs) - rest
            if pad_split < pad_whole:
                sizes.append(fit)
                remaining = rest
        sizes.append(remaining)
    return sizes


@dataclasses.dataclass
class EngineStats:
    """Global counters — the parity/caching tests assert layout work happens
    at plan-build time only, and whole-plan XLA traces happen at most once
    per (backend, batch-bucket), never per call."""

    layout_builds: int = 0   # CompiledBank layout preparations
    plan_builds: int = 0     # ExecutionPlan compilations
    plan_cache_hits: int = 0  # plan_for() served from the memo
    bank_calls: int = 0      # CompiledBank.apply invocations (eager or trace)
    jit_traces: int = 0      # whole-plan forward traces (one per compile)
    jit_calls: int = 0       # jitted plan dispatches (hits = calls - traces)

    def reset(self) -> None:
        self.layout_builds = 0
        self.plan_builds = 0
        self.plan_cache_hits = 0
        self.bank_calls = 0
        self.jit_traces = 0
        self.jit_calls = 0

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


STATS = EngineStats()


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


@jax.tree_util.register_pytree_node_class
class CompiledBank:
    """One PegasusLinear with its kernel layout precomputed and frozen.

    All layout work (one-hot of split features, +inf threshold padding,
    block padding of the LUT along K and N, int8 quantization + scales)
    happens in ``__init__``; ``apply`` only pads the activations.

    Pytree protocol: the tensors are leaves, the block geometry is static
    aux data — so banks can ride through ``jax.jit`` as arguments (shared
    across every compiled bucket) instead of being re-embedded as XLA
    constants in each executable.

    ``self.layer`` is a *detached replica* of the source layer (same arrays,
    fresh dataclass instance): a compiled bank must never pin the caller's
    model object, or the registry's drop-the-model-evict-the-plan weakref
    scheme could never fire (the registry keeps its own weakrefs to the
    source layers for staleness checks).
    """

    def __init__(
        self,
        layer: PegasusLinear,
        *,
        block_t: int = 256,
        block_n: int = 256,
        block_k: int = 128,
        interpret: bool | None = None,
        strategy: str = "auto",
    ):
        # q8 memo keyed on the ORIGINAL layer id (shared across rebuilds of
        # the same model); the replica below is what the bank retains.
        lut_q8, scales = quantized_lut_cached(layer)
        self.layer = dataclasses.replace(layer)
        self.block_t = block_t
        self.interpret = default_interpret() if interpret is None else interpret
        self.strategy = resolve_strategy(strategy, self.interpret)

        k, v, n = layer.num_groups, layer.group_size, layer.out_features
        self.depth = int(np.log2(layer.num_centroids) + 0.5)

        # -- layout prep: done ONCE here, never on the call path -----------
        bk = min(block_k, k)
        kp = k + (-k) % bk
        feat_oh = prepare_feat_onehot(layer.trees.features, v)
        thr = layer.trees.thresholds
        lut = layer.lut
        if kp != k:
            feat_oh = _pad_to(feat_oh, 0, bk)
            thr = jnp.pad(thr, ((0, kp - k), (0, 0)), constant_values=jnp.inf)
            lut = _pad_to(lut, 0, bk)
            lut_q8 = _pad_to(lut_q8, 0, bk)
            scales = jnp.pad(scales, (0, kp - k))
        bn = min(block_n, n)
        self.feat_oh = feat_oh
        self.thr = thr
        self.lut_p = _pad_to(lut, 2, bn)
        self.lut_q8_p = _pad_to(lut_q8, 2, bn)
        self.scales = scales
        self.kp = kp
        self.block_n = min(block_n, self.lut_p.shape[2])
        self.block_k = min(block_k, kp)
        STATS.layout_builds += 1

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten(self):
        children = (self.layer, self.feat_oh, self.thr,
                    self.lut_p, self.lut_q8_p, self.scales)
        aux = (self.block_t, self.block_n, self.block_k,
               self.depth, self.kp, self.interpret, self.strategy)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        # bypass __init__: no layout work, no STATS increment — this path
        # runs on every jit flatten/unflatten round-trip
        obj = object.__new__(cls)
        (obj.layer, obj.feat_oh, obj.thr,
         obj.lut_p, obj.lut_q8_p, obj.scales) = children
        (obj.block_t, obj.block_n, obj.block_k,
         obj.depth, obj.kp, obj.interpret, obj.strategy) = aux
        return obj

    # -- backend dispatch ---------------------------------------------------

    def apply(self, x: jax.Array, backend: str) -> jax.Array:
        STATS.bank_calls += 1
        if backend == "gather":
            return apply_gather(self.layer, x)
        if backend == "onehot":
            return apply_onehot(self.layer, x)
        if backend == "kernel":
            return self._apply_kernel(x, self.lut_p, None)
        if backend == "kernel_q8":
            return self._apply_kernel(x, self.lut_q8_p, self.scales)
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")

    def _apply_kernel(self, x, lut, scales) -> jax.Array:
        p = self.layer
        k, v, n = p.num_groups, p.group_size, p.out_features
        lead = x.shape[:-1]
        xg = x.reshape(-1, k, v).astype(jnp.float32)
        t = xg.shape[0]
        bt = min(self.block_t, max(8, t))
        xg = _pad_to(_pad_to(xg, 0, bt), 1, self.block_k)
        if scales is None:
            y = fuzzy_lut_pallas(
                xg, self.feat_oh, self.thr, lut,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
                strategy=self.strategy,
            )
        else:
            y = fuzzy_lut_q8_pallas(
                xg, self.feat_oh, self.thr, lut, scales,
                depth=self.depth, block_t=bt, block_n=self.block_n,
                block_k=self.block_k, interpret=self.interpret,
                strategy=self.strategy,
            )
        y = y[:t, :n]
        if p.bias is not None:
            y = y + p.bias
        return y.reshape(*lead, n)


# ---------------------------------------------------------------------------
# Cross-bank Primitive Fusion: compatible consecutive banks → one kernel
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class FusedBankStack:
    """A run of L shape-compatible banks compiled into ONE stacked kernel.

    Operand stacks are built once here (plan build): each bank's true-size
    tensors are padded to the group's ``(Kmax, Nmax)`` — +inf thresholds and
    zero LUT rows on padded groups descend to leaf 0 and contribute nothing —
    then stacked along a leading L axis. On the ``kernel``/``kernel_q8``
    backends ``apply`` dispatches ``fuzzy_lut_stack_pallas`` /
    ``..._q8`` (re-partition, bias, and dequant all inside the kernel loop);
    ``gather``/``onehot`` and any stack the kernel rejects (``ValueError``
    on a mis-padded operand) fall back to the per-bank chain, which is
    semantics-identical.

    The member banks stay whole inside the stack (pytree children), so the
    fallback chain, ``plan.bank_inputs`` and the per-bank parity tests keep
    working on fused plans.
    """

    def __init__(self, banks: Sequence["CompiledBank"]):
        if len(banks) < 2:
            raise ValueError("a fused stack needs at least 2 banks")
        for a, b in zip(banks, banks[1:]):
            if not _fusable(a, b):
                raise ValueError("banks are not shape-compatible for fusion")
        self.banks = list(banks)
        layers = [b.layer for b in banks]
        self.v = layers[0].group_size
        self.depth = banks[0].depth
        self.ks = tuple(l.num_groups for l in layers)
        self.n_out = layers[-1].out_features
        self.block_t = STACK_BLOCK_T
        self.interpret = banks[0].interpret
        self.strategy = banks[0].strategy

        kmax = max(self.ks)
        nmax = max(l.out_features for l in layers)
        c = layers[0].num_centroids
        i = c - 1
        feat_oh = jnp.zeros((len(layers), kmax, i, self.v), jnp.float32)
        thr = jnp.full((len(layers), kmax, i), jnp.inf, jnp.float32)
        lut = jnp.zeros((len(layers), kmax, c, nmax), jnp.float32)
        lut_q8 = jnp.zeros((len(layers), kmax, c, nmax), jnp.int8)
        scales = jnp.zeros((len(layers), kmax), jnp.float32)
        bias = jnp.zeros((len(layers), nmax), jnp.float32)
        for l, bank in enumerate(banks):
            k, n = bank.layer.num_groups, bank.layer.out_features
            # slice the bank's block-padded operands back to true size, then
            # re-pad to the GROUP geometry — no new quantization, no new
            # one-hots: strictly a restack of what CompiledBank already built
            feat_oh = feat_oh.at[l, :k].set(bank.feat_oh[:k])
            thr = thr.at[l, :k].set(bank.thr[:k])
            lut = lut.at[l, :k, :, :n].set(bank.lut_p[:k, :, :n])
            lut_q8 = lut_q8.at[l, :k, :, :n].set(bank.lut_q8_p[:k, :, :n])
            scales = scales.at[l, :k].set(bank.scales[:k])
            if bank.layer.bias is not None:
                bias = bias.at[l, :n].set(bank.layer.bias)
        self.feat_oh, self.thr = feat_oh, thr
        self.lut, self.lut_q8 = lut, lut_q8
        self.scales, self.bias = scales, bias
        STATS.layout_builds += 1

    # -- pytree protocol ----------------------------------------------------

    def tree_flatten(self):
        children = (tuple(self.banks), self.feat_oh, self.thr, self.lut,
                    self.lut_q8, self.scales, self.bias)
        aux = (self.ks, self.v, self.depth, self.n_out, self.block_t,
               self.interpret, self.strategy)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        obj = object.__new__(cls)
        (banks, obj.feat_oh, obj.thr, obj.lut,
         obj.lut_q8, obj.scales, obj.bias) = children
        obj.banks = list(banks)
        (obj.ks, obj.v, obj.depth, obj.n_out, obj.block_t,
         obj.interpret, obj.strategy) = aux
        return obj

    # -- dispatch -----------------------------------------------------------

    def _per_bank(self, x: jax.Array, backend: str) -> jax.Array:
        h = x
        for bank in self.banks:
            h = bank.apply(h, backend)
        return h

    def apply(self, x: jax.Array, backend: str) -> jax.Array:
        if backend not in ("kernel", "kernel_q8"):
            return self._per_bank(x, backend)
        lead = x.shape[:-1]
        xg = x.reshape(-1, self.ks[0], self.v).astype(jnp.float32)
        t = xg.shape[0]
        bt = min(self.block_t, t)
        xg = _pad_to(xg, 0, bt)
        try:
            if backend == "kernel":
                y = fuzzy_lut_stack_pallas(
                    xg, self.feat_oh, self.thr, self.lut, self.bias,
                    depth=self.depth, ks=self.ks, n_out=self.n_out,
                    block_t=bt, interpret=self.interpret,
                    strategy=self.strategy)
            else:
                y = fuzzy_lut_stack_q8_pallas(
                    xg, self.feat_oh, self.thr, self.lut_q8, self.scales,
                    self.bias, depth=self.depth, ks=self.ks,
                    n_out=self.n_out, block_t=bt, interpret=self.interpret,
                    strategy=self.strategy)
        except ValueError:
            # mis-padded operand stack (e.g. hand-built): the kernel refuses
            # loudly and the per-bank chain serves the call (and does its own
            # bank_calls accounting)
            return self._per_bank(x, backend)
        STATS.bank_calls += len(self.banks)   # fused path only: no double count
        return y[:t].reshape(*lead, self.n_out)


def _fusable(a: CompiledBank, b: CompiledBank) -> bool:
    """Can bank ``b`` consume bank ``a``'s output inside one stacked kernel?
    Same partition width and centroid count (stacked operands must share
    (v, C)), exact output→input chaining, and identical static kernel
    config."""
    return (a.layer.group_size == b.layer.group_size
            and a.layer.num_centroids == b.layer.num_centroids
            and a.layer.out_features == b.layer.in_features
            and a.interpret == b.interpret
            and a.strategy == b.strategy)


def _balloons(run: Sequence[CompiledBank], bank: CompiledBank,
              nmax_cap: int | None) -> bool:
    """Would adding ``bank`` to ``run`` pad some member's output rows past
    ``nmax_cap``? The stacked operands share one Nmax = max(out_features),
    so a single wide bank balloons every narrow member's padded [C, Nmax]
    LUT slab. Equal-width banks above the cap are NOT a balloon (no padding
    is added), so uniformly-wide runs still fuse."""
    if nmax_cap is None:
        return False
    ns = [b.layer.out_features for b in run] + [bank.layer.out_features]
    nmax = max(ns)
    return nmax > nmax_cap and min(ns) < nmax


def fuse_banks(banks: Sequence[CompiledBank], *,
               nmax_cap: int | None = DEFAULT_FUSE_NMAX_CAP) -> list:
    """Plan-build fusion pass: group maximal runs of compatible consecutive
    banks into :class:`FusedBankStack` steps; lone banks pass through.

    ``nmax_cap`` bounds each group's padded output width (``None`` = no
    cap): a run splits rather than letting one wide bank balloon a narrow
    stack's ``[L, Kmax, C, Nmax]`` VMEM footprint — the wide bank starts
    its own run (and may still fuse with equally-wide neighbors, which add
    no padding).

    Purely structural — the returned step list is what the sequential
    forward iterates, and each step exposes the same
    ``apply(x, backend)`` contract, so fusing never changes trace counts
    (the whole forward is still one jitted computation per bucket)."""
    steps: list = []
    run: list[CompiledBank] = []

    def flush():
        if len(run) >= 2:
            steps.append(FusedBankStack(run))
        else:
            steps.extend(run)
        run.clear()

    for bank in banks:
        if run and (not _fusable(run[-1], bank)
                    or _balloons(run, bank, nmax_cap)):
            flush()
        run.append(bank)
    flush()
    return steps


# ---------------------------------------------------------------------------
# ExecutionPlan + per-family structural forwards
# ---------------------------------------------------------------------------


def resolve_devices(devices) -> tuple | None:
    """Normalize a ``devices=`` knob into a canonical device tuple.

    Accepts ``None`` (single-device, the default), an int ``k`` (the first
    ``k`` of ``jax.devices()``), or a sequence of ``jax.Device`` objects /
    integer device ids. The canonical form — ``None`` or a tuple of
    ``jax.Device`` — is what participates in ``plan_for``'s memo key, so
    ``devices=2`` and ``devices=jax.devices()[:2]`` memo-hit the same plan.
    """
    if devices is None:
        return None
    if isinstance(devices, int):
        avail = jax.devices()
        if devices < 1:
            raise ValueError(f"devices must be ≥ 1, got {devices}")
        if devices > len(avail):
            raise ValueError(
                f"devices={devices} but only {len(avail)} jax devices are "
                "visible (simulate more CPU devices with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        return tuple(avail[:devices])
    avail = None
    out = []
    for d in devices:
        if isinstance(d, int):
            avail = jax.devices() if avail is None else avail
            out.append(avail[d])
        else:
            out.append(d)
    return tuple(out) or None


class _PlanCounters:
    """Per-plan trace instrumentation, held OUTSIDE the plan so the jitted
    forward's closure never references the plan itself (see ExecutionPlan).

    Guarded by a small lock: the async serving runtime may call one plan
    from the drain thread while ``infer()`` runs on another — the counter
    read-modify-writes must not lose updates."""

    __slots__ = ("traces", "traced_buckets", "rows", "lock")

    def __init__(self):
        self.traces = 0                               # guarded-by: lock
        # distinct (backend, bucket) pairs ever traced — named so the
        # guarded-by map cannot collide with ExecutionPlan.buckets,
        # the immutable bucket LADDER
        self.traced_buckets: set[tuple[str, int]] = set()  # guarded-by: lock
        # (backend, bucket) → [requested rows, dispatched (padded) rows]:
        # the pad_waste surface — what fraction of every bucket's compute
        # went to filler rows (ladder efficiency, reported by the bench and
        # MultiModelServer.stats()).
        self.rows: dict[tuple[str, int], list] = {}   # guarded-by: lock
        self.lock = make_lock("plan._ctr.lock")


class ExecutionPlan:
    """Compiled model: banks + structural forward, backend bound globally.

    The forward is a pure function ``forward(apply, state, *inputs)`` where
    ``state`` is a jax pytree (banks + any captured arrays) and every other
    degree of freedom (window length, NAM flag, block geometry, interpret
    mode) is a static Python value closed over at plan-build. ``__call__``
    pads the batch up to its bucket, dispatches the jitted forward, and
    slices the padding back off — so the whole model is ONE XLA computation
    per ``(backend, bucket)`` and repeated calls at any batch size that maps
    to a warm bucket perform zero Python-per-bank dispatch and zero retraces.

    **Multi-device execution** comes in two flavors:

      * ``devices=`` (build-time) — SHARDED mode: the whole-plan forward is
        wrapped in ``shard_map`` over a 1-D ``("batch",)`` mesh, the padded
        batch split evenly across the devices and the bank operands
        replicated (they are small — KiB of LUT per bank). One call spreads
        one big batch over every device; outputs are bit-exact with the
        single-device plan because every row's compute is independent of
        the batch partition. Every bucket size must divide evenly by the
        device count (the default power-of-two ladder accepts 2/4/8).
      * ``device=`` (call-time, single-device plans only) — PLACED mode:
        the padded inputs and a cached replica of the bank state are
        committed to one specific device and the call executes entirely
        there. This is what the serving runtime's per-device executor
        streams use: N placed plans run concurrently, one stream per
        device.
    """

    def __init__(
        self,
        banks: Sequence[CompiledBank],
        forward: Callable[..., jax.Array],
        state: Any,
        *,
        backend: str = "onehot",
        family: str = "sequential",
        bucket_sizes: Sequence[int] | None = None,
        devices=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
        self.banks = list(banks)
        self._forward = forward
        self._state = state
        self.backend = backend
        self.family = family
        self.buckets = tuple(sorted(bucket_sizes)) if bucket_sizes else DEFAULT_BUCKETS
        self.devices = resolve_devices(devices)
        mesh = None
        if self.devices is not None and len(self.devices) > 1:
            bad = [b for b in self.buckets if b % len(self.devices)]
            if bad:
                raise ValueError(
                    f"bucket sizes {bad} are not divisible by the "
                    f"{len(self.devices)}-device mesh — every bucket is "
                    "split evenly across the batch axis (pass bucket_sizes "
                    "that the device count divides)")
            mesh = Mesh(np.asarray(self.devices), ("batch",))
        self._mesh = mesh
        # PLACED mode: per-device replicas of the bank state, built lazily
        # on first use (cross-device copies of KiB-scale LUT tables)
        self._replicas: dict = {}                # guarded-by: _replica_lock
        self._replica_lock = make_lock("plan._replica_lock")
        # compile-cache instrumentation (per plan; STATS mirrors globally).
        # The counters live in a detached holder: _pure must not close over
        # `self`, or plan ↔ jit-closure would form a reference cycle and an
        # evicted plan's executables/tensors would linger until a gen-2 GC
        # pass instead of freeing on the registry's refcount drop.
        self._ctr = ctr = _PlanCounters()
        self.jit_calls = 0
        # set by the family builders after construction (sequential/CNN runs
        # may compile FusedBankStack steps; other families stay per-bank)
        self.fused_groups = 0
        self.fused_banks = 0
        self.fused_stacks: list = []
        # build-time fusion knobs + audit report, recorded by build_plan so
        # the plan auditor (repro.analysis.planaudit) can explain WHY pairs
        # stayed unfused and stats() can surface finding counts
        self.fuse_cfg = {"fuse": True, "nmax_cap": DEFAULT_FUSE_NMAX_CAP}
        self.audit_report = None

        def _pure(state, inputs, backend):
            # body runs at TRACE time only — this is the retrace counter the
            # bucketing tests assert on. PG004 is right that these are
            # trace-time side effects; here that is the POINT (they fire
            # once per compile, never per call), so they stay, justified:
            # pegasus-lint: disable=PG004 intentional trace-counter (fires once per compile)
            STATS.jit_traces += 1
            # pegasus-lint: disable-block=PG004 intentional compile-cache instrumentation under the innermost lock
            with ctr.lock:
                ctr.traces += 1
                ctr.traced_buckets.add((backend, int(inputs[0].shape[0])))

            def run(state, inputs):
                return forward(
                    lambda bank, x: bank.apply(x, backend), state, *inputs)

            if mesh is None:
                return run(state, inputs)
            # SHARDED mode: batch axis split across the mesh, bank state
            # replicated (P() prefix-spec broadcasts over the whole state
            # pytree). Rows never interact, so no collectives — check_rep
            # is off because the Pallas calls carry no replication rules.
            return shard_map(
                run, mesh=mesh,
                in_specs=(PartitionSpec(), PartitionSpec("batch")),
                out_specs=PartitionSpec("batch"),
                check_rep=False)(state, inputs)

        # inputs (arg 1) are DONATED: the bucket ladder hands the jitted
        # forward a padded buffer the plan itself owns, so XLA may reuse its
        # storage for intermediates/outputs instead of the old pad-then-copy
        # pair. __call__ guarantees every donated leaf is plan-owned
        # (_owned_padded) — a caller's array is never invalidated.
        self._jit = jax.jit(_pure, static_argnames=("backend",),
                            donate_argnums=(1,))
        STATS.plan_builds += 1

    @property
    def trace_count(self) -> int:
        with self._ctr.lock:
            return self._ctr.traces

    @property
    def compiled_buckets(self) -> set:
        # snapshot, not the live set: callers iterate it while the drain
        # thread may be tracing a new bucket (set mutation during iteration
        # raises); the stats/bugfix sweep moved this read under the lock
        with self._ctr.lock:
            return set(self._ctr.traced_buckets)

    def __call__(
        self, *inputs: jax.Array, backend: str | None = None,
        jit: bool = True, device=None,
    ) -> jax.Array:
        be = self.backend if backend is None else backend
        if be not in BACKENDS:
            raise ValueError(f"unknown backend {be!r}; expected one of {BACKENDS}")
        if device is not None and self._mesh is not None:
            raise ValueError(
                "this plan is sharded across a device mesh at build time "
                "(devices=); per-call device placement applies only to "
                "single-device plans")
        if not jit:
            return self._forward(
                lambda bank, x: bank.apply(x, be), self._state, *inputs)
        b = int(np.shape(inputs[0])[0])
        bucket = bucket_batch(b, self.buckets)
        padded = tuple(self._owned_padded(x, bucket, device) for x in inputs)
        STATS.jit_calls += 1
        with self._ctr.lock:
            self.jit_calls += 1
            rows = self._ctr.rows.setdefault((be, bucket), [0, 0])
            rows[0] += b
            rows[1] += bucket
        state = self._state if device is None else self._state_for(device)
        y = self._jit(state, padded, backend=be)
        return y if bucket == b else y[:b]

    def _state_for(self, device):
        """The bank-state replica committed to ``device`` (built once per
        device). Placed calls pass the replica so every operand of the
        jitted forward lives on one device — mixed-device arguments are a
        jit error, and replicating KiB-scale LUT tables once is far cheaper
        than shipping them per call."""
        with self._replica_lock:
            st = self._replicas.get(device)
        if st is None:
            # device_put OUTSIDE the lock (PG001): a cross-device copy must
            # not stall concurrent placed calls to other devices. Racing
            # builders both pay the copy once; setdefault keeps the first.
            built = jax.device_put(self._state, device)
            with self._replica_lock:
                st = self._replicas.setdefault(device, built)
        return st

    @staticmethod
    def _owned_padded(x: jax.Array, bucket: int, device=None) -> jax.Array:
        """A plan-OWNED buffer at the bucket size — safe to donate.

        Padding (and host→device transfer of non-jax inputs) always yields a
        fresh buffer; the one case where the caller's array would otherwise
        flow straight through — a jax array already at its bucket size — is
        defensively copied, because a donated buffer is deleted after the
        call. The copy is one batch-sized memcpy, orders of magnitude below
        the per-call budget it buys donation for.

        With ``device`` set (PLACED mode) the buffer is committed to that
        device first — the pad/copy then executes there, so the jitted call
        sees same-device operands and runs entirely on its stream.
        """
        if not isinstance(x, jax.Array):
            x = np.asarray(x)
            x = jnp.asarray(x) if device is None else jax.device_put(x, device)
            owned = True                   # fresh device buffer: plan-owned
        elif device is not None and device not in x.devices():
            x = jax.device_put(x, device)  # cross-device copy: plan-owned
            owned = True
        else:
            owned = False
        b = x.shape[0]
        if b != bucket:
            pad = [(0, bucket - b)] + [(0, 0)] * (x.ndim - 1)
            return jnp.pad(x, pad)
        return x if owned else x.copy()

    def _lut_cell_stats(self) -> tuple[int, int]:
        """(useful, dispatched) LUT cells across the plan's kernel steps.

        A fused stack dispatches its whole padded ``[L, Kmax, C, Nmax]``
        slab per call; only the member banks' true ``K·C·N`` cells carry
        signal. Standalone banks contribute their true cells to BOTH terms,
        so the ratio weights fused padding by its real share of the plan's
        LUT compute."""
        fused_members = {id(b) for s in self.fused_stacks for b in s.banks}
        useful = dispatched = 0
        for s in self.fused_stacks:
            c = s.banks[0].layer.num_centroids
            dispatched += len(s.banks) * max(s.ks) * c * int(s.lut.shape[-1])
            useful += sum(b.layer.num_groups * c * b.layer.out_features
                          for b in s.banks)
        for b in self.banks:
            if id(b) not in fused_members:
                cells = (b.layer.num_groups * b.layer.num_centroids
                         * b.layer.out_features)
                useful += cells
                dispatched += cells
        return useful, dispatched

    def compile_stats(self) -> dict:
        """Per-plan jit-cache counters (the serving stats surface)."""
        with self._ctr.lock:                     # consistent snapshot
            traces = self._ctr.traces
            jit_calls = self.jit_calls
            buckets = sorted(self._ctr.traced_buckets)
            rows = {k: list(v) for k, v in self._ctr.rows.items()}
        useful, dispatched = self._lut_cell_stats()
        # fused stacks dispatch Kmax/Nmax-padded operand slabs the batch
        # filler fraction alone never counted: fold the static operand
        # efficiency into the KERNEL backends' per-bucket waste (the
        # fallback backends run per-bank on true-size tables)
        fused_eff = useful / dispatched if dispatched else 1.0

        def _waste(be: str, req: int, disp: int) -> float:
            if not disp:
                return 0.0
            eff = fused_eff if be in ("kernel", "kernel_q8") else 1.0
            return round(1.0 - (req / disp) * eff, 4)

        return {
            "traces": traces,
            "jit_calls": jit_calls,
            "bucket_hits": jit_calls - traces,
            "buckets": buckets,
            # ladder efficiency: filler fraction of every dispatched bucket
            # (kernel backends include fused-stack operand padding)
            "pad_waste": {
                f"{be}@{bucket}": _waste(be, req, disp)
                for (be, bucket), (req, disp) in sorted(rows.items())
            },
            # static operand padding per fused group (batch-independent)
            "pad_waste_fused": {
                f"group{g}": {
                    "layers": len(s.banks),
                    "kmax": max(s.ks),
                    "nmax": int(s.lut.shape[-1]),
                    "frac": round(
                        1.0 - sum(b.layer.num_groups
                                  * b.layer.num_centroids
                                  * b.layer.out_features for b in s.banks)
                        / (len(s.banks) * max(s.ks)
                           * s.banks[0].layer.num_centroids
                           * int(s.lut.shape[-1])), 4),
                }
                for g, s in enumerate(self.fused_stacks)
            },
            # fusion coverage: how much of the plan runs as stacked kernels
            "fused_groups": self.fused_groups,
            "fused_banks": self.fused_banks,
            # sharded width: how many devices the batch axis splits across
            # (1 = single-device; placed calls don't change it)
            "devices": 1 if self.devices is None else len(self.devices),
            # plan-audit finding counts (repro.analysis.planaudit), None
            # when the plan was built with audit="off" and never audited
            "audit": None if self.audit_report is None
            else dict(self.audit_report.counts),
        }

    @property
    def num_banks(self) -> int:
        return len(self.banks)

    def bank_inputs(self, *inputs: jax.Array, backend: str = "gather") -> list:
        """Forward once (eagerly), recording the first activation each bank
        receives — a debugging/parity-test aid (None for unreached banks).
        Fused steps are walked per-bank so the recording stays per-bank."""
        rec: dict[int, jax.Array] = {}

        def apply(bank, x: jax.Array) -> jax.Array:
            if isinstance(bank, FusedBankStack):
                h = x
                for member in bank.banks:
                    rec.setdefault(id(member), h)
                    h = member.apply(h, backend)
                return h
            rec.setdefault(id(bank), x)
            return bank.apply(x, backend)

        self._forward(apply, self._state, *inputs)
        return [rec.get(id(b)) for b in self.banks]

    def table_bytes(self) -> int:
        """Total LUT bytes held by the plan (fp + q8 layouts)."""
        total = 0
        for b in self.banks:
            total += b.lut_p.size * b.lut_p.dtype.itemsize
            total += b.lut_q8_p.size * b.lut_q8_p.dtype.itemsize
        return total


def _compile_banks(layers: Sequence[PegasusLinear], **kw) -> list[CompiledBank]:
    return [CompiledBank(l, **kw) for l in layers]


def _note_fusion(plan: ExecutionPlan, steps: Sequence) -> None:
    for s in steps:
        if isinstance(s, FusedBankStack):
            plan.fused_groups += 1
            plan.fused_banks += len(s.banks)
            plan.fused_stacks.append(s)


def _sequential_plan(layers, backend, kw, buckets, fuse, nmax_cap,
                     devices=None) -> ExecutionPlan:
    banks = _compile_banks(layers, **kw)
    steps = fuse_banks(banks, nmax_cap=nmax_cap) if fuse else list(banks)

    def forward(apply, state, x):
        h = x.astype(jnp.float32)
        for step in state["steps"]:
            h = apply(step, h)
        return h

    plan = ExecutionPlan(banks, forward, {"steps": steps}, backend=backend,
                         family="sequential", bucket_sizes=buckets,
                         devices=devices)
    _note_fusion(plan, steps)
    return plan


def _rnn_plan(model, backend, kw, buckets, devices=None) -> ExecutionPlan:
    x_banks = _compile_banks(model.x_banks, **kw)
    h_banks = _compile_banks(model.h_banks, **kw)
    out_bank = CompiledBank(model.out_bank, **kw)
    window = int(model.window)   # static: the unroll length is frozen into
    # the plan (bank swaps after compilation are caught by plan_for's
    # _model_banks identity check, which rebuilds the plan)

    def forward(apply, state, x):
        xf = x.astype(jnp.float32)
        h_pre = apply(state["x"][0], xf[:, 0])
        for t in range(1, window):
            h_pre = apply(state["x"][t], xf[:, t]) + apply(state["h"][t - 1], h_pre)
        return apply(state["out"], h_pre)

    state = {"x": x_banks, "h": h_banks, "out": out_bank}
    return ExecutionPlan(x_banks + h_banks + [out_bank], forward, state,
                         backend=backend, family="rnn", bucket_sizes=buckets,
                         devices=devices)


def _cnn_plan(model, backend, kw, buckets, fuse, nmax_cap,
              devices=None) -> ExecutionPlan:
    from repro.nets.cnn import _windows  # structural helper, no cycle at call time

    window_bank = CompiledBank(model.window_bank, **kw)
    head_banks = _compile_banks(model.head_banks, **kw)
    # the head chain after the window pool is an ordinary sequential run —
    # fusable; the windowed step itself stays structural (per-window batch)
    head_steps = (fuse_banks(head_banks, nmax_cap=nmax_cap) if fuse
                  else list(head_banks))
    nam = bool(model.nam)        # static branch selector
    state = {
        "window": window_bank,
        "heads": head_steps,
        "out_bias": None if model.out_bias is None else jnp.asarray(model.out_bias),
    }

    def forward(apply, state, x):
        win = _windows(x.astype(jnp.float32))          # [B, P, KERNEL*f]
        b, pcount, wdim = win.shape
        contrib = apply(state["window"], win.reshape(-1, wdim)).reshape(b, pcount, -1)
        if nam:
            return contrib.sum(axis=1) + state["out_bias"]  # single SumReduce
        h = contrib.mean(axis=1)                       # rows already ReLU'd
        for bank in state["heads"]:
            h = apply(bank, h)
        return h

    plan = ExecutionPlan([window_bank] + head_banks, forward, state,
                         backend=backend, family="cnn", bucket_sizes=buckets,
                         devices=devices)
    _note_fusion(plan, head_steps)
    return plan


def _cnn_l_plan(model, backend, kw, buckets, devices=None) -> ExecutionPlan:
    from repro.nets.cnn import _packet_feats

    bank1 = CompiledBank(model.bank1, **kw)
    bank2 = CompiledBank(model.bank2, **kw)
    state = {
        "b1": bank1,
        "b2": bank2,
        "emb_tree": model.emb_tree,                    # FuzzyTree is a pytree
        "logit_lut": jnp.asarray(model.logit_lut),
        "bias": jnp.asarray(model.bias),
    }

    def forward(apply, state, seq, payload):
        x = _packet_feats(seq, payload) * 255.0        # [B, W, 62]
        b, w, d = x.shape
        h_pre = apply(state["b1"], x.reshape(-1, d))
        e_pre = apply(state["b2"], h_pre)
        emb = jnp.tanh(e_pre)
        idx = hard_index(state["emb_tree"], emb)
        contrib = state["logit_lut"][idx].reshape(b, w, -1)
        return contrib.sum(axis=1) + state["bias"]

    return ExecutionPlan([bank1, bank2], forward, state, backend=backend,
                         family="cnn_l", bucket_sizes=buckets,
                         devices=devices)


def build_plan(
    model: Any,
    *,
    backend: str = "onehot",
    block_t: int = 256,
    block_n: int = 256,
    block_k: int = 128,
    interpret: bool | None = None,
    strategy: str = "auto",
    bucket_sizes: Sequence[int] | None = None,
    fuse: bool = True,
    fuse_nmax_cap: int | None = DEFAULT_FUSE_NMAX_CAP,
    devices=None,
    audit: str = "warn",
) -> ExecutionPlan:
    """Compile any pegasusified model into an ExecutionPlan.

    Dispatch is structural (no imports of the net modules at module scope):
      * list/tuple of PegasusLinear  → sequential stack (MLP, AutoEncoder)
      * ``.x_banks``/``.h_banks``    → PegasusRNN
      * ``.window_bank``             → PegasusCNN (B and M/NAM)
      * ``.emb_tree``/``.logit_lut`` → PegasusCNNL (two-level NAM)

    Args:
        model: any pegasusified model (see the dispatch table above). A
            bare ``PegasusLinear`` is treated as a one-bank stack.
            Unrecognized structures raise ``TypeError`` at build time,
            never at call time.
        backend: default execution backend for ``plan(x)`` calls —
            ``"gather"`` | ``"onehot"`` | ``"kernel"`` | ``"kernel_q8"``
            (:data:`BACKENDS`); overridable per call via
            ``plan(x, backend=...)``. Unknown names raise ``ValueError``.
        block_t / block_n / block_k: Pallas kernel tile sizes (rows of the
            batch / LUT output columns / tree-descent lanes per program).
            Only the kernel backends read them; defaults suit the bank
            shapes the nets produce. Mis-sized tiles fail inside
            ``pallas_call`` at first trace, not at build.
        interpret: ``True`` forces Pallas interpret mode (slow, runs
            anywhere), ``False`` requires a compiled Pallas backend,
            ``None`` (default) resolves via :func:`default_interpret` —
            interpret everywhere except a real TPU backend.
        strategy: Map+SumReduce realization for the kernel backends —
            ``"mxu"`` (one-hot × LUT matmul), ``"lookup"`` (sparse
            gather descent), or ``"auto"`` (default: ``lookup`` under
            interpret mode, ``mxu`` on compiled TPU).
        bucket_sizes: overrides the batch-bucket ladder (default
            :data:`DEFAULT_BUCKETS`, 8…4096). Must be sorted ascending;
            batches above the top bucket round up to multiples of it.
            Fewer buckets ⇒ fewer traces but more padded compute
            (``compile_stats()["pad_waste"]`` reports the waste).
        fuse: ``False`` disables the cross-bank fusion pass
            (:func:`fuse_banks`) — the A/B switch and the escape hatch
            for a shape the stacked kernel mishandles (a stack the
            kernel refuses falls back per-bank instead of dying).
        fuse_nmax_cap: bounds each fused group's padded output width
            (:data:`DEFAULT_FUSE_NMAX_CAP` = 2048 columns; ``None``
            disables the cap) so one wide bank cannot balloon a narrow
            stack's padded ``[L, Kmax, C, Nmax]`` VMEM footprint;
            uniformly-wide runs still fuse above the cap because they
            add no padding. Both fusion knobs participate in
            ``plan_for``'s memo key, so fused and unfused plans of one
            model coexist.
        devices: SHARDED execution mode — ``None`` (default) compiles a
            single-device plan; an int ``k`` or a sequence of
            ``jax.Device``/device ids (see :func:`resolve_devices`) wraps
            the whole-plan forward in ``shard_map`` over a 1-D batch
            mesh: the padded bucket splits evenly across the devices and
            the bank operands replicate (they are KiB-scale). Outputs
            are bit-exact with the single-device plan. Every bucket size
            must divide by the device count (``ValueError`` at build).
            Participates in ``plan_for``'s memo key, so sharded and
            single-device plans of one model coexist.
        audit: run the static plan auditor (:mod:`repro.analysis.planaudit`,
            PGA101-PGA106) over the freshly built plan — ``"warn"``
            (default) attaches the report and raises a ``UserWarning``
            when it carries error/warning findings, ``"error"`` raises
            :class:`repro.analysis.planaudit.PlanAuditError` on error
            findings, ``"off"`` skips the pass (``plan.audit_report``
            stays ``None``). The audit never dispatches jax computation;
            it reads the plan's host-side tables only.

    The plan freezes ALL model state at build time — banks and non-bank
    attributes alike (RNN window, CNN nam/out_bias, CNN-L
    emb_tree/logit_lut/bias). Mutating the model afterwards does NOT affect
    a plan you hold: rebuild it, or go through :func:`plan_for`, whose memo
    detects bank swaps and non-bank reassignment and recompiles.
    """
    kw = dict(block_t=block_t, block_n=block_n, block_k=block_k,
              interpret=default_interpret() if interpret is None else interpret,
              strategy=strategy)
    if isinstance(model, PegasusLinear):
        plan = _sequential_plan([model], backend, kw, bucket_sizes, fuse,
                                fuse_nmax_cap, devices)
    elif isinstance(model, (list, tuple)):
        if not all(isinstance(l, PegasusLinear) for l in model):
            raise TypeError("bank list must contain only PegasusLinear")
        plan = _sequential_plan(model, backend, kw, bucket_sizes, fuse,
                                fuse_nmax_cap, devices)
    elif hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        plan = _rnn_plan(model, backend, kw, bucket_sizes, devices)
    elif hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        plan = _cnn_l_plan(model, backend, kw, bucket_sizes, devices)
    elif hasattr(model, "window_bank"):
        plan = _cnn_plan(model, backend, kw, bucket_sizes, fuse,
                         fuse_nmax_cap, devices)
    else:
        raise TypeError(f"don't know how to compile {type(model).__name__} into a plan")
    # the non-bank state the plan froze at build — plan_for compares this
    # against the live model to catch attribute reassignment (see _model_aux)
    plan._aux_token = _model_aux(model)
    # record the fusion knobs so the auditor can explain WHY a pair of
    # banks runs unfused (PGA105) instead of guessing
    plan.fuse_cfg = {"fuse": fuse, "nmax_cap": fuse_nmax_cap}
    _run_build_audit(plan, audit)
    return plan


def _run_build_audit(plan: ExecutionPlan, audit: str) -> None:
    """Build-time hook into the plan auditor. Imported lazily: plan.py is
    imported by the analysis package's sanitizer consumers, so a module-
    scope import would be circular."""
    if audit == "off":
        return
    if audit not in ("warn", "error"):
        raise ValueError(f"audit must be 'off'|'warn'|'error', got {audit!r}")
    from repro.analysis.planaudit import PlanAuditError, audit_plan

    report = audit_plan(plan)
    plan.audit_report = report
    counts = report.counts
    if audit == "error" and counts["error"]:
        raise PlanAuditError(report)
    if counts["error"] or counts["warning"]:
        warnings.warn(
            f"plan audit: {counts['error']} error / {counts['warning']} "
            f"warning finding(s) — inspect plan.audit_report or rerun "
            f"`python -m repro.analysis plan`:\n{report}",
            stacklevel=3)


# ---------------------------------------------------------------------------
# Model-structure helpers shared with the registry (repro.engine.registry),
# which owns all plan memoization: weakref-watched, bounded, evictable.
# ---------------------------------------------------------------------------


def _model_key(model: Any, interpret: bool, kw: dict) -> tuple:
    if isinstance(model, (list, tuple)):
        ids: tuple = tuple(id(l) for l in model)
    else:
        ids = (id(model),)
    return (*ids, interpret, tuple(sorted(kw.items())))


def _model_aux(model: Any) -> tuple:
    """Non-bank model state a compiled plan froze at build time (window
    length, NAM flag, out-bias, embedding tree, logit LUT). The registry
    must rebuild when any of it is reassigned — the forwards no longer read
    these attributes live, so a stale memo hit would silently serve outputs
    from the pre-mutation tensors."""
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return (int(model.window),)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return (model.emb_tree, model.logit_lut, model.bias)
    if hasattr(model, "window_bank"):
        return (bool(model.nam), model.out_bias)
    return ()


def _aux_matches(a: tuple, b: tuple) -> bool:
    """Identity for array-like entries (``==`` on jax arrays is elementwise),
    equality for plain scalars."""
    return len(a) == len(b) and all(
        x is y or (isinstance(x, (bool, int)) and isinstance(y, (bool, int))
                   and x == y)
        for x, y in zip(a, b))


def _model_banks(model: Any) -> tuple:
    """Current bank layers of a model, in plan construction order — used to
    detect in-place mutation (e.g. ``peg.window_bank = refine(...)``) that
    would otherwise hit the memo with a stale compiled plan."""
    if isinstance(model, PegasusLinear):
        return (model,)
    if isinstance(model, (list, tuple)):
        return tuple(model)
    if hasattr(model, "x_banks") and hasattr(model, "h_banks"):
        return (*model.x_banks, *model.h_banks, model.out_bank)
    if hasattr(model, "emb_tree") and hasattr(model, "logit_lut"):
        return (model.bank1, model.bank2)
    if hasattr(model, "window_bank"):
        return (model.window_bank, *model.head_banks)
    return ()
