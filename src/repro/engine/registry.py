"""PlanRegistry: named + memoized ExecutionPlans with weakref lifetimes.

Multi-model serving (Quark's all-on-dataplane sharing, FENIX's multiplexed
pipeline) needs one process to hold MANY compiled plans and to reclaim them
deterministically. This module owns ALL plan caching:

  * **Anonymous memo** (:meth:`PlanRegistry.plan_for` / module-level
    :func:`plan_for`) — the ``build_plan`` memo every ``pegasus_*_apply``
    wrapper hits. Entries are *weakref-watched*: the registry never pins the
    caller's model (plans hold detached bank replicas, see
    ``CompiledBank``), and a weakref callback on each watched object evicts
    the entry the moment the model is garbage-collected — dropped models
    free their plans, and a recycled ``id()`` can never alias a stale plan
    because the stale entry is gone before the id can be reused. The memo is
    LRU-bounded (``max_plans``) and explicitly evictable
    (:meth:`discard` / :meth:`clear`).
  * **Named entries** (:meth:`register` / :meth:`get`) — the serving
    surface: ``register("rnn-ids", model)`` pins the model + plan under a
    stable name until :meth:`evict`. ``get`` re-validates against the live
    model (bank swaps, aux reassignment) and transparently recompiles, so a
    served name never returns stale tables.

Staleness semantics are unchanged from the old strong-ref memo: a hit
requires the same model identity, the same bank layers in plan order, and
an unchanged non-bank aux token (window/NAM/bias/LUT — see ``_model_aux``).

**Thread safety:** registry state lives behind one RLock, but plan BUILDS
run outside it — a multi-second XLA compile for a newly added model must
not stall every in-flight ``get()`` on an always-on server. Racing
first-calls for one key are deduplicated by a per-key in-flight event:
the first caller builds, later callers wait and take the memo hit, so
concurrent first-calls still compile exactly once (the async serving
runtime submits from arbitrary threads while the drain thread revalidates
named entries).
"""

from __future__ import annotations

import threading
import time
import weakref
from collections import OrderedDict
from typing import Any

from repro.analysis.sanitizer import make_lock

from .plan import (
    DEFAULT_FUSE_NMAX_CAP,
    ExecutionPlan,
    STATS,
    _aux_matches,
    _model_aux,
    _model_banks,
    _model_key,
    build_plan,
    resolve_devices,
)
from repro.kernels.fuzzy_lut.kernel import default_interpret

__all__ = ["PlanRegistry", "plan_for", "reset_plan_cache", "default_registry"]


class _Entry:
    """One memoized plan + weakrefs to every object whose death evicts it."""

    __slots__ = ("key", "plan", "wrapper_ref", "bank_refs", "__weakref__")

    def __init__(self, key: tuple, model: Any, plan: ExecutionPlan,
                 on_death) -> None:
        self.key = key
        self.plan = plan
        watch = list(_model_banks(model))
        # identity check, not `in`: dataclass __eq__ on jax-array fields is
        # elementwise and has no truth value
        self.wrapper_ref = None
        if not isinstance(model, (list, tuple)) and all(model is not w for w in watch):
            try:
                self.wrapper_ref = weakref.ref(model, on_death)
            except TypeError:
                pass  # bare lists / slotted wrappers: bank refs carry eviction
        self.bank_refs = tuple(weakref.ref(b, on_death) for b in watch)

    def is_fresh(self, model: Any) -> bool:
        if self.wrapper_ref is not None and self.wrapper_ref() is not model:
            return False
        banks_now = _model_banks(model)
        if len(banks_now) != len(self.bank_refs):
            return False
        if any(r() is not b for r, b in zip(self.bank_refs, banks_now)):
            return False
        return _aux_matches(self.plan._aux_token, _model_aux(model))


class PlanRegistry:
    """Owns ExecutionPlans: a bounded weakref-watched memo plus named,
    strongly-pinned serving entries. See the module docstring."""

    def __init__(self, max_plans: int = 64):
        self.max_plans = max_plans
        # fault-injection hook (repro.launch.chaos) — assigned by
        # MultiModelServer.install_chaos() or directly in tests; duck-typed
        # so the engine layer never imports the launch layer. None (the
        # default) costs one attribute load per named build.
        self.chaos = None
        # reentrant: discard nests under register/evict, and a GC pass while
        # the lock is held may fire on_death callbacks on the same thread
        self._lock = make_lock("registry._lock", reentrant=True)
        self._memo: OrderedDict[tuple, _Entry] = OrderedDict()  # guarded-by: _lock
        self._named: dict[str, dict] = {}                       # guarded-by: _lock
        # key → Event: a build in progress; later same-key callers wait for
        # it instead of compiling a duplicate (builds run OUTSIDE _lock)
        self._building: dict[tuple, threading.Event] = {}       # guarded-by: _lock

    # -- anonymous memo (the plan_for surface) ------------------------------

    def plan_for(self, model: Any, *, interpret: bool | None = None,
                 **kw) -> ExecutionPlan:
        """Memoized :func:`build_plan`. Build options participate in the
        key — including the fusion config (``fuse``/``strategy``/block
        geometry) — so the same model may hold e.g. interpret and
        non-interpret, or fused and unfused, plans side by side."""
        interpret = default_interpret() if interpret is None else interpret
        # the audit mode does not change the compiled artifact — pop it
        # BEFORE keying so audit="off" and the default share one plan
        audit = kw.pop("audit", "warn")
        if kw.get("bucket_sizes") is not None:
            kw["bucket_sizes"] = tuple(kw["bucket_sizes"])
        # normalize into the key: an absent fuse kwarg IS fuse=True (the
        # build_plan default) — without this, plan_for(m) and
        # plan_for(m, fuse=True) would build and cache the same plan twice
        kw["fuse"] = bool(kw.get("fuse", True))
        cap = kw.get("fuse_nmax_cap", DEFAULT_FUSE_NMAX_CAP)
        kw["fuse_nmax_cap"] = None if cap is None else int(cap)
        # devices participates in the key as the resolved Device tuple, so
        # devices=2 and devices=jax.devices()[:2] share one plan, and an
        # absent kwarg keys identically to devices=None (single-device)
        kw["devices"] = resolve_devices(kw.get("devices"))
        key = _model_key(model, interpret, kw)
        while True:
            with self._lock:
                entry = self._memo.get(key)
                if entry is not None:
                    if entry.is_fresh(model):
                        STATS.plan_cache_hits += 1
                        self._memo.move_to_end(key)
                        return entry.plan
                    self._memo.pop(key, None)  # stale: bank/aux reassignment
                inflight = self._building.get(key)
                if inflight is None:
                    done = self._building[key] = threading.Event()
                    break                      # this thread builds
            # same-key build in progress elsewhere: wait, then re-check the
            # memo (hit on success; on builder failure, become the builder)
            inflight.wait()
        try:
            # the build runs WITHOUT the registry lock: other models keep
            # serving while this one's XLA trace/compile grinds
            plan = build_plan(model, interpret=interpret, audit=audit, **kw)
        except BaseException:
            with self._lock:
                self._building.pop(key, None)
            done.set()
            raise
        holder: list = []

        def on_death(_ref, registry=weakref.ref(self)):
            reg = registry()
            if reg is None:
                return
            with reg._lock:
                if holder and reg._memo.get(key) is holder[0]:
                    del reg._memo[key]

        entry = _Entry(key, model, plan, on_death)
        holder.append(entry)
        with self._lock:
            self._building.pop(key, None)
            while len(self._memo) >= self.max_plans:
                self._memo.popitem(last=False)
            self._memo[key] = entry
        done.set()
        return plan

    def discard(self, model: Any) -> int:
        """Explicitly evict every memo entry built for ``model`` (any build
        options). Returns the number of entries dropped."""
        banks = _model_banks(model)
        with self._lock:
            # snapshot: a cyclic-GC pass during iteration may fire on_death
            # callbacks that delete entries from the live dict
            doomed = [k for k, e in list(self._memo.items())
                      if (e.wrapper_ref is not None and e.wrapper_ref() is model)
                      or (banks and len(banks) == len(e.bank_refs)
                          and all(r() is b for r, b in zip(e.bank_refs, banks)))]
            for k in doomed:
                del self._memo[k]
            return len(doomed)

    def clear(self) -> None:
        with self._lock:
            self._memo.clear()
            self._named.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._memo)

    def cache_info(self) -> dict:
        with self._lock:
            return {"entries": len(self._memo), "capacity": self.max_plans,
                    "named": sorted(self._named)}

    # -- named serving entries ----------------------------------------------

    def register(self, name: str, model: Any, *, backend: str = "onehot",
                 **build_kw) -> ExecutionPlan:
        """Compile (or reuse) a plan for ``model`` and pin it under ``name``.
        Re-registering a name replaces its entry AND discards the replaced
        model's memo entries (matching :meth:`evict` — without this, the
        superseded model's plan lingered in the memo until LRU churn or GC
        even though nothing served it). The discard is skipped when old and
        new wrap the SAME bank objects: memo entries match by bank
        identity, so discarding would evict the new model's entry too."""
        t0 = time.perf_counter()
        chaos = self.chaos
        if chaos is not None:
            chaos.fire("plan_build", model=name, backend=backend)
        plan = self.plan_for(model, backend=backend, **build_kw)
        build_ms = (time.perf_counter() - t0) * 1e3
        with self._lock:
            old = self._named.get(name)
            self._named[name] = {
                "model": model,
                # the named store carries its own freshness watcher: named
                # plans must survive memo LRU churn without recompiling (the
                # memo is bounded; the pin is not)
                "entry": _Entry(None, model, plan, lambda _ref: None),
                "backend": backend,
                "build_kw": dict(build_kw),
                "plan_build_ms": build_ms,
                "recompiles": 0,
            }
            if (old is not None and old["model"] is not model
                    and tuple(map(id, _model_banks(old["model"])))
                    != tuple(map(id, _model_banks(model)))):
                self.discard(old["model"])
            return plan

    def get(self, name: str) -> ExecutionPlan:
        """The plan serving ``name`` — revalidated against the live model,
        recompiling on bank/aux reassignment so a name never serves stale
        tables. A recompile refreshes the entry's build stats
        (``plan_build_ms`` re-times the rebuild, ``recompiles`` counts
        them) — the stale-stats bug left the original register() timing on
        an entry whose plan had long been replaced. The rebuild itself runs
        without the registry lock (see the module docstring)."""
        with self._lock:
            ent = self._named[name]
            if ent["entry"].is_fresh(ent["model"]):
                return ent["entry"].plan
            model = ent["model"]
            backend, build_kw = ent["backend"], dict(ent["build_kw"])
        chaos = self.chaos
        if chaos is not None:
            chaos.fire("plan_build", model=name, backend=backend)
        t0 = time.perf_counter()
        plan = self.plan_for(model, backend=backend, **build_kw)
        with self._lock:
            ent = self._named.get(name)
            if ent is None or ent["model"] is not model:
                return plan              # evicted/re-registered meanwhile
            ent["entry"] = _Entry(None, model, plan, lambda _ref: None)
            ent["plan_build_ms"] = (time.perf_counter() - t0) * 1e3
            ent["recompiles"] += 1
            return plan

    def get_with_backend(self, name: str, backend: str) -> ExecutionPlan:
        """A plan for the model serving ``name``, (re)built for ``backend``
        instead of the registered one — the server's fallback-ladder entry
        point (``kernel`` path failing → serve degraded on ``gather``).
        Hits the memo when the fallback plan was already built (backend
        participates in the memo key), so flapping between preferred and
        fallback costs one compile each, total. The named entry itself is
        untouched: the preferred backend stays registered, and probe-back
        goes through :meth:`get` as usual."""
        with self._lock:
            ent = self._named[name]
            model = ent["model"]
            build_kw = dict(ent["build_kw"])
        chaos = self.chaos
        if chaos is not None:
            chaos.fire("plan_build", model=name, backend=backend)
        return self.plan_for(model, backend=backend, **build_kw)

    def backend_of(self, name: str) -> str:
        """The registered (preferred) backend serving ``name``."""
        with self._lock:
            return self._named[name]["backend"]

    def model(self, name: str) -> Any:
        with self._lock:
            return self._named[name]["model"]

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._named)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._named

    def evict(self, name: str) -> bool:
        """Drop a named entry (and its memo entry). The plan dies with the
        registry's pins unless the caller holds it elsewhere."""
        with self._lock:
            ent = self._named.pop(name, None)
            if ent is None:
                return False
            self.discard(ent["model"])
            return True

    def audit_report(self, name: str):
        """The plan-audit report for the plan serving ``name``
        (:class:`repro.analysis.planaudit.AuditReport`). Plans built with
        ``audit="off"`` are audited lazily here, once, and the report is
        cached on the plan — so ``stats()`` keeps reporting real counts
        even when builds skip the inline pass. Runs OUTSIDE the registry
        lock (the audit walks host-side tables, not the memo)."""
        plan = self.get(name)
        if plan.audit_report is None:
            from repro.analysis.planaudit import audit_plan

            plan.audit_report = audit_plan(plan)
        return plan.audit_report

    def stats(self) -> dict:
        """Per-name compile-cache + build stats (the serving ops surface)."""
        with self._lock:
            entries = sorted(self._named.items())
            return {
                name: {
                    "backend": ent["backend"],
                    "plan_build_ms": ent["plan_build_ms"],
                    "recompiles": ent.get("recompiles", 0),
                    "num_banks": ent["entry"].plan.num_banks,
                    "table_bytes": ent["entry"].plan.table_bytes(),
                    **ent["entry"].plan.compile_stats(),
                }
                for name, ent in entries
            }


# ---------------------------------------------------------------------------
# Default (module-global) registry — the plan_for every wrapper hits.
# ---------------------------------------------------------------------------

_DEFAULT = PlanRegistry()


def default_registry() -> PlanRegistry:
    return _DEFAULT


def plan_for(model: Any, *, interpret: bool | None = None, **kw) -> ExecutionPlan:
    """Memoized build_plan against the default registry. Plans are
    backend-agnostic here — pass the backend per call
    (``plan(x, backend=...)``); binding a default belongs to explicit
    build_plan/register. Block-size overrides participate in the key."""
    return _DEFAULT.plan_for(model, interpret=interpret, **kw)


def reset_plan_cache() -> None:
    _DEFAULT.clear()
