"""PlanRegistry: named + memoized ExecutionPlans with weakref lifetimes.

Multi-model serving (Quark's all-on-dataplane sharing, FENIX's multiplexed
pipeline) needs one process to hold MANY compiled plans and to reclaim them
deterministically. This module owns ALL plan caching:

  * **Anonymous memo** (:meth:`PlanRegistry.plan_for` / module-level
    :func:`plan_for`) — the ``build_plan`` memo every ``pegasus_*_apply``
    wrapper hits. Entries are *weakref-watched*: the registry never pins the
    caller's model (plans hold detached bank replicas, see
    ``CompiledBank``), and a weakref callback on each watched object evicts
    the entry the moment the model is garbage-collected — dropped models
    free their plans, and a recycled ``id()`` can never alias a stale plan
    because the stale entry is gone before the id can be reused. The memo is
    LRU-bounded (``max_plans``) and explicitly evictable
    (:meth:`discard` / :meth:`clear`).
  * **Named entries** (:meth:`register` / :meth:`get`) — the serving
    surface: ``register("rnn-ids", model)`` pins the model + plan under a
    stable name until :meth:`evict`. ``get`` re-validates against the live
    model (bank swaps, aux reassignment) and transparently recompiles, so a
    served name never returns stale tables.

Staleness semantics are unchanged from the old strong-ref memo: a hit
requires the same model identity, the same bank layers in plan order, and
an unchanged non-bank aux token (window/NAM/bias/LUT — see ``_model_aux``).
"""

from __future__ import annotations

import time
import weakref
from collections import OrderedDict
from typing import Any

from .plan import (
    ExecutionPlan,
    STATS,
    _aux_matches,
    _model_aux,
    _model_banks,
    _model_key,
    build_plan,
)
from repro.kernels.fuzzy_lut.kernel import default_interpret

__all__ = ["PlanRegistry", "plan_for", "reset_plan_cache", "default_registry"]


class _Entry:
    """One memoized plan + weakrefs to every object whose death evicts it."""

    __slots__ = ("key", "plan", "wrapper_ref", "bank_refs", "__weakref__")

    def __init__(self, key: tuple, model: Any, plan: ExecutionPlan,
                 on_death) -> None:
        self.key = key
        self.plan = plan
        watch = list(_model_banks(model))
        # identity check, not `in`: dataclass __eq__ on jax-array fields is
        # elementwise and has no truth value
        self.wrapper_ref = None
        if not isinstance(model, (list, tuple)) and all(model is not w for w in watch):
            try:
                self.wrapper_ref = weakref.ref(model, on_death)
            except TypeError:
                pass  # bare lists / slotted wrappers: bank refs carry eviction
        self.bank_refs = tuple(weakref.ref(b, on_death) for b in watch)

    def is_fresh(self, model: Any) -> bool:
        if self.wrapper_ref is not None and self.wrapper_ref() is not model:
            return False
        banks_now = _model_banks(model)
        if len(banks_now) != len(self.bank_refs):
            return False
        if any(r() is not b for r, b in zip(self.bank_refs, banks_now)):
            return False
        return _aux_matches(self.plan._aux_token, _model_aux(model))


class PlanRegistry:
    """Owns ExecutionPlans: a bounded weakref-watched memo plus named,
    strongly-pinned serving entries. See the module docstring."""

    def __init__(self, max_plans: int = 64):
        self.max_plans = max_plans
        self._memo: OrderedDict[tuple, _Entry] = OrderedDict()
        self._named: dict[str, dict] = {}

    # -- anonymous memo (the plan_for surface) ------------------------------

    def plan_for(self, model: Any, *, interpret: bool | None = None,
                 **kw) -> ExecutionPlan:
        """Memoized :func:`build_plan`. Build options participate in the
        key — including the fusion config (``fuse``/``strategy``/block
        geometry) — so the same model may hold e.g. interpret and
        non-interpret, or fused and unfused, plans side by side."""
        interpret = default_interpret() if interpret is None else interpret
        if kw.get("bucket_sizes") is not None:
            kw["bucket_sizes"] = tuple(kw["bucket_sizes"])
        # normalize into the key: an absent fuse kwarg IS fuse=True (the
        # build_plan default) — without this, plan_for(m) and
        # plan_for(m, fuse=True) would build and cache the same plan twice
        kw["fuse"] = bool(kw.get("fuse", True))
        key = _model_key(model, interpret, kw)
        entry = self._memo.get(key)
        if entry is not None:
            if entry.is_fresh(model):
                STATS.plan_cache_hits += 1
                self._memo.move_to_end(key)
                return entry.plan
            self._memo.pop(key, None)  # stale: bank/aux reassignment
        plan = build_plan(model, interpret=interpret, **kw)
        holder: list = []

        def on_death(_ref, registry=weakref.ref(self)):
            reg = registry()
            if reg is not None and holder and reg._memo.get(key) is holder[0]:
                del reg._memo[key]

        entry = _Entry(key, model, plan, on_death)
        holder.append(entry)
        while len(self._memo) >= self.max_plans:
            self._memo.popitem(last=False)
        self._memo[key] = entry
        return plan

    def discard(self, model: Any) -> int:
        """Explicitly evict every memo entry built for ``model`` (any build
        options). Returns the number of entries dropped."""
        banks = _model_banks(model)
        # snapshot: a cyclic-GC pass during iteration may fire on_death
        # callbacks that delete entries from the live dict
        doomed = [k for k, e in list(self._memo.items())
                  if (e.wrapper_ref is not None and e.wrapper_ref() is model)
                  or (banks and len(banks) == len(e.bank_refs)
                      and all(r() is b for r, b in zip(e.bank_refs, banks)))]
        for k in doomed:
            del self._memo[k]
        return len(doomed)

    def clear(self) -> None:
        self._memo.clear()
        self._named.clear()

    def __len__(self) -> int:
        return len(self._memo)

    def cache_info(self) -> dict:
        return {"entries": len(self._memo), "capacity": self.max_plans,
                "named": sorted(self._named)}

    # -- named serving entries ----------------------------------------------

    def register(self, name: str, model: Any, *, backend: str = "onehot",
                 **build_kw) -> ExecutionPlan:
        """Compile (or reuse) a plan for ``model`` and pin it under ``name``.
        Re-registering a name replaces its entry."""
        t0 = time.perf_counter()
        plan = self.plan_for(model, backend=backend, **build_kw)
        self._named[name] = {
            "model": model,
            # the named store carries its own freshness watcher: named plans
            # must survive memo LRU churn without recompiling (the memo is
            # bounded; the pin is not)
            "entry": _Entry(None, model, plan, lambda _ref: None),
            "backend": backend,
            "build_kw": dict(build_kw),
            "plan_build_ms": (time.perf_counter() - t0) * 1e3,
        }
        return plan

    def get(self, name: str) -> ExecutionPlan:
        """The plan serving ``name`` — revalidated against the live model,
        recompiling on bank/aux reassignment so a name never serves stale
        tables."""
        ent = self._named[name]
        if ent["entry"].is_fresh(ent["model"]):
            return ent["entry"].plan
        plan = self.plan_for(ent["model"], backend=ent["backend"],
                             **ent["build_kw"])
        ent["entry"] = _Entry(None, ent["model"], plan, lambda _ref: None)
        return plan

    def model(self, name: str) -> Any:
        return self._named[name]["model"]

    def names(self) -> list[str]:
        return sorted(self._named)

    def __contains__(self, name: str) -> bool:
        return name in self._named

    def evict(self, name: str) -> bool:
        """Drop a named entry (and its memo entry). The plan dies with the
        registry's pins unless the caller holds it elsewhere."""
        ent = self._named.pop(name, None)
        if ent is None:
            return False
        self.discard(ent["model"])
        return True

    def stats(self) -> dict:
        """Per-name compile-cache + build stats (the serving ops surface)."""
        return {
            name: {
                "backend": ent["backend"],
                "plan_build_ms": ent["plan_build_ms"],
                "num_banks": ent["entry"].plan.num_banks,
                "table_bytes": ent["entry"].plan.table_bytes(),
                **ent["entry"].plan.compile_stats(),
            }
            for name, ent in sorted(self._named.items())
        }


# ---------------------------------------------------------------------------
# Default (module-global) registry — the plan_for every wrapper hits.
# ---------------------------------------------------------------------------

_DEFAULT = PlanRegistry()


def default_registry() -> PlanRegistry:
    return _DEFAULT


def plan_for(model: Any, *, interpret: bool | None = None, **kw) -> ExecutionPlan:
    """Memoized build_plan against the default registry. Plans are
    backend-agnostic here — pass the backend per call
    (``plan(x, backend=...)``); binding a default belongs to explicit
    build_plan/register. Block-size overrides participate in the key."""
    return _DEFAULT.plan_for(model, interpret=interpret, **kw)


def reset_plan_cache() -> None:
    _DEFAULT.clear()
