"""Unified Pegasus execution engine (whole-plan jitted, backend-dispatched).

One compilation step — :func:`build_plan` — turns ANY pegasusified model
(MLP bank list, PegasusRNN, PegasusCNN, PegasusCNNL, AutoEncoder bank list)
into a reusable :class:`ExecutionPlan`: the kernel layouts (feature one-hots,
block-padded LUT/threshold tensors, int8-quantized LUT + scales) are built
ONCE at plan time, and every call traces the ENTIRE forward into one jitted
XLA computation per ``(backend, batch-bucket)`` — request batches are padded
up to a bounded bucket ladder (:data:`DEFAULT_BUCKETS`) so varying sizes hit
a warm compile cache. Backends: ``{"gather", "onehot", "kernel",
"kernel_q8"}``; compile-cache behavior is observable via :data:`STATS`
(``jit_traces`` / ``jit_calls``) and ``plan.compile_stats()`` (which also
reports per-bucket ``pad_waste`` and the fusion coverage counters).

Cross-bank Primitive Fusion (:func:`fuse_banks` / :class:`FusedBankStack`,
on by default — ``build_plan(..., fuse=False)`` opts out): compatible
consecutive banks execute as ONE stacked Pallas kernel invocation on the
``kernel``/``kernel_q8`` backends, activations re-partitioned bank-to-bank
inside VMEM instead of round-tripping between L separate ``pallas_call``s.

Plan lifetime is owned by :class:`PlanRegistry` (``registry.py``): a
weakref-watched, LRU-bounded memo behind :func:`plan_for` (dropped models
evict their plans) plus named, strongly-pinned entries behind
``register``/``get`` — the multi-model serving surface
(``repro.launch.serve.MultiModelServer``).
"""

from .plan import (
    BACKENDS,
    DEFAULT_BUCKETS,
    DEFAULT_FUSE_NMAX_CAP,
    STATS,
    CompiledBank,
    EngineStats,
    ExecutionPlan,
    FusedBankStack,
    bucket_batch,
    bucket_chunks,
    build_plan,
    fuse_banks,
)
from .registry import (
    PlanRegistry,
    default_registry,
    plan_for,
    reset_plan_cache,
)

__all__ = [
    "BACKENDS",
    "DEFAULT_BUCKETS",
    "DEFAULT_FUSE_NMAX_CAP",
    "STATS",
    "CompiledBank",
    "EngineStats",
    "ExecutionPlan",
    "FusedBankStack",
    "PlanRegistry",
    "bucket_batch",
    "bucket_chunks",
    "build_plan",
    "default_registry",
    "fuse_banks",
    "plan_for",
    "reset_plan_cache",
]
