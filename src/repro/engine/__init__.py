"""Unified Pegasus execution engine (backend-dispatched, plan-cached).

One compilation step — :func:`build_plan` — turns ANY pegasusified model
(MLP bank list, PegasusRNN, PegasusCNN, PegasusCNNL, AutoEncoder bank list)
into a reusable :class:`ExecutionPlan`: the kernel layouts (feature one-hots,
block-padded LUT/threshold tensors, int8-quantized LUT + scales) are built
ONCE at plan time, and every subsequent call is pure compute on one of the
four backends ``{"gather", "onehot", "kernel", "kernel_q8"}``.
"""

from .plan import (
    BACKENDS,
    STATS,
    CompiledBank,
    EngineStats,
    ExecutionPlan,
    build_plan,
    plan_for,
    reset_plan_cache,
)

__all__ = [
    "BACKENDS",
    "STATS",
    "CompiledBank",
    "EngineStats",
    "ExecutionPlan",
    "build_plan",
    "plan_for",
    "reset_plan_cache",
]
