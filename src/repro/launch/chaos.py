"""Deterministic fault injection for the serving stack (the chaos harness).

The self-healing machinery in :mod:`repro.launch.serve` /
:mod:`repro.launch.devices` (circuit breakers, the backend fallback
ladder, bounded retry, device-stream supervision — see
docs/RELIABILITY.md) only earns trust if its error paths can be exercised
ON DEMAND, deterministically, in tests and benches. This module provides
that: a seeded :class:`FaultInjector` holding scoped fault specs that make
a named model's plan call, a plan build, or a device-stream dispatch
raise / hang / slow on the Nth matching occurrence — transient (``count``
fires) or persistent (``count=None``).

Installation is EXPLICIT, never monkey-patching: the serving components
carry a ``chaos`` hook attribute (``None`` by default) and call
``injector.fire(site, **scope)`` at their dispatch edges —

  * ``MultiModelServer.install_chaos(injector)`` wires the server, its
    ``PlanRegistry``, and its ``DeviceStreamPool`` in one call;
  * ``PlanRegistry.chaos`` / ``DeviceStreamPool.chaos`` are directly
    assignable for component-level tests.

Zero overhead when disabled: with no injector installed the hot path is a
single ``is not None`` check per dispatch edge (the edges are per
micro-batch / per chunk, never per flow), and the engine's bare ``plan()``
path — the regression-gated per-call number — carries no hook at all.

Sites and their scope keys (a spec field left ``None`` matches anything):

  ========================  =====================================
  site                      scope keys passed by the hooks
  ========================  =====================================
  ``"plan_call"``           ``model``, ``backend``
  ``"plan_build"``          ``model``, ``backend``
  ``"stream_dispatch"``     ``stream`` (device-stream index)
  ========================  =====================================

Determinism: matching, occurrence counting, and the probabilistic draw
(one ``random.Random(seed)`` owned by the injector) all happen in
``fire()`` call order under one lock, so the same seed and the same call
sequence produce the identical fired-fault :meth:`schedule` — the
property the chaos test suite pins.
"""

from __future__ import annotations

import time

from repro.analysis.sanitizer import make_lock

__all__ = ["FaultInjector", "FaultSpec", "InjectedFaultError",
           "SITES", "MODES"]

SITES = ("plan_call", "plan_build", "stream_dispatch")
MODES = ("raise", "hang", "slow")

# default stall for mode="slow" / mode="hang" when the spec leaves
# delay_ms unset: a slow call stutters, a hung call stalls long enough
# that any reasonable supervision/timeout fires first (tests pass a short
# explicit delay_ms instead — a true infinite hang would wedge the suite).
_SLOW_MS = 50.0
_HANG_MS = 30_000.0


class InjectedFaultError(RuntimeError):
    """The typed error an armed fault spec raises at its site. Carries the
    site and scope so handlers (and test assertions) can tell an injected
    fault from an organic one."""

    def __init__(self, site: str, scope: dict):
        self.site = site
        self.scope = dict(scope)
        detail = ", ".join(f"{k}={v!r}" for k, v in sorted(scope.items())
                           if v is not None)
        super().__init__(f"injected fault at {site} ({detail or 'any'})")


class FaultSpec:
    """One scoped fault plan. Built via :meth:`FaultInjector.inject`; the
    mutable counters are owned by the injector's lock."""

    __slots__ = ("site", "model", "backend", "stream", "mode", "after",
                 "count", "probability", "delay_ms", "error",
                 "matched", "fired")

    def __init__(self, site: str, *, model=None, backend=None, stream=None,
                 mode: str = "raise", after: int = 1, count: int | None = 1,
                 probability: float = 1.0, delay_ms: float | None = None):
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; expected one "
                             f"of {SITES}")
        if mode not in MODES:
            raise ValueError(f"unknown fault mode {mode!r}; expected one "
                             f"of {MODES}")
        if after < 1:
            raise ValueError(f"after is the 1-based Nth matching "
                             f"occurrence; got {after}")
        if count is not None and count < 1:
            raise ValueError(f"count must be ≥ 1 or None (persistent); "
                             f"got {count}")
        self.site = site                # immutable after construction
        self.model = model              # immutable after construction
        self.backend = backend          # immutable after construction
        self.stream = stream            # immutable after construction
        self.mode = mode                # immutable after construction
        self.after = int(after)         # immutable after construction
        self.count = count              # immutable after construction
        self.probability = float(probability)   # immutable
        if delay_ms is None:
            delay_ms = _HANG_MS if mode == "hang" else _SLOW_MS
        self.delay_ms = float(delay_ms)          # immutable
        self.error = None               # optional raise payload; immutable
        self.matched = 0                # guarded-by: _lock
        self.fired = 0                  # guarded-by: _lock

    # holds: _lock (the owning injector's — counters read/written under it)
    def _matches(self, scope: dict) -> bool:
        return ((self.model is None or scope.get("model") == self.model)
                and (self.backend is None
                     or scope.get("backend") == self.backend)
                and (self.stream is None
                     or scope.get("stream") == self.stream))

    def describe(self) -> dict:
        """Schema-stable spec description (counters read by the owner)."""
        return {"site": self.site, "model": self.model,
                "backend": self.backend, "stream": self.stream,
                "mode": self.mode, "after": self.after, "count": self.count,
                "probability": self.probability, "delay_ms": self.delay_ms}


class FaultInjector:
    """Seeded, scoped, deterministic fault injection (module docstring).

    Typical use::

        inj = FaultInjector(seed=7)
        # 2nd-and-every-later plan call for "ids" on its kernel path fails
        inj.inject("plan_call", model="ids", backend="kernel",
                   mode="raise", after=2, count=None)
        server.install_chaos(inj)

    ``fire()`` is the hook the serving components call; user code never
    calls it directly.
    """

    def __init__(self, seed: int = 0):
        import random
        self.seed = seed
        self._rng = random.Random(seed)   # guarded-by: _lock
        self._lock = make_lock("chaos._lock")
        self._specs: list[FaultSpec] = []     # guarded-by: _lock
        self._schedule: list[dict] = []       # guarded-by: _lock
        self._fired_total = 0                 # guarded-by: _lock
        # arm flag: a plain bool read on the hot path (GIL-atomic; a racing
        # disarm may let one in-flight fire through, which is fine — the
        # injector is test/bench machinery, not a safety interlock)
        self.armed = True

    # -- authoring -----------------------------------------------------------

    def inject(self, site: str, *, model: str | None = None,
               backend: str | None = None, stream: int | None = None,
               mode: str = "raise", after: int = 1, count: int | None = 1,
               probability: float = 1.0, delay_ms: float | None = None,
               error: BaseException | None = None) -> FaultSpec:
        """Register one scoped fault plan; returns the spec.

        Args:
            site: one of :data:`SITES`.
            model / backend / stream: scope filters — ``None`` matches any.
            mode: ``"raise"`` raises :class:`InjectedFaultError` (or
                ``error``), ``"slow"`` sleeps ``delay_ms`` then proceeds,
                ``"hang"`` is a long bounded stall (default 30 s — pass a
                short ``delay_ms`` in tests).
            after: the fault arms from the Nth MATCHING occurrence
                (1-based); earlier occurrences pass through.
            count: how many times it fires once armed; ``None`` =
                persistent (every matching occurrence from ``after`` on).
            probability: chance an armed occurrence actually fires, drawn
                from the injector's seeded RNG (deterministic per seed).
            delay_ms: stall length for ``slow``/``hang``.
            error: optional exception instance to raise instead of
                :class:`InjectedFaultError` (``raise`` mode only).
        """
        spec = FaultSpec(site, model=model, backend=backend, stream=stream,
                         mode=mode, after=after, count=count,
                         probability=probability, delay_ms=delay_ms)
        spec.error = error
        with self._lock:
            self._specs.append(spec)
        return spec

    def clear(self) -> None:
        """Drop every spec (fired-schedule history is kept — determinism
        assertions compare full histories)."""
        with self._lock:
            self._specs.clear()

    # -- the hook ------------------------------------------------------------

    def fire(self, site: str, **scope) -> None:
        """Component hook: evaluate every spec against this occurrence and
        act. Matching/counting/drawing happens under the lock; the ACTION
        (sleep or raise) happens outside it so a stalled fault cannot
        serialize unrelated hooks."""
        if not self.armed:
            return
        acting: list[FaultSpec] = []
        with self._lock:
            for spec in self._specs:
                if spec.site != site or not spec._matches(scope):
                    continue
                spec.matched += 1
                if spec.matched < spec.after:
                    continue
                if spec.count is not None and spec.fired >= spec.count:
                    continue
                if (spec.probability < 1.0
                        and self._rng.random() >= spec.probability):
                    continue
                spec.fired += 1
                self._fired_total += 1
                self._schedule.append({
                    "site": site, "mode": spec.mode,
                    "occurrence": spec.matched,
                    "model": scope.get("model"),
                    "backend": scope.get("backend"),
                    "stream": scope.get("stream"),
                })
                acting.append(spec)
        for spec in acting:
            if spec.mode in ("slow", "hang"):
                time.sleep(spec.delay_ms / 1e3)
            if spec.mode == "raise":
                raise (spec.error if spec.error is not None
                       else InjectedFaultError(site, scope))

    # -- introspection -------------------------------------------------------

    def schedule(self) -> list[dict]:
        """Every fired fault, in fire order — the deterministic record the
        same-seed-same-schedule test compares."""
        with self._lock:
            return [dict(e) for e in self._schedule]

    def stats(self) -> dict:
        """The ``health.chaos`` section of the server stats schema."""
        with self._lock:
            return {
                "installed": True,
                "seed": self.seed,
                "armed": self.armed,
                "fired": self._fired_total,
                "specs": [dict(s.describe(), matched=s.matched,
                               fired=s.fired) for s in self._specs],
            }
