"""Serving driver: prefill + batched decode with sharded KV caches, and the
Pegasus LUT path as a first-class serving feature (--pegasus).

``serve_step`` is the unit the decode_32k/long_500k dry-run cells lower:
one new token for the whole batch against preallocated caches/states.

``PegasusServer`` is the dataplane-model analog for ONE model: a compiled
:class:`repro.engine.ExecutionPlan` (layouts + int8 LUTs precomputed at
plan-build) reused across every request batch, with the backend —
``gather | onehot | kernel | kernel_q8`` — chosen once via ``--backend``.

``MultiModelServer`` is the scale step the paper's pitch implies (a shared
dataplane serves MANY models and traffic classes at once — Quark runs whole
CNNs on one switch, FENIX multiplexes DNN workloads through one pipeline):
N named heterogeneous plans (MLP/RNN/CNN/AE) behind one server, requests
addressed ``(model_name, inputs)``, same-model requests coalesced into
bucket-aligned micro-batches, models scheduled by weighted fair queueing
(:class:`repro.launch.scheduler.WFQScheduler`: deficit round-robin over
priority-weighted queues), and per-model serving + compile-cache +
latency stats.

``AsyncMultiModelServer`` makes that an always-on service: a background
drain thread, thread-safe ``submit()`` returning futures, an
asyncio-native ``infer_async()`` frontend, and bounded per-model queues
with reject/block backpressure — the host-side analog of FENIX's
multiplexed pipeline under continuous ingestion.

Requests may carry a ``deadline_ms`` budget: the scheduler sheds a
request whose queue-wait has already burned through its slack instead of
dispatching it (its future fails with
:class:`~repro.launch.scheduler.DeadlineExceededError`; sync ``serve()``
surfaces sheds through :class:`PartialDrainError`), and admission control
refuses doomed requests at submit once a service rate is observed. See
docs/SERVING.md for the operator guide.
"""

from __future__ import annotations

import argparse
import asyncio
import threading
import time
import warnings
from collections import deque
from concurrent.futures import Future

import concurrent.futures
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import ThreadAffinity, make_lock
from repro.configs.registry import ArchConfig, get_config, smoke_config
from repro.models.transformer import (
    decode_step, forward_train, init_decode_state, init_model,
)

from .devices import DeviceStreamPool
from .health import CLOSED, CircuitBreaker
from .mesh import batch_specs, decode_state_specs, named, param_specs
from .request import InferRequest, InferResult
from .scheduler import (
    PRIORITY_WEIGHTS, DeadlineExceededError, QueueFullError, WFQScheduler,
)

__all__ = ["make_serve_step", "make_prefill_step", "Server", "PegasusServer",
           "MultiModelServer", "AsyncMultiModelServer", "PartialDrainError",
           "QueueFullError", "DeadlineExceededError", "PRIORITY_WEIGHTS",
           "InferRequest", "InferResult", "DeviceStreamPool",
           "ServerStoppedError", "PoisonedRequestError", "FALLBACK_BACKEND"]

# The bottom rung of the backend fallback ladder: plain jnp gather — no
# Pallas kernel, no one-hot matmul structure, the least machinery that can
# possibly fail. A model whose preferred-backend path trips its breaker
# keeps serving on a gather plan (degraded) until a probe back on the
# preferred path succeeds.
FALLBACK_BACKEND = "gather"


def _warn_legacy(what: str, instead: str) -> None:
    """One DeprecationWarning per call site (the default filter dedupes by
    location) for the pre-typed call shapes — kept as working shims."""
    warnings.warn(
        f"{what} is deprecated; {instead} (see repro.launch.request)",
        DeprecationWarning, stacklevel=3)


def _as_requests(requests, *, named: bool) -> tuple[list, bool]:
    """Normalize a ``serve()`` argument into ``(list[InferRequest], typed)``.

    Typed calls pass :class:`InferRequest` items through unchanged. Legacy
    items — bare arrays / input tuples when ``named=False``
    (``PegasusServer``), ``(name, inputs[, deadline_ms])`` triples when
    ``named=True`` (``MultiModelServer``) — are wrapped; the caller emits
    the deprecation warning. Mixing the two shapes in one list is a
    ``TypeError`` (the return type would be ambiguous)."""
    items = list(requests)
    if not items:
        return [], True
    n_typed = sum(isinstance(r, InferRequest) for r in items)
    if n_typed == len(items):
        return items, True
    if n_typed:
        raise TypeError(
            "serve() got a mix of InferRequest and legacy-shaped items — "
            "pass one or the other, not both")
    out = []
    for item in items:
        if named:
            name, inputs = item[0], item[1]
            deadline_ms = item[2] if len(item) > 2 else None
            out.append(InferRequest(name, inputs, deadline_ms=deadline_ms))
        else:   # the item IS the inputs (single array or input tuple)
            inputs = tuple(item) if isinstance(item, (tuple, list)) else item
            out.append(InferRequest("", inputs))
    return out, False


class PartialDrainError(RuntimeError):
    """Some requests did not serve — a model failed to drain and/or
    deadline-bearing requests were shed — while the rest completed.

    Raised by :meth:`MultiModelServer.serve` instead of mutating and
    re-raising the underlying exception (the old ``err.partial_results =
    ...`` decoration failed with ``AttributeError`` on slotted/immutable
    exception types and permanently decorated an exception object that may
    be shared or re-raised elsewhere). Carries:

      * ``partial_results`` — ``{name: [outputs]}`` for every model that DID
        serve (that work is computed and counted; only the failed models'
        requests need resubmitting). A failed model that served SOME slices
        before failing appears here too, with its served prefix — its name
        in ``failed`` is what marks it incomplete,
      * ``failed`` — ``{name: exception}`` for every requested model that
        did not,
      * ``shed`` — ``{name: [DeadlineExceededError per shed request]}``
        for requests dropped for a missed deadline (refused at admission
        or shed at pull time). Shed work was never computed — resubmit it
        only if the caller still wants a LATE answer, and
      * ``__cause__`` — the first underlying exception (``raise ... from``).
    """

    def __init__(self, failed: dict, partial_results: dict,
                 shed: dict | None = None):
        self.failed = dict(failed)
        self.partial_results = partial_results
        self.shed = {k: list(v) for k, v in (shed or {}).items()}
        parts = []
        if self.failed:
            names = ", ".join(sorted(self.failed))
            parts.append(f"model(s) {names} failed to drain: "
                         f"{next(iter(self.failed.values()))!r}")
        if self.shed:
            n = sum(len(v) for v in self.shed.values())
            parts.append(f"{n} request(s) shed past their deadline on "
                         f"{', '.join(sorted(self.shed))}")
        super().__init__(
            "; ".join(parts) + " (served models' outputs are in "
            ".partial_results; per-model errors in .failed; shed requests "
            "in .shed)")


class ServerStoppedError(RuntimeError):
    """The server was stopped with this request still queued
    (``AsyncMultiModelServer.stop(drain=False)``) — the request was NOT
    served and will not be; resubmit after ``start()`` if the work is
    still wanted. Typed so waiters can tell an orderly shutdown from a
    dispatch failure."""


class PoisonedRequestError(RuntimeError):
    """A request exhausted its bounded retries (``max_requeues``
    requeue-at-front attempts all failed) — retrying again would loop
    forever, since a permanently-bad request coalesces with every later
    submit to its model. The last underlying dispatch error rides in
    ``__cause__``."""


def _resolve_future(fut: Future | None, *, result=None,
                    error: BaseException | None = None) -> None:
    """Resolve a request future, tolerating a caller-side cancel racing the
    resolution (futures here are never set_running, so ``cancel()`` can win
    between our done() check and set_result — an InvalidStateError leaking
    out of the resolution loop would strand every later future in the
    round)."""
    if fut is None or fut.done():
        return
    try:
        if error is not None:
            fut.set_exception(error)
        else:
            fut.set_result(result)
    except concurrent.futures.InvalidStateError:
        pass    # cancelled mid-resolution: the caller owns that outcome


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, tokens, pos, enc_out=None):
        logits, new_state = decode_step(cfg, params, state, tokens, pos,
                                        enc_out=enc_out)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_state

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = True):
    def prefill_step(params, batch):
        logits, _ = forward_train(cfg, params, batch, last_only=last_only)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


class Server:
    """Minimal batched greedy-decode server (the paper-kind is inference)."""

    def __init__(self, cfg: ArchConfig, mesh, *, kv_len: int = 512,
                 batch_size: int = 8, dtype=jnp.float32):
        self.cfg, self.mesh = cfg, mesh
        params = init_model(cfg, jax.random.PRNGKey(0), dtype=dtype)
        self.param_sh = named(mesh, param_specs(cfg, params, mesh))
        self.params = jax.device_put(params, self.param_sh)
        state = init_decode_state(cfg, batch_size, kv_len, dtype=dtype)
        self.state_sh = named(
            mesh, decode_state_specs(cfg, state, mesh, batch_size=batch_size))
        self.state = jax.device_put(state, self.state_sh)
        self.batch_size = batch_size
        self._step = jax.jit(
            make_serve_step(cfg),
            in_shardings=(self.param_sh, self.state_sh, None, None),
            out_shardings=(None, self.state_sh),
            donate_argnums=(1,),
        )

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy continuation for a batch of single-token prompts."""
        toks = jnp.asarray(prompt_tokens[:, :1], jnp.int32)
        out = [toks]
        for t in range(max_new):
            toks, self.state = self._step(self.params, self.state, toks, jnp.int32(t))
            out.append(toks)
        return np.concatenate([np.asarray(t) for t in out], axis=1)


class PegasusServer:
    """Batched multi-request server over ONE cached ExecutionPlan.

    The plan is compiled once in ``__init__`` (feature one-hots, padded
    LUT/threshold tensors, int8 LUT + scales); every request batch after
    that dispatches the whole-plan JITTED forward — the batch is padded up
    to its compile bucket (powers of two by default), so arbitrary request
    sizes hit a warm XLA executable instead of retracing per shape.
    Requests may be single inputs or tuples (e.g. ``(seq, payload)`` for
    CNN-L); requests are fused into one plan call, chunked along the
    bucket ladder (``repro.engine.bucket_chunks``) so full chunks are exact
    bucket sizes, and the outputs split back out. ``stats()`` reports the
    compile-cache counters (traces vs bucket hits).

    Every request input MUST carry a leading batch dim (wrap a single flow
    as ``x[None]``) — axis 0 is always interpreted as the batch axis.

    Serving counters are incremented ONLY after the plan call succeeds — a
    raising request (bad shape, unknown backend) must not corrupt
    ``requests_served``/``batches_run``.
    """

    def __init__(self, model, *, backend: str = "onehot",
                 interpret: bool | None = None, max_batch: int | None = None,
                 fuse: bool = True):
        from repro.engine import build_plan

        t0 = time.perf_counter()
        self.plan = build_plan(model, backend=backend, interpret=interpret,
                               fuse=fuse)
        self.plan_build_ms = (time.perf_counter() - t0) * 1e3
        self.backend = backend
        # default cap = the top of the plan's bucket ladder (4096), so a
        # coalesced batch that has its own exact bucket is never split
        self.max_batch = (max(self.plan.buckets) if max_batch is None
                          else max_batch)
        self.requests_served = 0
        self.batches_run = 0
        self.flows_served = 0

    def stats(self) -> dict:
        """Unified serving-stats schema (shared across all three servers —
        see docs/SERVING.md): ``serving`` carries the request counters,
        ``engine`` the plan build + compile-cache stats (a bucket_hits to
        traces ratio near 1:1 means the bucket ladder is mis-sized);
        ``scheduler``/``slo`` are empty here (no queueing on this server)
        and ``devices`` reports the plan's device count."""
        ndev = 1 if self.plan.devices is None else len(self.plan.devices)
        return {
            "backend": self.backend,
            "serving": {
                "requests_served": self.requests_served,
                "batches_run": self.batches_run,
                "flows_served": self.flows_served,
                "batches_dispatched": self.batches_run,
            },
            "engine": {
                "plan_build_ms": self.plan_build_ms,
                "num_banks": self.plan.num_banks,
                "table_bytes": self.plan.table_bytes(),
                **self.plan.compile_stats(),
            },
            "scheduler": {},
            "slo": {},
            "devices": {"count": ndev, "per_device": []},
            # schema-uniform with the multi-model servers: one plan, no
            # queue, no breakers — nothing to heal
            "health": {"models": {}, "degraded_models": [],
                       "chaos": {"installed": False}},
        }

    def infer(self, *inputs, backend: str | None = None) -> jax.Array:
        """One already-batched call through the cached plan (one request)."""
        y = self.plan(*inputs, backend=backend)
        self.batches_run += 1            # success-only counting
        self.requests_served += 1
        self.flows_served += int(np.shape(inputs[0])[0])
        return y

    def serve(self, requests, *, backend: str | None = None) -> list:
        """Fuse a list of requests into bucket-aligned batches, split results.

        The typed surface: a list of :class:`InferRequest` returns a list
        of :class:`InferResult` (request order). This server dispatches
        immediately — there is no queue, so ``deadline_ms``/``priority``
        on the requests are accepted but have nothing to act on (use
        ``MultiModelServer`` for scheduled serving). The legacy shape — a
        list of bare arrays / input tuples returning raw ``np.ndarray``
        outputs — still works as a deprecated shim."""
        from repro.engine import bucket_chunks

        reqs, typed = _as_requests(requests, named=False)
        if not reqs:
            return []
        if not typed:
            _warn_legacy("PegasusServer.serve(list of arrays)",
                         "pass a list of InferRequest")
        cat, sizes, total = _coalesce([r.inputs for r in reqs])
        chunks, start = [], 0
        for size in bucket_chunks(total, self.plan.buckets, self.max_batch):
            sl = (cat if size == total
                  else [c[start : start + size] for c in cat])
            chunks.append(self.plan(*sl, backend=backend))
            start += size
        out = jnp.concatenate(chunks, axis=0) if len(chunks) > 1 else chunks[0]
        # commit counters only once EVERY chunk dispatched — a failure on a
        # later chunk must not leave batches_run ahead of requests_served
        self.batches_run += len(chunks)
        self.requests_served += len(sizes)
        self.flows_served += total
        split = _split(out, sizes)
        if not typed:
            return split
        return [InferResult(r.model, o, n)
                for r, o, n in zip(reqs, split, sizes)]


def _coalesce(requests) -> tuple[list, list[int], int]:
    """Normalize a request list (arrays or input tuples, each with a leading
    batch dim) into per-input concatenations + per-request sizes."""
    reqs = [tuple(r) if isinstance(r, (tuple, list)) else (r,) for r in requests]
    sizes = [int(np.shape(r[0])[0]) for r in reqs]
    if len(reqs) == 1:
        cat = [r if isinstance(r, jax.Array) else jnp.asarray(r)
               for r in reqs[0]]
    else:
        cat = [jnp.concatenate([jnp.asarray(r[i]) for r in reqs], axis=0)
               for i in range(len(reqs[0]))]
    return cat, sizes, sum(sizes)


def _split(out: jax.Array, sizes: list[int]) -> list[np.ndarray]:
    """Cut a coalesced output back into per-request numpy arrays."""
    if len(sizes) == 1:
        return [np.asarray(out)]
    return [np.asarray(o)
            for o in jnp.split(out, np.cumsum(sizes)[:-1], axis=0)]


class MultiModelServer:
    """Many heterogeneous models behind ONE server.

    Each model is compiled once and pinned under a name in a
    :class:`repro.engine.PlanRegistry` (per-model backend override allowed).
    Requests address models by name; pending same-model requests are
    coalesced into bucket-aligned micro-batches (``bucket_chunks``: full
    chunks are exact bucket sizes, the tail pads minimally) and models with
    pending work are scheduled by **weighted fair queueing**
    (:class:`~repro.launch.scheduler.WFQScheduler`: deficit round-robin,
    each model's flow share proportional to its priority weight — with
    every model at the default weight this degenerates to the PR-3
    one-chunk-per-model round-robin), so a burst on one model cannot
    starve the others and a high-priority model is served first.

    Two call styles:
      * ``infer(name, *inputs)`` — immediate single-request dispatch.
      * ``submit(name, *inputs)`` + ``drain()`` — enqueue across models,
        then serve everything; ``drain`` returns ``{name: [out_per_request]}``
        in per-model submit order. ``serve(requests)`` wraps submit+drain
        for a mixed ``[(name, inputs), ...]`` list, preserving order.

    Ingestion is thread-safe: the scheduler owns every queue behind one
    lock, so concurrent ``submit``/``add_model`` during a ``drain`` can
    neither corrupt the queue map (the old dict-iteration RuntimeError) nor
    lose requests (the old drain ``clear()``-ed whole queues at commit,
    wiping anything submitted mid-drain — requests are now popped
    individually). Plan dispatch itself stays on the draining thread.

    All counters (``requests_served``/``batches_run``/``flows_served``) are
    per model and committed only when a pulled slice fully serves; a
    failing slice is requeued at the front (retryable, never
    double-counted), its exception lands in ``last_drain_errors``, and
    every other model drains and returns normally. ``schedule_log`` records
    the model name of every dispatched micro-batch — the fairness tests
    assert on it.
    """

    def __init__(self, models: dict | None = None, *, backend: str = "onehot",
                 interpret: bool | None = None, max_batch: int | None = None,
                 registry=None, fuse: bool = True,
                 queue_depth: int | None = None, policy: str = "block",
                 quantum: int | None = None, devices=None,
                 breaker_failures: int = 3, breaker_reset_s: float = 1.0,
                 max_requeues: int = 5, retry_backoff_s: float = 0.02):
        from repro.engine import DEFAULT_BUCKETS, PlanRegistry
        from repro.engine.plan import resolve_devices

        self.registry = PlanRegistry() if registry is None else registry
        # devices: fan dispatch out across N device streams — each pulled
        # chunk is placed on the least-loaded device's executor queue and
        # runs there via per-call placement (plan state replicated per
        # device, see ExecutionPlan.__call__(device=)). None (the default)
        # keeps the single-stream inline dispatch; an EXPLICIT devices=1
        # still gets a one-stream pool so scaling comparisons across K run
        # one code path (the sharding bench gates K=4 against K=1).
        self.devices = resolve_devices(devices)
        self._pool = (DeviceStreamPool(self.devices)
                      if self.devices else None)
        self.backend = backend
        self.interpret = interpret
        self.fuse = fuse    # cross-bank fusion default for add_model plans
        self.max_batch = (max(DEFAULT_BUCKETS) if max_batch is None
                          else max_batch)
        self.queue_depth = queue_depth   # default bound for new model queues
        self.policy = policy             # default backpressure policy
        # DRR credit per round per unit weight, in flows. None → max_batch
        # (a weight-1 model earns ~one full micro-batch per round). Set it
        # SMALLER to ration deep backlogs across more rounds — finer-grained
        # priority differentiation at slightly more scheduling overhead.
        self.quantum = quantum
        self._sched = WFQScheduler()
        # counter commits are read-modify-writes shared between the drain
        # thread and infer() callers — same race the plan-level counters
        # guard with _PlanCounters.lock
        self._ctr_lock = make_lock("serve._ctr_lock")
        self._counters: dict[str, dict] = {}        # guarded-by: _ctr_lock
        # bounded: the log is a debugging/fairness-test surface, not an
        # audit trail — a long-lived server must not grow it without limit.
        # Deliberately NOT guarded-by-annotated: deque.append is atomic
        # under the GIL and readers tolerate a stale tail.
        self.schedule_log: deque = deque(maxlen=4096)
        self.batches_dispatched = 0                 # guarded-by: _ctr_lock
        # bound by the async drain loop (never for the caller-driven sync
        # server): once bound, all dispatch must happen on that thread
        self._dispatch_affinity = ThreadAffinity("dispatch")
        self.last_drain_errors: dict[str, Exception] = {}
        self.last_shed: dict[str, int] = {}   # sheds seen by the last drain
        # -- self-healing (docs/RELIABILITY.md) -----------------------------
        # Per-model breakers guard the PREFERRED backend path: after
        # breaker_failures consecutive slice failures the model serves
        # DEGRADED on the gather fallback until a cooldown probe back on
        # the preferred path succeeds. max_requeues bounds the deadline-
        # aware retry (requeue-at-front) so a poison-pill request fails
        # typed PoisonedRequestError instead of looping forever.
        self.breaker_failures = int(breaker_failures)    # immutable config
        self.breaker_reset_s = float(breaker_reset_s)    # immutable config
        self.max_requeues = int(max_requeues)            # immutable config
        self.retry_backoff_s = float(retry_backoff_s)    # immutable config
        self._breakers: dict[str, CircuitBreaker] = {}  # guarded-by: _ctr_lock
        self._health_ctrs: dict[str, dict] = {}         # guarded-by: _ctr_lock
        # retry pacing, touched ONLY by the dispatch thread (the sync
        # drain caller or the async loop — the same single-dispatcher
        # exclusivity _dispatch_affinity pins), so deliberately unguarded
        # like schedule_log
        self._retry_streak: dict[str, int] = {}
        self._retry_not_before: dict[str, float] = {}
        # fault-injection hook — None until install_chaos(); the hot path
        # pays one is-None check per dispatched slice (repro.launch.chaos)
        self._chaos = None
        for name in self.registry.names():   # adopt a pre-populated registry
            self._track(name)
        for name, model in dict(models or {}).items():
            self.add_model(name, model)

    def _track(self, name: str, **sched_kw) -> None:
        """Queue + counters for a registry name this server serves. Names
        registered on a shared registry after construction are adopted
        lazily on first submit/infer. Server-wide depth/policy defaults
        apply only at queue CREATION — an existing queue keeps its config
        unless the caller passed explicit overrides."""
        if name not in self._sched:
            sched_kw.setdefault("depth", self.queue_depth)
            sched_kw.setdefault("policy", self.policy)
        self._sched.add_queue(name, **sched_kw)
        with self._ctr_lock:
            self._counters.setdefault(name, {"requests_served": 0,
                                             "batches_run": 0,
                                             "flows_served": 0})
            if name not in self._breakers:
                self._breakers[name] = CircuitBreaker(
                    name, failure_threshold=self.breaker_failures,
                    reset_timeout_s=self.breaker_reset_s)
            self._health_ctrs.setdefault(name, {"fallback_batches": 0,
                                                "probe_batches": 0,
                                                "retries": 0,
                                                "poisoned": 0,
                                                "deadline_dropped": 0})

    def _breaker(self, name: str) -> CircuitBreaker | None:
        with self._ctr_lock:
            return self._breakers.get(name)

    def _tracked(self, name: str) -> None:
        with self._ctr_lock:
            known = name in self._counters
        if not known:
            if name not in self.registry:
                raise KeyError(
                    f"unknown model {name!r}; registered: {self.models()}")
            self._track(name)

    def _quantum(self) -> int:
        """DRR credit per round per unit weight, in flows — by default the
        effective micro-batch ceiling, so a weight-1 model earns about one
        full micro-batch per round and a weight-4 model earns four."""
        return max(1, int(self.max_batch if self.quantum is None
                          else self.quantum))

    # -- model management ---------------------------------------------------

    def add_model(self, name: str, model, *, backend: str | None = None,
                  priority: str | None = None, weight: float | None = None,
                  queue_depth: int | None = None, policy: str | None = None,
                  **build_kw):
        """Compile + register one model; returns its ExecutionPlan.

        Args:
            name: the serving handle requests address; re-registering an
                existing name rebuilds its plan and re-applies any
                explicit scheduling fields below.
            model: the Pegasus bank structure to compile (whatever
                ``repro.engine.build_plan`` accepts).
            backend: engine backend for this plan (``gather | onehot |
                kernel | kernel_q8``); ``None`` uses the server default.
            priority: a class in :data:`PRIORITY_WEIGHTS` (``"high"`` = 4x
                the flow share of ``"normal"``; ``"low"`` = 0.25x).
            weight: explicit WFQ weight (flows-per-round multiplier);
                overrides ``priority``. Must be > 0.
            queue_depth: max queued requests for this model (``None`` =
                server default; unbounded if that is also ``None``).
            policy: backpressure when the bounded queue is full —
                ``"reject"`` raises :class:`QueueFullError` at submit,
                ``"block"`` parks the submitter until space frees.
            **build_kw: forwarded to ``build_plan`` (``fuse``,
                ``bucket_sizes``, ``block_t``, ... — see its docstring).

        Raises:
            ValueError: unknown ``priority``/``policy``, or
                non-positive ``weight``/``queue_depth``.
        """
        build_kw.setdefault("fuse", self.fuse)
        plan = self.registry.register(
            name, model, backend=backend or self.backend,
            interpret=self.interpret, **build_kw)
        sched_kw: dict = {"priority": priority, "weight": weight}
        if queue_depth is not None:
            sched_kw["depth"] = queue_depth
        if policy is not None:
            sched_kw["policy"] = policy
        self._track(name, **sched_kw)   # explicit fields apply on re-register
        return plan

    def set_priority(self, name: str, *, priority: str | None = None,
                     weight: float | None = None) -> float:
        """Re-class a served model's WFQ weight (effective next round)."""
        self._tracked(name)
        return self._sched.set_weight(name, weight=weight, priority=priority)

    def remove_model(self, name: str) -> bool:
        """Evict a model; its pending queue is dropped with it (queued
        futures, if any, fail with KeyError)."""
        dropped = self._sched.remove_queue(name)
        err = KeyError(f"model {name!r} removed with requests pending")
        for r in dropped:
            _resolve_future(r.future, error=err)
        with self._ctr_lock:
            self._counters.pop(name, None)
            self._breakers.pop(name, None)
            self._health_ctrs.pop(name, None)
        return self.registry.evict(name)

    def models(self) -> list[str]:
        return self.registry.names()

    def install_chaos(self, injector) -> None:
        """Wire a :class:`repro.launch.chaos.FaultInjector` into every
        dispatch edge this server owns — its own plan-call edge, the
        registry's plan-build edge, and the device pool's stream-dispatch
        edge — in one call. Explicit hooks, never monkey-patching; with no
        injector installed every edge costs one ``is None`` check."""
        self._chaos = injector
        self.registry.chaos = injector
        if self._pool is not None:
            self._pool.chaos = injector

    def uninstall_chaos(self) -> None:
        """Detach the injector from every hook :meth:`install_chaos` set."""
        self._chaos = None
        self.registry.chaos = None
        if self._pool is not None:
            self._pool.chaos = None

    # -- request paths ------------------------------------------------------

    def infer(self, request, *legacy_inputs, backend: str | None = None):
        """Immediate single-request dispatch through the named plan — no
        queueing, no coalescing, no deadline (the request runs NOW on the
        calling thread; a typed request's ``deadline_ms``/``priority``
        have no queue to act on). ``backend`` optionally overrides the
        plan's compiled backend for this call.

        The typed surface takes one :class:`InferRequest` and returns an
        :class:`InferResult`; the legacy ``infer(name, *inputs)`` shape
        (raw output, deprecated) still works. Raises ``KeyError`` for an
        unknown name; plan errors (bad shape, unknown backend) propagate
        without touching the counters."""
        if isinstance(request, InferRequest):
            if legacy_inputs:
                raise TypeError(
                    "infer(InferRequest) takes no extra positional inputs "
                    "— they ride in request.inputs")
            name, inputs = request.model, request.inputs
        else:
            _warn_legacy("MultiModelServer.infer(name, *inputs)",
                         "pass an InferRequest")
            name, inputs = request, legacy_inputs
        self._tracked(name)
        y = self.registry.get(name)(*inputs, backend=backend)
        flows = int(np.shape(inputs[0])[0])
        with self._ctr_lock:
            c = self._counters[name]
            c["requests_served"] += 1    # success-only counting
            c["batches_run"] += 1
            c["flows_served"] += flows
        if isinstance(request, InferRequest):
            return InferResult(name, y, flows)
        return y

    def _enqueue(self, name: str, inputs: tuple, future: Future | None,
                 timeout: float | None,
                 deadline_ms: float | None = None,
                 priority: str = "normal") -> int:
        self._tracked(name)
        inputs = tuple(x if isinstance(x, jax.Array) else jnp.asarray(x)
                       for x in inputs)
        return self._sched.submit(name, inputs, int(np.shape(inputs[0])[0]),
                                  future=future, timeout=timeout,
                                  deadline_ms=deadline_ms, priority=priority)

    def submit(self, request, *legacy_inputs, timeout: float | None = None,
               deadline_ms: float | None = None) -> int:
        """Enqueue one :class:`InferRequest` for the next :meth:`drain`.

        Args:
            request: the typed request — model name, input arrays (each
                with a LEADING BATCH DIM; wrap a single flow as
                ``x[None]``; multi-input models like CNN-L pass an input
                tuple), optional ``deadline_ms`` budget, and per-request
                ``priority`` (queue-jump within this model's queue — see
                :data:`~repro.launch.scheduler.PRIORITY_RANK`). The legacy
                ``submit(name, *inputs, deadline_ms=...)`` shape still
                works as a deprecated shim.
            timeout: seconds to wait for queue space when the model queue
                is bounded with ``policy="block"``; ``None`` waits forever.
                Expiry raises :class:`QueueFullError`.
            deadline_ms: legacy-shape only (typed requests carry their own
                ``deadline_ms``).

        Returns:
            The request's queue position at insert time (0-based).

        Raises:
            KeyError: unknown model name.
            QueueFullError: bounded queue full (``policy="reject"``, or
                ``block`` timed out) — also raised at admission when the
                queue's ``admit_ms`` horizon is exceeded.
            DeadlineExceededError: admission control predicts the deadline
                cannot be met given the observed service rate (the
                scheduler may also shed the queued request later at pull
                time, failing its future with the same error).
            ValueError: non-positive ``deadline_ms``.
        """
        if isinstance(request, InferRequest):
            if legacy_inputs or deadline_ms is not None:
                raise TypeError(
                    "submit(InferRequest) takes no extra inputs or "
                    "deadline_ms — they ride in the request")
            return self._enqueue(request.model, request.inputs, None,
                                 timeout, deadline_ms=request.deadline_ms,
                                 priority=request.priority)
        _warn_legacy("MultiModelServer.submit(name, *inputs)",
                     "pass an InferRequest")
        return self._enqueue(request, legacy_inputs, None, timeout,
                             deadline_ms=deadline_ms)

    def pending(self) -> dict[str, int]:
        return self._sched.pending()

    def discard_pending(self, name: str) -> int:
        """Drop a model's queued requests (returns how many). The escape
        hatch for a poisoned queue: a permanently-bad request is coalesced
        with every later submit to its model, so retries would fail
        forever until the queue is cleared. Dropped futures are cancelled
        (or failed, if already running)."""
        dropped = self._sched.discard(name)
        err = RuntimeError(f"request discarded from {name!r}'s queue")
        for r in dropped:
            if r.future is not None and not r.future.done():
                if not r.future.cancel():
                    r.future.set_exception(err)
        return len(dropped)

    # -- dispatch -----------------------------------------------------------

    def _begin_group(self, name: str, reqs: list, backend: str | None) -> dict:
        """Phase 1 of serving one pulled slice: coalesce → bucket_chunks
        micro-batches → plan calls. JAX dispatch is asynchronous, so this
        returns as soon as every chunk is ENQUEUED on the device — the
        caller begins every group in the round before finishing any, which
        keeps the device pipeline full across models (blocking on model A's
        results before dispatching model B serialized the round and cost
        ~2x aggregate throughput). With a multi-device pool, each chunk is
        instead handed to the LEAST-LOADED device stream (fewest pending
        flows, ties → lowest device index) and ``outs`` holds the pool
        futures. Returns a group record; a dispatch failure rides in its
        ``"error"`` key."""
        from repro.engine import bucket_chunks

        # sanitizer checkpoint: once the async loop binds the dispatch
        # affinity, ANY other thread reaching this dispatch edge is the
        # "two concurrent dispatchers" bug (unbound → free, sync path)
        self._dispatch_affinity.assert_here()
        t0 = time.perf_counter()
        # queue-wait ends HERE, not at pull time: a round's groups dispatch
        # sequentially, so later (lower-priority) groups keep waiting while
        # earlier ones run — the stamp must capture that ordering effect
        for r in reqs:
            r.t_dispatch = t0
        # "managed" = no explicit caller backend override: only managed
        # groups ride the fallback ladder and feed the model's breaker (an
        # explicit per-drain backend is the caller experimenting, not the
        # serving path the breaker guards)
        g: dict = {"name": name, "reqs": reqs, "t0": t0, "degraded": False,
                   "probe": False, "managed": backend is None}
        try:
            br = self._breaker(name) if g["managed"] else None
            if br is not None and br.state != CLOSED:
                # fallback ladder: the preferred-path breaker is tripped.
                # A granted cooldown probe retries the preferred backend
                # (success auto-reinstates); otherwise this slice serves
                # DEGRADED on the gather fallback plan — same model, same
                # tables, least-machinery backend.
                if br.allow():
                    g["probe"] = True
                else:
                    g["degraded"] = True
            if self._chaos is not None:
                self._chaos.fire(
                    "plan_call", model=name,
                    backend=(FALLBACK_BACKEND if g["degraded"] else
                             backend or self.registry.backend_of(name)))
            if g["degraded"]:
                plan = self.registry.get_with_backend(name, FALLBACK_BACKEND)
            else:
                plan = self.registry.get(name)
            if g["degraded"] or g["probe"]:
                with self._ctr_lock:
                    h = self._health_ctrs.get(name)
                    if h is not None:
                        key = ("fallback_batches" if g["degraded"]
                               else "probe_batches")
                        h[key] += 1
            cat, sizes, total = _coalesce([r.inputs for r in reqs])
            chunks = bucket_chunks(total, plan.buckets, self.max_batch)
            outs, start = [], 0
            for size in chunks:
                sl = (cat if start == 0 and size == total
                      else [c[start : start + size] for c in cat])
                if self._pool is None:
                    outs.append(plan(*sl, backend=backend))
                else:
                    # the chunk runs on whichever stream has the least
                    # pending work; np conversion happens ON that worker so
                    # the block is off this thread too. assert_worker is
                    # the sanitizer's thread-affinity pin for "ALL plan
                    # calls run on pool workers" (no-op unless enabled).
                    outs.append(self._pool.submit(
                        lambda d, plan=plan, sl=tuple(sl): (
                            self._pool.assert_worker(),
                            np.asarray(plan(*sl, backend=backend,
                                            device=d)))[1],
                        size))
                self.schedule_log.append(name)
                with self._ctr_lock:
                    self.batches_dispatched += 1
                start += size
        except Exception as e:
            g["error"] = e
            return g
        g.update(outs=outs, sizes=sizes, total=total, batches=len(chunks),
                 t_begun=time.perf_counter())
        return g

    def _finish_group(self, g: dict):
        """Phase 2: block on the group's device results, split per request,
        commit counters, record latency, resolve futures. On failure the
        model's breaker records it (preferred path only) and the slice goes
        through deadline-aware bounded retry — requeue-at-front, capped by
        ``max_requeues``, never past a request's own deadline (see
        :meth:`_retry_or_fail`). Returns the per-request np outputs, or
        None on failure."""
        name, reqs = g["name"], g["reqs"]
        err = g.get("error")
        if err is None:
            t_finish = time.perf_counter()
            try:
                if self._pool is not None:
                    # pool mode: outs are futures of per-chunk NP arrays on
                    # DIFFERENT devices — concatenate on the host (jnp
                    # would refuse to mix committed devices)
                    arrs = [f.result() for f in g["outs"]]
                    out = (np.concatenate(arrs, axis=0)
                           if len(arrs) > 1 else arrs[0])
                    split = ([out] if len(g["sizes"]) == 1 else
                             np.split(out, np.cumsum(g["sizes"])[:-1],
                                      axis=0))
                else:
                    out = (jnp.concatenate(g["outs"], axis=0)
                           if len(g["outs"]) > 1 else g["outs"][0])
                    split = _split(out, g["sizes"])  # np conversion: sync
            except Exception as e:
                err = e
        # the breaker sees only the PREFERRED path: a degraded (fallback)
        # slice neither extends nor resets the preferred path's streak
        br = (self._breaker(name)
              if g.get("managed", True) and not g.get("degraded") else None)
        if err is not None:
            self.last_drain_errors[name] = err
            if br is not None:
                br.record_failure()
            self._retry_or_fail(name, reqs, err, probe=g.get("probe", False))
            return None
        if br is not None:
            br.record_success()      # probe success auto-reinstates
        self._retry_streak.pop(name, None)
        self._retry_not_before.pop(name, None)
        # service = this group's own dispatch phase + its own blocking
        # finish — NOT wall time since begin, which would fold every
        # earlier group's host conversion into later (lower-priority)
        # groups' service percentiles. Still approximate under concurrent
        # device work, but free of that systematic ordering bias.
        service_ms = ((g["t_begun"] - g["t0"])
                      + (time.perf_counter() - t_finish)) * 1e3
        self._sched.record_service(name, reqs, service_ms)
        with self._ctr_lock:
            # .get: the model may have been remove_model'd while this slice
            # was in flight — its results still resolve, only the counters
            # have nowhere to go (a KeyError here would strand the futures)
            c = self._counters.get(name)
            if c is not None:
                c["requests_served"] += len(reqs)
                c["batches_run"] += g["batches"]
                c["flows_served"] += g["total"]
        for r, o in zip(reqs, split):
            if r.future is not None:
                # observed submit→dispatch wait, for InferResult
                # telemetry: the typed paths read it off the settled future
                r.future.queue_wait_ms = (r.t_dispatch - r.t_submit) * 1e3
            _resolve_future(r.future, result=o)
        return split

    def _retry_or_fail(self, name: str, reqs: list, err: Exception, *,
                       probe: bool = False) -> None:
        """Failure triage for one slice — deadline-aware bounded retry.

        Per request: a deadline already burned through fails NOW with the
        dispatch error (never retry past a request's own ``deadline_ms``);
        a request at ``max_requeues`` fails typed
        :class:`PoisonedRequestError` (the dispatch error in
        ``__cause__``); everything else is requeued at the FRONT (retry
        order preserved) with its requeue count bumped. A failed breaker
        PROBE requeues without charging the count — the probe was the
        server's experiment, not the request's fault. Consecutive failed
        slices back off exponentially (``retry_backoff_s`` doubling, capped
        at 1 s): the async loop excludes the model until the pause expires,
        the sync drain's per-call exclusion makes pacing moot."""
        now = time.perf_counter()
        survivors: list = []
        n_deadline = n_poison = 0
        for r in reqs:
            if (r.deadline_ms is not None
                    and (now - r.t_submit) * 1e3 >= r.deadline_ms):
                _resolve_future(r.future, error=err)
                n_deadline += 1
            elif not probe and r.requeues >= self.max_requeues:
                perr = PoisonedRequestError(
                    f"request for {name!r} failed {r.requeues + 1} times "
                    f"(max_requeues={self.max_requeues}); giving up — "
                    "discard or fix the request")
                perr.__cause__ = err
                _resolve_future(r.future, error=perr)
                n_poison += 1
            else:
                if not probe:
                    r.requeues += 1
                survivors.append(r)
        if survivors:
            self._sched.requeue_front(name, survivors)
            streak = self._retry_streak.get(name, 0)
            self._retry_not_before[name] = now + min(
                self.retry_backoff_s * (2 ** streak), 1.0)
            self._retry_streak[name] = streak + 1
        with self._ctr_lock:
            h = self._health_ctrs.get(name)
            if h is not None:
                h["retries"] += len(survivors)
                h["poisoned"] += n_poison
                h["deadline_dropped"] += n_deadline

    def drain(self, *, backend: str | None = None) -> dict:
        """Serve every queued request: the WFQ scheduler releases per-model
        slices (deficit round-robin: ``quantum x weight`` flows of credit
        per round, descending-weight dispatch order), each slice coalesces
        and cuts into bucket-aligned micro-batches. Returns
        ``{name: [np.ndarray per request, in submit order]}``.

        Failures are isolated per model: a slice whose dispatch raises is
        requeued at the front (retryable) with ALL its counters untouched
        (they only commit when a slice fully serves — a retry never
        double-counts partially-run chunks), the model is excluded for the
        rest of this drain, and every other model drains normally. The
        per-model exceptions land in ``last_drain_errors``; drain raises
        only if NO model succeeded. The retry is BOUNDED: a request that
        fails ``max_requeues`` requeues fails typed
        :class:`PoisonedRequestError` instead of looping forever (or clear
        the queue sooner with ``discard_pending``), and a request whose own
        ``deadline_ms`` has burned through is never retried at all.

        Deadline-bearing requests whose slack ran out while queued are
        SHED by the scheduler (dropped, future failed with
        :class:`DeadlineExceededError`) and do not appear in the returned
        lists; ``last_shed`` records ``{name: count}`` for this drain."""
        self.last_drain_errors = {}
        results: dict = {}
        failed: set = set()
        quantum = self._quantum()
        while True:
            groups = self._sched.pull_round(quantum, exclude=failed)
            if not groups:
                break
            # two phases: dispatch EVERY group, then block on each — the
            # device works across models while the host splits/converts
            begun = [self._begin_group(name, reqs, backend)
                     for name, reqs in groups]
            for g in begun:
                outs = self._finish_group(g)
                if outs is None:
                    failed.add(g["name"])  # skip for the rest of this drain
                else:
                    results.setdefault(g["name"], []).extend(outs)
        self.last_shed = {name: len(reqs)
                          for name, reqs in self._sched.take_shed().items()}
        if self.last_drain_errors and not results:
            raise next(iter(self.last_drain_errors.values()))
        return results

    def serve(self, requests, *, backend: str | None = None) -> list:
        """Mixed-model convenience: submit everything, drain, return
        results aligned to the request order.

        Args:
            requests: a list of :class:`InferRequest` (the typed surface —
                per-request ``deadline_ms`` and ``priority`` honored,
                returns :class:`InferResult` per request). The legacy
                shape — ``(name, inputs)`` / ``(name, inputs,
                deadline_ms)`` tuples returning raw outputs — still works
                as a deprecated shim.
            backend: per-drain engine backend override (sync drain only).

        Returns:
            One result per request, in request order — only when EVERY
            request served.

        Raises:
            PartialDrainError: any requested model failed to drain and/or
                any deadline-bearing request was shed. Served outputs ride
                in ``.partial_results`` (``{name: [outputs]}`` — that work
                is computed and counted), drain failures in ``.failed``,
                and shed requests in ``.shed``
                (``{name: [DeadlineExceededError]}``); shed work was never
                computed and only the failed/shed requests need
                resubmitting.
        """
        reqs, typed = _as_requests(requests, named=True)
        if not typed:
            _warn_legacy("MultiModelServer.serve(list of (name, inputs) "
                         "tuples)", "pass a list of InferRequest")
        order: list[tuple[InferRequest, Future]] = []
        for req in reqs:
            # a private future per request keeps served/shed alignment
            # robust: drain()'s per-model lists exclude shed requests, so
            # the old positional indexing into them would mis-align
            fut: Future = Future()
            try:
                self._enqueue(req.model, req.inputs, fut, None,
                              deadline_ms=req.deadline_ms,
                              priority=req.priority)
            except DeadlineExceededError as e:
                _resolve_future(fut, error=e)   # admission refusal == shed
            order.append((req, fut))
        by_model = self.drain(backend=backend)
        # a name in last_drain_errors did NOT fully serve — including a
        # model whose earlier slice landed in by_model before a later slice
        # failed (drain excludes it from then on), so membership in
        # by_model alone must not count as success
        failed = {name: self.last_drain_errors[name]
                  for name in dict.fromkeys(r.model for r, _ in order)
                  if name in self.last_drain_errors}
        shed: dict[str, list] = {}
        for req, fut in order:
            if fut.done():
                exc = fut.exception()
                if isinstance(exc, DeadlineExceededError):
                    shed.setdefault(req.model, []).append(exc)
        if failed or shed:
            cause = (next(iter(failed.values())) if failed
                     else next(iter(shed.values()))[0])
            raise PartialDrainError(failed, by_model, shed=shed) from cause
        if not typed:
            return [fut.result() for _, fut in order]
        return [InferResult(req.model, fut.result(), req.flows,
                            queue_wait_ms=getattr(fut, "queue_wait_ms", None))
                for req, fut in order]

    def close(self) -> None:
        """Release the per-device executor threads (multi-device servers
        only; a no-op otherwise). Queued device work finishes first."""
        if self._pool is not None:
            self._pool.close()

    def stats(self) -> dict:
        """The unified serving-stats schema (shared with ``PegasusServer``
        and ``AsyncMultiModelServer`` — field-by-field reference in
        docs/SERVING.md): ``serving`` carries the per-model + aggregate
        request counters, ``engine`` the registry cache plus per-model
        plan build/compile-cache stats, ``scheduler`` the queue config and
        latency percentiles, ``slo`` the per-model SLO counters
        (admission/shed/goodput/starvation), ``devices`` the per-device
        stream utilization/depth (multi-device servers), and ``health``
        the self-healing state — per-model breaker + fallback/retry
        counters, ``degraded_models``, and the installed chaos injector
        (docs/RELIABILITY.md)."""
        reg = self.registry.stats()
        zeros = {"requests_served": 0, "batches_run": 0, "flows_served": 0}
        # registry names BEFORE taking the counter lock: models() acquires
        # registry._lock (rank 0), outermost in the declared hierarchy —
        # nesting it under _ctr_lock (rank 2) is the inversion the runtime
        # sanitizer flagged on first enablement
        names = self.models()
        with self._ctr_lock:
            # zeroed defaults keep the schema uniform for names on a
            # shared registry that this server hasn't served yet; the
            # dispatch total snapshots in the SAME critical section so one
            # stats() call is internally consistent under a live drain
            per_model = {name: {**zeros, **self._counters.get(name, {})}
                         for name in names}
            batches_dispatched = self.batches_dispatched
            breakers = dict(self._breakers)
            hctrs = {n: dict(c) for n, c in self._health_ctrs.items()}
        # breaker snapshots AFTER releasing _ctr_lock: each stats() call
        # takes health._lock (rank 6 — legal under rank 2, but there is no
        # reason to hold the counter lock across N of them)
        health_models: dict = {}
        degraded_models: list = []
        for n in names:
            br = breakers.get(n)
            if br is None:
                continue
            bst = br.stats()
            is_degraded = bst["state"] != CLOSED
            if is_degraded:
                degraded_models.append(n)
            health_models[n] = {
                **bst, **hctrs.get(n, {}),
                "degraded": is_degraded,
                "preferred_backend": reg.get(n, {}).get("backend"),
                "fallback_backend": FALLBACK_BACKEND,
            }
        return {
            "backend": self.backend,
            "serving": {
                "requests_served": sum(m["requests_served"]
                                       for m in per_model.values()),
                "batches_run": sum(m["batches_run"]
                                   for m in per_model.values()),
                "flows_served": sum(m["flows_served"]
                                    for m in per_model.values()),
                "batches_dispatched": batches_dispatched,
                "models": per_model,
            },
            "engine": {
                "cache": self.registry.cache_info(),
                "models": reg,
            },
            "scheduler": {
                "models": self._sched.describe(),
                "latency": self._sched.latency_stats(),
            },
            "slo": {"models": self._sched.counters()},
            "devices": (self._pool.stats() if self._pool is not None
                        else {"count": 1, "per_device": []}),
            "health": {
                "models": health_models,
                "degraded_models": sorted(degraded_models),
                "chaos": (self._chaos.stats() if self._chaos is not None
                          else {"installed": False}),
            },
        }

    def slo_counters(self) -> dict:
        """The scheduler's per-model SLO counters alone (cheaper than full
        :meth:`stats`; see :meth:`WFQScheduler.counters` for the fields).
        The overload benchmark diffs these across phases."""
        return self._sched.counters()

    def reset_slo_counters(self) -> None:
        """Zero the SLO counters (benchmarks reset between load phases)."""
        self._sched.reset_counters()

    def reset_latency_stats(self) -> None:
        """Drop the latency reservoirs (benchmarks reset after warmup)."""
        self._sched.reset_latency()


class AsyncMultiModelServer(MultiModelServer):
    """The always-on :class:`MultiModelServer`: a background drain thread
    plus future-returning ``submit()``.

    ``submit(name, *inputs)`` is safe from any thread and returns a
    :class:`concurrent.futures.Future` resolving to the request's np output
    (or raising the dispatch error — async requests are NOT requeued on
    failure; the future carries the exception and the caller decides).
    Queues are bounded (``queue_depth``, default 1024 requests/model) with
    ``policy`` backpressure: ``"block"`` parks the submitter until the
    drain loop frees space (bounding producer speed to consumer speed),
    ``"reject"`` raises :class:`QueueFullError` immediately (shed load at
    ingestion, dataplane-style).

    The drain loop pulls one WFQ round at a time (so ``stop()`` stays
    responsive and priorities re-evaluate between rounds) and funnels every
    compiled-plan call through its single thread; ingestion touches the
    scheduler lock plus one ``device_put`` per input (inputs are staged to
    the device at submit time, on the producer's thread). Use as a context manager, or ``start()``/``stop()``:

        with AsyncMultiModelServer({"ids": banks}, queue_depth=256) as srv:
            futs = [srv.submit("ids", x) for x in bursts]
            outs = [f.result() for f in futs]

    ``stop(drain=True)`` (the default, and what ``__exit__`` calls) first
    waits for the queues to empty, then joins the loop — pending futures
    all resolve before stop returns.
    """

    def __init__(self, models: dict | None = None, *,
                 queue_depth: int | None = 1024, policy: str = "block",
                 idle_wait: float = 0.05, **kw):
        super().__init__(models, queue_depth=queue_depth, policy=policy, **kw)
        self._idle_wait = idle_wait
        self._stop_flag = threading.Event()
        self._thread: threading.Thread | None = None
        self.loop_errors: deque = deque(maxlen=64)   # unexpected loop crashes

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "AsyncMultiModelServer":
        """Spawn the background drain loop and return ``self`` (idempotent
        — a live loop is left alone; after ``stop()`` a fresh thread is
        spawned). Until start, submitted futures sit queued and never
        resolve; ``serve()``/``infer_async()`` refuse to run with the loop
        down rather than hang."""
        if self._thread is None or not self._thread.is_alive():
            self._stop_flag.clear()
            self._thread = threading.Thread(
                target=self._serve_loop, name="pegasus-drain", daemon=True)
            self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float | None = None) -> None:
        """Stop the loop (what ``__exit__`` calls, with the defaults).

        Args:
            drain: wait for every queue to empty first, so in-flight
                futures all resolve before return; ``False`` halts after
                the current round and FAILS every still-pending future
                with :class:`ServerStoppedError` — a waiter blocked on
                ``future.result()`` unblocks instead of hanging forever
                (the old contract left them queued and unresolved).
            timeout: overall budget in SECONDS for drain-wait + join;
                ``None`` waits indefinitely. On expiry the loop may still
                be alive (``running`` stays true) and a later ``stop()``
                can finish the job — the thread is never abandoned while
                alive, which would let ``start()`` spawn a second
                concurrent dispatcher.
        """
        if self._thread is None:
            if not drain:
                # never started (or already stopped): the drain=False
                # contract still holds — no future may stay pending
                self._fail_pending_stopped()
            return
        deadline = None if timeout is None else time.monotonic() + timeout
        if drain:
            while self.pending() and self._thread.is_alive():
                if deadline is not None and time.monotonic() > deadline:
                    break
                time.sleep(0.002)
        self._stop_flag.set()
        self._sched.kick()
        self._thread.join(None if deadline is None
                          else max(0.0, deadline - time.monotonic()))
        # forget the thread only once it actually exited: after a timed-out
        # join the loop is still live, and untracking it would let start()
        # clear the stop flag and spawn a SECOND concurrent dispatcher
        if not self._thread.is_alive():
            self._thread = None
            if drain and self.pending():
                # a submit raced the stop flag (landed after the pending()
                # check, unseen by the exiting loop): honor the drain
                # contract by serving the stragglers inline, and fail any
                # future a failing slice would otherwise strand
                try:
                    self.drain()
                except Exception:
                    pass                        # recorded per model below
                for name in list(self.pending()):
                    err = self.last_drain_errors.get(name) or RuntimeError(
                        f"server stopped with {name!r} requests pending")
                    for r in self._sched.discard(name):
                        _resolve_future(r.future, error=err)
            elif not drain:
                self._fail_pending_stopped()

    def _fail_pending_stopped(self) -> None:
        """``stop(drain=False)``: discard every queued request and fail its
        future with typed :class:`ServerStoppedError`, so no waiter is
        left blocked on a future nothing will ever resolve."""
        for name in list(self.pending()):
            err = ServerStoppedError(
                f"server stopped (drain=False) with {name!r} requests "
                "pending — the request was not served; resubmit after "
                "start() if still wanted")
            for r in self._sched.discard(name):
                _resolve_future(r.future, error=err)

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "AsyncMultiModelServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    # -- ingestion ----------------------------------------------------------

    def _typed_future(self, req: InferRequest, raw: Future) -> Future:
        """Wrap a raw-output future into one resolving to
        :class:`InferResult` (errors/cancellation pass through)."""
        out: Future = Future()

        def _done(f: Future) -> None:
            if f.cancelled():
                out.cancel()
                return
            exc = f.exception()
            if exc is not None:
                _resolve_future(out, error=exc)
            else:
                _resolve_future(out, result=InferResult(
                    req.model, f.result(), req.flows,
                    queue_wait_ms=getattr(f, "queue_wait_ms", None)))

        raw.add_done_callback(_done)
        return out

    def submit(self, request, *legacy_inputs, timeout: float | None = None,
               deadline_ms: float | None = None) -> Future:
        """Thread-safe enqueue of one :class:`InferRequest`; returns a
        :class:`concurrent.futures.Future` of its :class:`InferResult`
        (the legacy ``submit(name, *inputs, deadline_ms=...)`` shape still
        works as a deprecated shim whose future resolves to the raw np
        output, as before). Parameters and failure modes as
        :meth:`MultiModelServer.submit` (``timeout`` in seconds for
        ``block`` backpressure), with one difference in how deadline
        misses surface: a shed or admission-refused request FAILS THE
        RETURNED FUTURE with :class:`DeadlineExceededError` instead of
        raising here (uniform handling at ``future.result()`` whether the
        miss was predicted at submit or happened in the queue). Dispatch
        errors also ride on the future — async requests are never
        requeued."""
        fut: Future = Future()
        if isinstance(request, InferRequest):
            if legacy_inputs or deadline_ms is not None:
                raise TypeError(
                    "submit(InferRequest) takes no extra inputs or "
                    "deadline_ms — they ride in the request")
            try:
                self._enqueue(request.model, request.inputs, fut, timeout,
                              deadline_ms=request.deadline_ms,
                              priority=request.priority)
            except DeadlineExceededError as e:
                _resolve_future(fut, error=e)
            return self._typed_future(request, fut)
        _warn_legacy("AsyncMultiModelServer.submit(name, *inputs)",
                     "pass an InferRequest")
        try:
            self._enqueue(request, legacy_inputs, fut, timeout,
                          deadline_ms=deadline_ms)
        except DeadlineExceededError as e:
            _resolve_future(fut, error=e)
        return fut

    async def infer_async(self, request, *legacy_inputs,
                          timeout: float | None = None,
                          deadline_ms: float | None = None):
        """asyncio-native single request: ``await`` the
        :class:`InferResult` for one :class:`InferRequest` from a running
        event loop without blocking it (the legacy ``infer_async(name,
        *inputs)`` shape awaits the raw output, deprecated).

        The enqueue itself runs in a worker thread
        (``asyncio.to_thread``) because ``policy="block"`` backpressure
        can park the submitter; the returned future is then awaited via
        ``asyncio.wrap_future``. Parameters as :meth:`submit`. Raises
        :class:`DeadlineExceededError` if the request is refused at
        admission or shed in the queue, ``RuntimeError`` if the drain loop
        is not running (the await would never complete)."""
        if not self.running:
            raise RuntimeError(
                "the background drain loop is not running — start() the "
                "server (or use it as a context manager) before "
                "infer_async(), otherwise the await would never resolve")
        fut = await asyncio.to_thread(
            self.submit, request, *legacy_inputs,
            timeout=timeout, deadline_ms=deadline_ms)
        return await asyncio.wrap_future(fut)

    def serve(self, requests, *, backend: str | None = None) -> list:
        """Mixed-request convenience over futures: submits everything —
        a list of :class:`InferRequest` returning :class:`InferResult` per
        request, or legacy ``(name, inputs[, deadline_ms])`` tuples
        returning raw outputs (deprecated) — and waits for the results in
        order. Unlike the sync server there is no partial-result exception
        — each future fails independently (sheds carry
        :class:`DeadlineExceededError`), so this raises the FIRST failed
        request's error once all are settled."""
        if backend is not None:
            raise ValueError(
                "AsyncMultiModelServer.serve dispatches via the background "
                "loop; per-call backend overrides are a sync-drain feature "
                "(register the model with the backend you want instead)")
        if not self.running:
            raise RuntimeError(
                "the background drain loop is not running — start() the "
                "server (or use it as a context manager) before serve(), "
                "otherwise the submitted futures would never resolve")
        reqs, typed = _as_requests(requests, named=True)
        if not typed:
            _warn_legacy("AsyncMultiModelServer.serve(list of (name, "
                         "inputs) tuples)", "pass a list of InferRequest")
        futs = [self.submit(req) for req in reqs]   # always the typed path
        # settle EVERYTHING before raising (the documented contract): an
        # early failure must not leave later requests in flight while the
        # caller proceeds to resubmit/stop/inspect
        concurrent.futures.wait(futs)
        if not typed:
            return [f.result().output for f in futs]
        return [f.result() for f in futs]

    # -- the background loop ------------------------------------------------

    def _serve_loop(self) -> None:
        # claim the dispatch edge for this thread: under PEGASUS_SANITIZE=1
        # any dispatch from another thread while the loop runs raises
        # ThreadAffinityError (release on exit so stop() + sync drain()
        # stragglers stay legal)
        self._dispatch_affinity.bind()
        try:
            self._serve_loop_body()
        finally:
            self._dispatch_affinity.release()

    def _serve_loop_body(self) -> None:
        while not self._stop_flag.is_set():
            try:
                # re-read per round: server.quantum is documented as a live
                # tunable, so the loop must not cache it at thread start.
                # Models inside their retry backoff window are excluded —
                # their requeued-at-front work waits out the pause while
                # every other model keeps draining.
                now = time.perf_counter()
                backoff = frozenset(
                    n for n, t in self._retry_not_before.items() if t > now)
                groups = self._sched.pull_round(self._quantum(),
                                                exclude=backoff)
                if not groups:
                    if backoff:
                        # wait_for_work returns immediately while the
                        # backed-off work sits queued; pace the retry loop
                        # instead of spinning on it
                        time.sleep(0.002)
                    else:
                        self._sched.wait_for_work(self._idle_wait)
                    continue
                # two-phase like drain(): enqueue every model's chunks on
                # the device before blocking on any result. Async failures
                # land on the futures, never requeue — a poisoned request
                # must not wedge the loop forever.
                begun = [self._begin_group(name, reqs, None)
                         for name, reqs in groups]
                for g in begun:
                    try:
                        self._finish_group(g)
                    except Exception as e:       # unexpected: _finish_group
                        # already routes dispatch errors onto futures, so
                        # anything escaping it would otherwise strand this
                        # group's futures AND skip every later group's
                        self.loop_errors.append(e)
                        for r in g["reqs"]:
                            _resolve_future(r.future, error=e)
            except Exception as e:               # pragma: no cover - safety
                self.loop_errors.append(e)
                time.sleep(self._idle_wait)


def _pegasus_demo(args) -> None:
    """--pegasus: train a tiny MLP on synthetic traffic, compile one plan,
    and serve request batches on the chosen backend."""
    from repro.data.synthetic_traffic import make_dataset
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    ds = make_dataset("peerrush", flows_per_class=120)
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=120)
    banks = pegasusify_mlp(mlp, ds.train["stats"].astype(np.float32), refine_steps=0)
    server = PegasusServer(banks, backend=args.backend, fuse=not args.no_fuse)
    st0 = server.plan.compile_stats()
    print(f"plan compiled in {server.plan_build_ms:.1f} ms "
          f"({server.plan.num_banks} banks, {st0['fused_groups']} fused "
          f"groups covering {st0['fused_banks']} banks, backend={args.backend})")
    x = ds.test["stats"].astype(np.float32)
    requests = [InferRequest("mlp", x[i : i + args.batch])
                for i in range(0, min(len(x), 8 * args.batch), args.batch)]
    server.serve(requests)  # warmup/compile
    t0 = time.perf_counter()
    results = server.serve(requests)
    dt = time.perf_counter() - t0
    flows = sum(r.flows for r in results)
    print(f"served {len(requests)} requests ({flows} flows) in {dt * 1e3:.1f} ms "
          f"→ {flows / dt:.0f} flows/s on backend={args.backend}")
    st = server.stats()["engine"]
    print(f"compile cache: {st['traces']} traces, {st['bucket_hits']} bucket "
          f"hits over {st['jit_calls']} jit calls; buckets={st['buckets']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pegasus", action="store_true",
                    help="serve a pegasusified model via the execution engine")
    ap.add_argument("--backend", default="onehot",
                    choices=["gather", "onehot", "kernel", "kernel_q8"],
                    help="engine backend bound to the serving plan")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable cross-bank primitive fusion (A/B escape "
                         "hatch; fusion is the default)")
    args = ap.parse_args()
    if args.pegasus:
        _pegasus_demo(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --pegasus is given")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, batch_size=args.batch)
    prompts = np.ones((args.batch, 1), np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
