"""Serving driver: prefill + batched decode with sharded KV caches, and the
Pegasus LUT path as a first-class serving feature (--pegasus).

``serve_step`` is the unit the decode_32k/long_500k dry-run cells lower:
one new token for the whole batch against preallocated caches/states.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig, get_config, smoke_config
from repro.models.transformer import (
    decode_step, forward_train, init_decode_state, init_model,
)

from .mesh import batch_specs, decode_state_specs, named, param_specs

__all__ = ["make_serve_step", "make_prefill_step", "Server"]


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, tokens, pos, enc_out=None):
        logits, new_state = decode_step(cfg, params, state, tokens, pos,
                                        enc_out=enc_out)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_state

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = True):
    def prefill_step(params, batch):
        logits, _ = forward_train(cfg, params, batch, last_only=last_only)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


class Server:
    """Minimal batched greedy-decode server (the paper-kind is inference)."""

    def __init__(self, cfg: ArchConfig, mesh, *, kv_len: int = 512,
                 batch_size: int = 8, dtype=jnp.float32):
        self.cfg, self.mesh = cfg, mesh
        params = init_model(cfg, jax.random.PRNGKey(0), dtype=dtype)
        self.param_sh = named(mesh, param_specs(cfg, params, mesh))
        self.params = jax.device_put(params, self.param_sh)
        state = init_decode_state(cfg, batch_size, kv_len, dtype=dtype)
        self.state_sh = named(
            mesh, decode_state_specs(cfg, state, mesh, batch_size=batch_size))
        self.state = jax.device_put(state, self.state_sh)
        self.batch_size = batch_size
        self._step = jax.jit(
            make_serve_step(cfg),
            in_shardings=(self.param_sh, self.state_sh, None, None),
            out_shardings=(None, self.state_sh),
            donate_argnums=(1,),
        )

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy continuation for a batch of single-token prompts."""
        toks = jnp.asarray(prompt_tokens[:, :1], jnp.int32)
        out = [toks]
        for t in range(max_new):
            toks, self.state = self._step(self.params, self.state, toks, jnp.int32(t))
            out.append(toks)
        return np.concatenate([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, batch_size=args.batch)
    prompts = np.ones((args.batch, 1), np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
