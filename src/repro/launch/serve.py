"""Serving driver: prefill + batched decode with sharded KV caches, and the
Pegasus LUT path as a first-class serving feature (--pegasus).

``serve_step`` is the unit the decode_32k/long_500k dry-run cells lower:
one new token for the whole batch against preallocated caches/states.

``PegasusServer`` is the dataplane-model analog: ONE compiled
:class:`repro.engine.ExecutionPlan` (layouts + int8 LUTs precomputed at
plan-build) reused across every request batch, with the backend —
``gather | onehot | kernel | kernel_q8`` — chosen once via ``--backend``.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig, get_config, smoke_config
from repro.models.transformer import (
    decode_step, forward_train, init_decode_state, init_model,
)

from .mesh import batch_specs, decode_state_specs, named, param_specs

__all__ = ["make_serve_step", "make_prefill_step", "Server", "PegasusServer"]


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, tokens, pos, enc_out=None):
        logits, new_state = decode_step(cfg, params, state, tokens, pos,
                                        enc_out=enc_out)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_state

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = True):
    def prefill_step(params, batch):
        logits, _ = forward_train(cfg, params, batch, last_only=last_only)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


class Server:
    """Minimal batched greedy-decode server (the paper-kind is inference)."""

    def __init__(self, cfg: ArchConfig, mesh, *, kv_len: int = 512,
                 batch_size: int = 8, dtype=jnp.float32):
        self.cfg, self.mesh = cfg, mesh
        params = init_model(cfg, jax.random.PRNGKey(0), dtype=dtype)
        self.param_sh = named(mesh, param_specs(cfg, params, mesh))
        self.params = jax.device_put(params, self.param_sh)
        state = init_decode_state(cfg, batch_size, kv_len, dtype=dtype)
        self.state_sh = named(
            mesh, decode_state_specs(cfg, state, mesh, batch_size=batch_size))
        self.state = jax.device_put(state, self.state_sh)
        self.batch_size = batch_size
        self._step = jax.jit(
            make_serve_step(cfg),
            in_shardings=(self.param_sh, self.state_sh, None, None),
            out_shardings=(None, self.state_sh),
            donate_argnums=(1,),
        )

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy continuation for a batch of single-token prompts."""
        toks = jnp.asarray(prompt_tokens[:, :1], jnp.int32)
        out = [toks]
        for t in range(max_new):
            toks, self.state = self._step(self.params, self.state, toks, jnp.int32(t))
            out.append(toks)
        return np.concatenate([np.asarray(t) for t in out], axis=1)


class PegasusServer:
    """Batched multi-request server over ONE cached ExecutionPlan.

    The plan is compiled once in ``__init__`` (feature one-hots, padded
    LUT/threshold tensors, int8 LUT + scales); every request batch after
    that dispatches the whole-plan JITTED forward — the batch is padded up
    to its compile bucket (powers of two by default), so arbitrary request
    sizes hit a warm XLA executable instead of retracing per shape.
    Requests may be single inputs or tuples (e.g. ``(seq, payload)`` for
    CNN-L); requests are fused into one plan call (chunked at
    ``max_batch``) and the outputs split back out. ``stats()`` reports the
    compile-cache counters (traces vs bucket hits).

    Every request input MUST carry a leading batch dim (wrap a single flow
    as ``x[None]``) — axis 0 is always interpreted as the batch axis.
    """

    def __init__(self, model, *, backend: str = "onehot",
                 interpret: bool | None = None, max_batch: int = 1024):
        from repro.engine import build_plan

        t0 = time.perf_counter()
        self.plan = build_plan(model, backend=backend, interpret=interpret)
        self.plan_build_ms = (time.perf_counter() - t0) * 1e3
        self.backend = backend
        self.max_batch = max_batch
        self.requests_served = 0
        self.batches_run = 0

    def stats(self) -> dict:
        """Serving + compile-cache counters (the ops surface: a bucket_hits
        to traces ratio near 1:1 means the bucket ladder is mis-sized)."""
        return {
            "backend": self.backend,
            "plan_build_ms": self.plan_build_ms,
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            **self.plan.compile_stats(),
        }

    def infer(self, *inputs, backend: str | None = None) -> jax.Array:
        """One already-batched call through the cached plan (one request)."""
        self.batches_run += 1
        self.requests_served += 1
        return self.plan(*inputs, backend=backend)

    def serve(self, requests, *, backend: str | None = None) -> list[np.ndarray]:
        """Fuse a list of requests into plan-sized batches and split results."""
        if not requests:
            return []
        reqs = [tuple(r) if isinstance(r, (tuple, list)) else (r,) for r in requests]
        sizes = [int(np.shape(r[0])[0]) for r in reqs]
        n_in = len(reqs[0])
        cat = [jnp.concatenate([jnp.asarray(r[i]) for r in reqs], axis=0)
               for i in range(n_in)]
        total = sum(sizes)
        chunks = []
        for start in range(0, total, self.max_batch):
            sl = [c[start : start + self.max_batch] for c in cat]
            chunks.append(self.plan(*sl, backend=backend))
            self.batches_run += 1
        out = jnp.concatenate(chunks, axis=0) if len(chunks) > 1 else chunks[0]
        self.requests_served += len(reqs)
        return [np.asarray(o) for o in jnp.split(out, np.cumsum(sizes)[:-1], axis=0)]


def _pegasus_demo(args) -> None:
    """--pegasus: train a tiny MLP on synthetic traffic, compile one plan,
    and serve request batches on the chosen backend."""
    from repro.data.synthetic_traffic import make_dataset
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    ds = make_dataset("peerrush", flows_per_class=120)
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=120)
    banks = pegasusify_mlp(mlp, ds.train["stats"].astype(np.float32), refine_steps=0)
    server = PegasusServer(banks, backend=args.backend)
    print(f"plan compiled in {server.plan_build_ms:.1f} ms "
          f"({server.plan.num_banks} banks, backend={args.backend})")
    x = ds.test["stats"].astype(np.float32)
    requests = [x[i : i + args.batch] for i in range(0, min(len(x), 8 * args.batch), args.batch)]
    server.serve(requests)  # warmup/compile
    t0 = time.perf_counter()
    outs = server.serve(requests)
    dt = time.perf_counter() - t0
    flows = sum(len(o) for o in outs)
    print(f"served {len(requests)} requests ({flows} flows) in {dt * 1e3:.1f} ms "
          f"→ {flows / dt:.0f} flows/s on backend={args.backend}")
    st = server.stats()
    print(f"compile cache: {st['traces']} traces, {st['bucket_hits']} bucket "
          f"hits over {st['jit_calls']} jit calls; buckets={st['buckets']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pegasus", action="store_true",
                    help="serve a pegasusified model via the execution engine")
    ap.add_argument("--backend", default="onehot",
                    choices=["gather", "onehot", "kernel", "kernel_q8"],
                    help="engine backend bound to the serving plan")
    args = ap.parse_args()
    if args.pegasus:
        _pegasus_demo(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --pegasus is given")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, batch_size=args.batch)
    prompts = np.ones((args.batch, 1), np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
