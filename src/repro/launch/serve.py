"""Serving driver: prefill + batched decode with sharded KV caches, and the
Pegasus LUT path as a first-class serving feature (--pegasus).

``serve_step`` is the unit the decode_32k/long_500k dry-run cells lower:
one new token for the whole batch against preallocated caches/states.

``PegasusServer`` is the dataplane-model analog for ONE model: a compiled
:class:`repro.engine.ExecutionPlan` (layouts + int8 LUTs precomputed at
plan-build) reused across every request batch, with the backend —
``gather | onehot | kernel | kernel_q8`` — chosen once via ``--backend``.

``MultiModelServer`` is the scale step the paper's pitch implies (a shared
dataplane serves MANY models and traffic classes at once — Quark runs whole
CNNs on one switch, FENIX multiplexes DNN workloads through one pipeline):
N named heterogeneous plans (MLP/RNN/CNN/AE) behind one server, requests
addressed ``(model_name, inputs)``, same-model requests coalesced into
bucket-aligned micro-batches, models scheduled fairly (round-robin), and
per-model serving + compile-cache stats.
"""

from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig, get_config, smoke_config
from repro.models.transformer import (
    decode_step, forward_train, init_decode_state, init_model,
)

from .mesh import batch_specs, decode_state_specs, named, param_specs

__all__ = ["make_serve_step", "make_prefill_step", "Server", "PegasusServer",
           "MultiModelServer"]


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, state, tokens, pos, enc_out=None):
        logits, new_state = decode_step(cfg, params, state, tokens, pos,
                                        enc_out=enc_out)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, new_state

    return serve_step


def make_prefill_step(cfg: ArchConfig, *, last_only: bool = True):
    def prefill_step(params, batch):
        logits, _ = forward_train(cfg, params, batch, last_only=last_only)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


class Server:
    """Minimal batched greedy-decode server (the paper-kind is inference)."""

    def __init__(self, cfg: ArchConfig, mesh, *, kv_len: int = 512,
                 batch_size: int = 8, dtype=jnp.float32):
        self.cfg, self.mesh = cfg, mesh
        params = init_model(cfg, jax.random.PRNGKey(0), dtype=dtype)
        self.param_sh = named(mesh, param_specs(cfg, params, mesh))
        self.params = jax.device_put(params, self.param_sh)
        state = init_decode_state(cfg, batch_size, kv_len, dtype=dtype)
        self.state_sh = named(
            mesh, decode_state_specs(cfg, state, mesh, batch_size=batch_size))
        self.state = jax.device_put(state, self.state_sh)
        self.batch_size = batch_size
        self._step = jax.jit(
            make_serve_step(cfg),
            in_shardings=(self.param_sh, self.state_sh, None, None),
            out_shardings=(None, self.state_sh),
            donate_argnums=(1,),
        )

    def generate(self, prompt_tokens: np.ndarray, max_new: int = 16) -> np.ndarray:
        """Greedy continuation for a batch of single-token prompts."""
        toks = jnp.asarray(prompt_tokens[:, :1], jnp.int32)
        out = [toks]
        for t in range(max_new):
            toks, self.state = self._step(self.params, self.state, toks, jnp.int32(t))
            out.append(toks)
        return np.concatenate([np.asarray(t) for t in out], axis=1)


class PegasusServer:
    """Batched multi-request server over ONE cached ExecutionPlan.

    The plan is compiled once in ``__init__`` (feature one-hots, padded
    LUT/threshold tensors, int8 LUT + scales); every request batch after
    that dispatches the whole-plan JITTED forward — the batch is padded up
    to its compile bucket (powers of two by default), so arbitrary request
    sizes hit a warm XLA executable instead of retracing per shape.
    Requests may be single inputs or tuples (e.g. ``(seq, payload)`` for
    CNN-L); requests are fused into one plan call, chunked along the
    bucket ladder (``repro.engine.bucket_chunks``) so full chunks are exact
    bucket sizes, and the outputs split back out. ``stats()`` reports the
    compile-cache counters (traces vs bucket hits).

    Every request input MUST carry a leading batch dim (wrap a single flow
    as ``x[None]``) — axis 0 is always interpreted as the batch axis.

    Serving counters are incremented ONLY after the plan call succeeds — a
    raising request (bad shape, unknown backend) must not corrupt
    ``requests_served``/``batches_run``.
    """

    def __init__(self, model, *, backend: str = "onehot",
                 interpret: bool | None = None, max_batch: int | None = None,
                 fuse: bool = True):
        from repro.engine import build_plan

        t0 = time.perf_counter()
        self.plan = build_plan(model, backend=backend, interpret=interpret,
                               fuse=fuse)
        self.plan_build_ms = (time.perf_counter() - t0) * 1e3
        self.backend = backend
        # default cap = the top of the plan's bucket ladder (4096), so a
        # coalesced batch that has its own exact bucket is never split
        self.max_batch = (max(self.plan.buckets) if max_batch is None
                          else max_batch)
        self.requests_served = 0
        self.batches_run = 0

    def stats(self) -> dict:
        """Serving + compile-cache counters (the ops surface: a bucket_hits
        to traces ratio near 1:1 means the bucket ladder is mis-sized)."""
        return {
            "backend": self.backend,
            "plan_build_ms": self.plan_build_ms,
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            **self.plan.compile_stats(),
        }

    def infer(self, *inputs, backend: str | None = None) -> jax.Array:
        """One already-batched call through the cached plan (one request)."""
        y = self.plan(*inputs, backend=backend)
        self.batches_run += 1            # success-only counting
        self.requests_served += 1
        return y

    def serve(self, requests, *, backend: str | None = None) -> list[np.ndarray]:
        """Fuse a list of requests into bucket-aligned batches, split results."""
        from repro.engine import bucket_chunks

        if not requests:
            return []
        cat, sizes, total = _coalesce(requests)
        chunks, start = [], 0
        for size in bucket_chunks(total, self.plan.buckets, self.max_batch):
            sl = (cat if size == total
                  else [c[start : start + size] for c in cat])
            chunks.append(self.plan(*sl, backend=backend))
            start += size
        out = jnp.concatenate(chunks, axis=0) if len(chunks) > 1 else chunks[0]
        # commit counters only once EVERY chunk dispatched — a failure on a
        # later chunk must not leave batches_run ahead of requests_served
        self.batches_run += len(chunks)
        self.requests_served += len(sizes)
        return _split(out, sizes)


def _coalesce(requests) -> tuple[list, list[int], int]:
    """Normalize a request list (arrays or input tuples, each with a leading
    batch dim) into per-input concatenations + per-request sizes."""
    reqs = [tuple(r) if isinstance(r, (tuple, list)) else (r,) for r in requests]
    sizes = [int(np.shape(r[0])[0]) for r in reqs]
    if len(reqs) == 1:
        cat = [r if isinstance(r, jax.Array) else jnp.asarray(r)
               for r in reqs[0]]
    else:
        cat = [jnp.concatenate([jnp.asarray(r[i]) for r in reqs], axis=0)
               for i in range(len(reqs[0]))]
    return cat, sizes, sum(sizes)


def _split(out: jax.Array, sizes: list[int]) -> list[np.ndarray]:
    """Cut a coalesced output back into per-request numpy arrays."""
    if len(sizes) == 1:
        return [np.asarray(out)]
    return [np.asarray(o)
            for o in jnp.split(out, np.cumsum(sizes)[:-1], axis=0)]


class MultiModelServer:
    """Many heterogeneous models behind ONE server.

    Each model is compiled once and pinned under a name in a
    :class:`repro.engine.PlanRegistry` (per-model backend override allowed).
    Requests address models by name; pending same-model requests are
    coalesced into bucket-aligned micro-batches (``bucket_chunks``: full
    chunks are exact bucket sizes, the tail pads minimally) and the models
    with pending work are scheduled fairly — one micro-batch per model per
    round-robin turn — so a burst on one model cannot starve the others.

    Two call styles:
      * ``infer(name, *inputs)`` — immediate single-request dispatch.
      * ``submit(name, *inputs)`` + ``drain()`` — enqueue across models,
        then serve everything; ``drain`` returns ``{name: [out_per_request]}``
        in per-model submit order. ``serve(requests)`` wraps submit+drain
        for a mixed ``[(name, inputs), ...]`` list, preserving order.

    All counters (``requests_served``/``batches_run``/``flows_served``) are
    per model and committed only when a model's queue fully serves; a
    failing model keeps its queue (retryable, never double-counted), its
    exception lands in ``last_drain_errors``, and every other model drains
    and returns normally. ``schedule_log`` records the model name of every
    dispatched micro-batch — the fairness tests assert on it.
    """

    def __init__(self, models: dict | None = None, *, backend: str = "onehot",
                 interpret: bool | None = None, max_batch: int | None = None,
                 registry=None, fuse: bool = True):
        from repro.engine import DEFAULT_BUCKETS, PlanRegistry

        self.registry = PlanRegistry() if registry is None else registry
        self.backend = backend
        self.interpret = interpret
        self.fuse = fuse    # cross-bank fusion default for add_model plans
        self.max_batch = (max(DEFAULT_BUCKETS) if max_batch is None
                          else max_batch)
        self._queues: dict[str, deque] = {}
        self._counters: dict[str, dict] = {}
        # bounded: the log is a debugging/fairness-test surface, not an
        # audit trail — a long-lived server must not grow it without limit
        self.schedule_log: deque = deque(maxlen=4096)
        self.batches_dispatched = 0
        self.last_drain_errors: dict[str, Exception] = {}
        for name in self.registry.names():   # adopt a pre-populated registry
            self._track(name)
        for name, model in dict(models or {}).items():
            self.add_model(name, model)

    def _track(self, name: str) -> None:
        """Queue + counters for a registry name this server serves. Names
        registered on a shared registry after construction are adopted
        lazily on first submit/infer."""
        self._queues.setdefault(name, deque())
        self._counters.setdefault(name, {"requests_served": 0,
                                         "batches_run": 0, "flows_served": 0})

    def _tracked(self, name: str) -> None:
        if name not in self._counters:
            if name not in self.registry:
                raise KeyError(
                    f"unknown model {name!r}; registered: {self.models()}")
            self._track(name)

    # -- model management ---------------------------------------------------

    def add_model(self, name: str, model, *, backend: str | None = None,
                  **build_kw):
        """Compile + register one model; returns its ExecutionPlan."""
        build_kw.setdefault("fuse", self.fuse)
        plan = self.registry.register(
            name, model, backend=backend or self.backend,
            interpret=self.interpret, **build_kw)
        self._track(name)
        return plan

    def remove_model(self, name: str) -> bool:
        """Evict a model; its pending queue is dropped with it."""
        self._queues.pop(name, None)
        self._counters.pop(name, None)
        return self.registry.evict(name)

    def models(self) -> list[str]:
        return self.registry.names()

    # -- request paths ------------------------------------------------------

    def infer(self, name: str, *inputs, backend: str | None = None):
        """Immediate single-request dispatch through the named plan."""
        self._tracked(name)
        y = self.registry.get(name)(*inputs, backend=backend)
        c = self._counters[name]
        c["requests_served"] += 1        # success-only counting
        c["batches_run"] += 1
        c["flows_served"] += int(np.shape(inputs[0])[0])
        return y

    def submit(self, name: str, *inputs) -> int:
        """Enqueue one request; returns its per-model position for this
        drain round. Inputs must carry a leading batch dim."""
        self._tracked(name)
        q = self._queues[name]
        q.append(tuple(x if isinstance(x, jax.Array) else jnp.asarray(x)
                       for x in inputs))
        return len(q) - 1

    def pending(self) -> dict[str, int]:
        return {n: len(q) for n, q in self._queues.items() if q}

    def discard_pending(self, name: str) -> int:
        """Drop a model's queued requests (returns how many). The escape
        hatch for a poisoned queue: a permanently-bad request is coalesced
        with every later submit to its model, so retries would fail
        forever until the queue is cleared."""
        q = self._queues.get(name)
        n = len(q) if q else 0
        if q:
            q.clear()
        return n

    def drain(self, *, backend: str | None = None) -> dict:
        """Serve every queued request: per model, coalesce the queue and cut
        it into bucket-aligned micro-batches; dispatch round-robin (one
        chunk per model with remaining work per turn). Returns
        ``{name: [np.ndarray per request, in submit order]}``.

        Failures are isolated per model: a model whose dispatch raises keeps
        its queue (retryable) and ALL its counters untouched (they only
        commit when the model's queue fully serves — a retry never
        double-counts partially-run chunks), while every other model drains
        normally and returns its results. The per-model exceptions land in
        ``last_drain_errors``; drain raises only if NO model succeeded. A
        request that is itself bad will fail every retry (it coalesces with
        whatever else queues up) — clear it with ``discard_pending``."""
        from repro.engine import bucket_chunks

        work = []
        self.last_drain_errors = {}
        for name, q in self._queues.items():
            if not q:
                continue
            try:
                cat, sizes, total = _coalesce(list(q))
                plan = self.registry.get(name)
                chunks = bucket_chunks(total, plan.buckets, self.max_batch)
            except Exception as e:
                self.last_drain_errors[name] = e
                continue
            work.append({"name": name, "plan": plan, "cat": cat,
                         "sizes": sizes, "total": total,
                         "chunks": deque(chunks), "start": 0, "outs": [],
                         "batches": 0})

        results: dict = {}
        while work:
            next_round = []
            for w in work:                       # fair: one chunk per model
                size = w["chunks"].popleft()
                if w["start"] == 0 and size == w["total"]:
                    sl = w["cat"]                # whole queue in one chunk
                else:
                    sl = [c[w["start"] : w["start"] + size] for c in w["cat"]]
                try:
                    w["outs"].append(w["plan"](*sl, backend=backend))
                except Exception as e:           # isolate: queue + stats kept
                    self.last_drain_errors[w["name"]] = e
                    continue
                self.schedule_log.append(w["name"])
                self.batches_dispatched += 1
                w["start"] += size
                w["batches"] += 1
                if w["chunks"]:
                    next_round.append(w)
                else:                            # model fully served: commit
                    out = (jnp.concatenate(w["outs"], axis=0)
                           if len(w["outs"]) > 1 else w["outs"][0])
                    results[w["name"]] = _split(out, w["sizes"])
                    c = self._counters[w["name"]]
                    c["requests_served"] += len(w["sizes"])
                    c["batches_run"] += w["batches"]
                    c["flows_served"] += w["total"]
                    self._queues[w["name"]].clear()
            work = next_round
        if self.last_drain_errors and not results:
            raise next(iter(self.last_drain_errors.values()))
        return results

    def serve(self, requests, *, backend: str | None = None) -> list[np.ndarray]:
        """Mixed-model convenience: ``requests`` is ``[(name, inputs), ...]``
        (inputs a single array or a tuple); returns outputs aligned to the
        request order. If any requested model failed to drain, its actual
        error is raised with the already-served models' outputs attached as
        ``partial_results`` on the exception (their work is computed and
        counted — only the failed models' requests need resubmitting)."""
        order = []
        for name, inputs in requests:
            inputs = tuple(inputs) if isinstance(inputs, (tuple, list)) else (inputs,)
            order.append((name, self.submit(name, *inputs)))
        by_model = self.drain(backend=backend)
        for name, _ in order:
            if name not in by_model and name in self.last_drain_errors:
                err = self.last_drain_errors[name]
                err.partial_results = by_model
                raise err
        return [by_model[name][pos] for name, pos in order]

    def stats(self) -> dict:
        """Per-model serving counters merged with the registry's per-plan
        compile-cache stats, plus the memo cache_info."""
        reg = self.registry.stats()
        zeros = {"requests_served": 0, "batches_run": 0, "flows_served": 0}
        return {
            "models": {
                # zeroed defaults keep the schema uniform for names on a
                # shared registry that this server hasn't served yet
                name: {**zeros, **self._counters.get(name, {}),
                       **reg.get(name, {})}
                for name in self.models()
            },
            "cache": self.registry.cache_info(),
            "batches_dispatched": self.batches_dispatched,
        }


def _pegasus_demo(args) -> None:
    """--pegasus: train a tiny MLP on synthetic traffic, compile one plan,
    and serve request batches on the chosen backend."""
    from repro.data.synthetic_traffic import make_dataset
    from repro.nets.mlp import pegasusify_mlp, train_mlp

    ds = make_dataset("peerrush", flows_per_class=120)
    mlp = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes, steps=120)
    banks = pegasusify_mlp(mlp, ds.train["stats"].astype(np.float32), refine_steps=0)
    server = PegasusServer(banks, backend=args.backend, fuse=not args.no_fuse)
    st0 = server.plan.compile_stats()
    print(f"plan compiled in {server.plan_build_ms:.1f} ms "
          f"({server.plan.num_banks} banks, {st0['fused_groups']} fused "
          f"groups covering {st0['fused_banks']} banks, backend={args.backend})")
    x = ds.test["stats"].astype(np.float32)
    requests = [x[i : i + args.batch] for i in range(0, min(len(x), 8 * args.batch), args.batch)]
    server.serve(requests)  # warmup/compile
    t0 = time.perf_counter()
    outs = server.serve(requests)
    dt = time.perf_counter() - t0
    flows = sum(len(o) for o in outs)
    print(f"served {len(requests)} requests ({flows} flows) in {dt * 1e3:.1f} ms "
          f"→ {flows / dt:.0f} flows/s on backend={args.backend}")
    st = server.stats()
    print(f"compile cache: {st['traces']} traces, {st['bucket_hits']} bucket "
          f"hits over {st['jit_calls']} jit calls; buckets={st['buckets']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pegasus", action="store_true",
                    help="serve a pegasusified model via the execution engine")
    ap.add_argument("--backend", default="onehot",
                    choices=["gather", "onehot", "kernel", "kernel_q8"],
                    help="engine backend bound to the serving plan")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable cross-bank primitive fusion (A/B escape "
                         "hatch; fusion is the default)")
    args = ap.parse_args()
    if args.pegasus:
        _pegasus_demo(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --pegasus is given")
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    server = Server(cfg, mesh, batch_size=args.batch)
    prompts = np.ones((args.batch, 1), np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
