"""Typed request/result surface for the serving runtime.

The serving API grew three divergent call shapes across PRs 3-6:
``infer(name, *inputs)``, ``submit(name, *inputs, deadline_ms=...)`` and
``serve([(name, inputs, deadline_ms), ...])`` tuple triples. This module
is the single replacement: every server entry point routes through
:class:`InferRequest` in and :class:`InferResult` out, and the legacy
shapes survive only as thin deprecated shims (see ``serve.py``).

Design notes:

* ``InferRequest`` is frozen — a request is a value, safe to share across
  the submitting thread, the WFQ queues and the drain/device threads.
  ``inputs`` is always a tuple (a bare array normalizes to a 1-tuple in
  ``__post_init__``); multi-operand models (the RNN takes ``(x, h0)``
  style streams in principle) pass longer tuples unchanged.
* ``priority`` is *per-request* urgency layered on top of the per-model
  WFQ class: within one model's queue, ``high`` requests jump ahead of
  ``normal`` ahead of ``low`` (see ``WFQScheduler.submit``). It does NOT
  change the cross-model weight — that stays the registration-time
  priority class.
* ``InferResult`` carries the output plus the serving telemetry a client
  would otherwise scrape out of ``stats()``: flow count and observed
  queue wait. ``result.output`` is the raw array for callers that only
  want the tensor.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

__all__ = ["InferRequest", "InferResult", "PRIORITIES"]

#: Valid per-request priorities, in ascending urgency.
PRIORITIES = ("low", "normal", "high")


@dataclass(frozen=True)
class InferRequest:
    """One inference request: which model, what inputs, how urgent.

    Parameters
    ----------
    model:
        Registered model name (``MultiModelServer``) — ignored by the
        single-model ``PegasusServer``, where it may be left as ``""``.
    inputs:
        One array or a tuple of arrays (leading axis = flows). A bare
        array is normalized to a 1-tuple.
    deadline_ms:
        Optional end-to-end latency budget in milliseconds. Requests
        predicted or observed to miss it are shed with
        ``DeadlineExceededError`` (PR-6 semantics, unchanged).
    priority:
        Per-request urgency within the model's queue: ``"low"`` |
        ``"normal"`` | ``"high"``.
    """

    model: str
    inputs: Any
    deadline_ms: float | None = None
    priority: str = "normal"

    def __post_init__(self):
        if self.priority not in PRIORITIES:
            raise ValueError(
                f"priority must be one of {PRIORITIES}, got {self.priority!r}")
        if not isinstance(self.inputs, tuple):
            object.__setattr__(
                self, "inputs",
                tuple(self.inputs) if isinstance(self.inputs, list)
                else (self.inputs,))
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {self.deadline_ms}")

    @property
    def flows(self) -> int:
        """Number of flows (batch rows) this request carries."""
        return int(self.inputs[0].shape[0])


@dataclass(frozen=True)
class InferResult:
    """One served response: the output plus its serving telemetry.

    ``output`` is the model's output array for this request's rows.
    ``flows`` is the batch-row count served. ``queue_wait_ms`` is the
    submit→dispatch wait observed by the scheduler (``None`` on paths
    that bypass the scheduler, e.g. ``PegasusServer.infer``).
    """

    model: str
    output: Any
    flows: int
    queue_wait_ms: float | None = field(default=None, compare=False)
