"""ShapeDtypeStruct stand-ins for every (arch × shape) cell — the dry-run's
inputs. No device allocation happens here (shannon/kernels pattern).

Cell semantics:
  train_4k    → ``train_step``  : tokens/labels [GB, S] (stub: embeds)
  prefill_32k → ``prefill_step``: forward over the full sequence
  decode_32k  → ``serve_step``  : ONE new token against a seq_len KV cache
  long_500k   → ``serve_step``  : as above at 524288 (sub-quadratic archs only)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, ArchConfig, get_config
from repro.models.transformer import init_decode_state

__all__ = ["input_specs", "decode_state_shapes", "cell_is_supported", "skip_reason"]


def cell_is_supported(cfg: ArchConfig, shape_name: str) -> bool:
    return skip_reason(cfg, shape_name) is None


def skip_reason(cfg: ArchConfig, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full attention: 524k-token KV has no sub-quadratic path in the "
                "published architecture (DESIGN.md §Arch-applicability)")
    return None


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape_name: str) -> dict:
    """Returns {batch | tokens/pos/state-free inputs} ShapeDtypeStructs."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    seq, gb, kind = SHAPES[shape_name]

    if kind == "train" or kind == "prefill":
        batch = {}
        if cfg.encoder_layers:  # whisper: encoder frames + decoder text
            batch["embeds"] = _struct((gb, seq, cfg.d_model), jnp.bfloat16)
            batch["dec_tokens"] = _struct((gb, cfg.max_decoder_len), jnp.int32)
            if kind == "train":
                batch["labels"] = _struct((gb, cfg.max_decoder_len), jnp.int32)
        elif cfg.frontend_stub:  # vlm: patch/frame embeddings
            batch["embeds"] = _struct((gb, seq, cfg.d_model), jnp.bfloat16)
            if kind == "train":
                batch["labels"] = _struct((gb, seq), jnp.int32)
        else:
            batch["tokens"] = _struct((gb, seq), jnp.int32)
            if kind == "train":
                batch["labels"] = _struct((gb, seq), jnp.int32)
        return batch

    # decode: one token + cache/state structs
    out = {
        "tokens": _struct((gb, 1), jnp.int32),
        "pos": _struct((), jnp.int32),
        "state": decode_state_shapes(cfg, gb, seq),
    }
    if cfg.encoder_layers:
        # cross-attention context from the encoder (its own envelope)
        out["enc_out"] = _struct((gb, 1500, cfg.d_model), jnp.bfloat16)
    return out


def decode_state_shapes(cfg: ArchConfig, batch: int, kv_len: int) -> dict:
    """Shape-only version of init_decode_state (no allocation)."""
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, kv_len, dtype=jnp.bfloat16)
    )
