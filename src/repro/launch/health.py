"""Circuit breakers for the self-healing serving stack.

One :class:`CircuitBreaker` guards one failure domain — a served model's
preferred-backend path (``MultiModelServer``/``AsyncMultiModelServer``) or
one device stream (``DeviceStreamPool``). The state machine is the
classic three-state breaker:

  * **CLOSED** — healthy; every call proceeds. ``failure_threshold``
    CONSECUTIVE failures trip it OPEN (one success resets the streak).
  * **OPEN** — quarantined; :meth:`allow` refuses until
    ``reset_timeout_s`` has elapsed since the trip, then transitions to
    HALF_OPEN and grants a probe.
  * **HALF_OPEN** — probation; up to ``half_open_probes`` in-flight
    probes are granted. A probe success auto-reinstates (→ CLOSED), a
    probe failure re-opens and restarts the cooldown.

What the owner does with a refused :meth:`allow` is its policy, not the
breaker's: the server routes the model onto the gather fallback ladder
(serving degraded), the device pool places chunks on other streams. State
plus transition counters surface through the nested ``stats()`` schema
(``health.models.<name>`` / ``devices.per_device[i]`` — see
docs/RELIABILITY.md).

The clock is injectable (``clock=time.monotonic`` by default) so the
lifecycle tests drive cooldowns without sleeping. All mutable state lives
behind one ``health._lock`` (registered in the PR-8 lock hierarchy as the
innermost serving rank: breaker calls happen under ``devices._lock`` in
placement, never the other way around).
"""

from __future__ import annotations

import time

from repro.analysis.sanitizer import make_lock

__all__ = ["CircuitBreaker", "CLOSED", "OPEN", "HALF_OPEN"]

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Three-state consecutive-failure breaker (module docstring).

    Args:
        name: label used in stats/errors (e.g. the model name or
            ``"stream-2"``).
        failure_threshold: consecutive failures that trip CLOSED → OPEN.
        reset_timeout_s: cooldown before an OPEN breaker grants a probe.
        half_open_probes: max concurrent probe grants while HALF_OPEN.
        clock: monotonic-seconds callable (injectable for tests).
    """

    def __init__(self, name: str = "", *, failure_threshold: int = 3,
                 reset_timeout_s: float = 1.0, half_open_probes: int = 1,
                 clock=time.monotonic):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be ≥ 1, got {failure_threshold}")
        if reset_timeout_s < 0:
            raise ValueError(
                f"reset_timeout_s must be ≥ 0, got {reset_timeout_s}")
        if half_open_probes < 1:
            raise ValueError(
                f"half_open_probes must be ≥ 1, got {half_open_probes}")
        self.name = name                              # immutable
        self.failure_threshold = int(failure_threshold)   # immutable
        self.reset_timeout_s = float(reset_timeout_s)     # immutable
        self.half_open_probes = int(half_open_probes)     # immutable
        self._clock = clock                           # immutable
        self._lock = make_lock("health._lock")
        self._state = CLOSED        # guarded-by: _lock
        self._consecutive = 0       # guarded-by: _lock
        self._opened_at = 0.0       # guarded-by: _lock
        self._probes = 0            # guarded-by: _lock
        # transition counters (the stats surface)
        self._opened = 0            # guarded-by: _lock
        self._reopened = 0          # guarded-by: _lock
        self._half_opened = 0       # guarded-by: _lock
        self._reinstated = 0        # guarded-by: _lock

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May a call proceed on the guarded path right now?

        CLOSED always allows. OPEN refuses during the cooldown, then
        transitions to HALF_OPEN and grants (the caller's call IS the
        probe). HALF_OPEN grants while probe slots remain. A grant from a
        non-CLOSED state must be answered with :meth:`record_success` or
        :meth:`record_failure`, or the probe slot stays occupied."""
        with self._lock:
            if self._state == CLOSED:
                return True
            if self._state == OPEN:
                if self._clock() - self._opened_at < self.reset_timeout_s:
                    return False
                self._state = HALF_OPEN
                self._half_opened += 1
                self._probes = 1
                return True
            # HALF_OPEN: bounded concurrent probes
            if self._probes < self.half_open_probes:
                self._probes += 1
                return True
            return False

    def record_success(self) -> str:
        """A guarded call succeeded: reset the failure streak and, from
        probation, auto-reinstate (→ CLOSED). Returns the new state."""
        with self._lock:
            self._consecutive = 0
            if self._state != CLOSED:
                self._state = CLOSED
                self._probes = 0
                self._reinstated += 1
            return self._state

    def record_failure(self) -> str:
        """A guarded call failed: extend the streak; trip OPEN from CLOSED
        at the threshold, re-open immediately from HALF_OPEN (a failed
        probe restarts the cooldown). Returns the new state — callers key
        quarantine work (queue migration, fallback rebuild) off the
        transition to ``OPEN``."""
        with self._lock:
            self._consecutive += 1
            if self._state == HALF_OPEN:
                self._state = OPEN
                self._opened_at = self._clock()
                self._probes = 0
                self._reopened += 1
            elif (self._state == CLOSED
                    and self._consecutive >= self.failure_threshold):
                self._state = OPEN
                self._opened_at = self._clock()
                self._opened += 1
            return self._state

    def stats(self) -> dict:
        """State + transition counters — one entry of the nested
        ``stats()`` health schema."""
        with self._lock:
            return {
                "state": self._state,
                "consecutive_failures": self._consecutive,
                "opened": self._opened,
                "reopened": self._reopened,
                "half_opens": self._half_opened,
                "reinstated": self._reinstated,
                "failure_threshold": self.failure_threshold,
                "reset_timeout_s": self.reset_timeout_s,
            }
