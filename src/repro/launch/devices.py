"""DeviceStreamPool: N per-device executor streams behind one submit().

The multi-device serving fan-out (ROADMAP "Multi-device sharded serving"):
ONE WFQ pull loop drains the scheduler and hands each bucket-aligned chunk
to this pool, which places it on the **least-loaded device** — the device
with the fewest *pending flows* (queued + in-flight), ties broken by
lowest device index so placement is deterministic and testable. Each
device owns a daemon worker thread and a FIFO deque; a chunk dispatched
to device *i* runs ``fn(device_i)`` on that worker (the plan call inside
does ``device_put`` of state + operands, so the XLA execution is pinned
to that stream). Futures are the hand-off: ``submit`` returns a
``concurrent.futures.Future`` that the worker resolves with the result or
the exception.

Why flows and not chunk count: chunks are bucket-padded and ragged
(17-flow and 512-flow chunks cost very differently), so queue depth in
chunks is a poor load signal; pending flow count tracks actual work.

This is deliberately engine-agnostic — ``fn`` is any callable taking a
device. The serving layer passes ``lambda d: plan(*chunk, backend=be,
device=d)``; tests pass stubs.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future

from repro.analysis.sanitizer import (ThreadAffinity, ThreadAffinityError,
                                      make_lock)

__all__ = ["DeviceStreamPool"]


class _Stream:
    """One device's executor: worker thread + FIFO + load counters."""

    __slots__ = ("device", "index", "q", "pending_flows", "dispatched_chunks",
                 "dispatched_flows", "busy_s", "errors")

    def __init__(self, device, index: int):
        self.device = device         # immutable after construction
        self.index = index           # immutable after construction
        self.q: deque = deque()      # guarded-by: _lock
        # queued + in-flight flows (the load signal)
        self.pending_flows = 0       # guarded-by: _lock
        self.dispatched_chunks = 0   # guarded-by: _lock
        self.dispatched_flows = 0    # guarded-by: _lock
        self.busy_s = 0.0            # guarded-by: _lock
        self.errors = 0              # guarded-by: _lock


class DeviceStreamPool:
    """Per-device worker threads with least-loaded-by-flows placement."""

    def __init__(self, devices):
        devices = tuple(devices)
        if not devices:
            raise ValueError("DeviceStreamPool needs at least one device")
        self._streams = tuple(_Stream(d, i) for i, d in enumerate(devices))
        self._lock = make_lock("devices._lock")
        self._work = threading.Condition(self._lock)
        self._closed = False         # guarded-by: _lock
        self._t0 = time.perf_counter()
        self._threads = []
        # sanitizer surface: each worker binds its affinity at thread start,
        # so "plan dispatch happens on a pool worker" is assertable
        # (assert_worker); all binds are no-ops unless PEGASUS_SANITIZE=1
        self._affinities = {i: ThreadAffinity(f"device-stream-{i}")
                            for i in range(len(self._streams))}
        for s in self._streams:
            t = threading.Thread(target=self._run, args=(s,),
                                 name=f"device-stream-{s.index}", daemon=True)
            self._threads.append(t)
            t.start()

    @property
    def devices(self) -> tuple:
        return tuple(s.device for s in self._streams)

    def __len__(self) -> int:
        return len(self._streams)

    # -- placement -----------------------------------------------------------

    def _least_loaded(self) -> _Stream:  # holds: _lock
        # min pending flows, tie → lowest index (deque order is stable, and
        # min() keeps the first minimum, so index order IS the tiebreak)
        return min(self._streams, key=lambda s: s.pending_flows)

    def assert_worker(self) -> None:
        """Sanitizer checkpoint: raise :class:`ThreadAffinityError` unless
        the calling thread is one of this pool's workers (no-op with the
        sanitizer off — the affinities never bind). The serving layer calls
        this from its dispatch closures, pinning the "ALL plan calls run on
        device workers" invariant at runtime."""
        idents = {a.bound_ident for a in self._affinities.values()}
        idents.discard(None)
        if idents and threading.get_ident() not in idents:
            raise ThreadAffinityError(
                f"thread {threading.current_thread().name} is not a "
                "DeviceStreamPool worker")

    def submit(self, fn, flows: int) -> Future:
        """Place ``fn(device)`` on the least-loaded stream; returns a Future.

        ``flows`` is the work size used for the load signal — pass the
        chunk's flow count (NOT the padded bucket size: the caller knows
        the real rows, and padding is uniform per bucket anyway).
        """
        fut: Future = Future()
        with self._work:
            if self._closed:
                raise RuntimeError("DeviceStreamPool is closed")
            s = self._least_loaded()
            s.pending_flows += int(flows)
            s.q.append((fn, int(flows), fut))
            self._work.notify_all()
        return fut

    # -- worker --------------------------------------------------------------

    def _run(self, s: _Stream) -> None:
        self._affinities[s.index].bind()
        while True:
            with self._work:
                while not s.q and not self._closed:
                    self._work.wait()
                if not s.q and self._closed:
                    return
                fn, flows, fut = s.q.popleft()
            if not fut.set_running_or_notify_cancel():
                with self._lock:
                    s.pending_flows -= flows
                continue
            t0 = time.perf_counter()
            try:
                out = fn(s.device)
            except BaseException as exc:  # noqa: BLE001 — future carries it
                with self._lock:
                    s.pending_flows -= flows
                    s.errors += 1
                    s.busy_s += time.perf_counter() - t0
                fut.set_exception(exc)
            else:
                with self._lock:
                    s.pending_flows -= flows
                    s.dispatched_chunks += 1
                    s.dispatched_flows += flows
                    s.busy_s += time.perf_counter() - t0
                fut.set_result(out)

    # -- ops surface ---------------------------------------------------------

    def stats(self) -> dict:
        """``{"count": N, "per_device": [{...}, ...]}`` — the ``devices``
        section of the unified server ``stats()`` schema."""
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        with self._lock:
            return {
                "count": len(self._streams),
                "per_device": [
                    {
                        "device": str(s.device),
                        "dispatched_chunks": s.dispatched_chunks,
                        "dispatched_flows": s.dispatched_flows,
                        "queue_depth": len(s.q),
                        "pending_flows": s.pending_flows,
                        "errors": s.errors,
                        "busy_ms": s.busy_s * 1e3,
                        "utilization": s.busy_s / elapsed,
                    }
                    for s in self._streams
                ],
            }

    def close(self, timeout: float = 5.0) -> None:
        """Stop accepting work, let queued work finish, join the workers."""
        with self._work:
            if self._closed:
                return
            self._closed = True
            self._work.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
