"""DeviceStreamPool: N per-device executor streams behind one submit().

The multi-device serving fan-out (ROADMAP "Multi-device sharded serving"):
ONE WFQ pull loop drains the scheduler and hands each bucket-aligned chunk
to this pool, which places it on the **least-loaded device** — the device
with the fewest *pending flows* (queued + in-flight), ties broken by
lowest device index so placement is deterministic and testable. Each
device owns a daemon worker thread and a FIFO deque; a chunk dispatched
to device *i* runs ``fn(device_i)`` on that worker (the plan call inside
does ``device_put`` of state + operands, so the XLA execution is pinned
to that stream). Futures are the hand-off: ``submit`` returns a
``concurrent.futures.Future`` that the worker resolves with the result or
the exception.

Why flows and not chunk count: chunks are bucket-padded and ragged
(17-flow and 512-flow chunks cost very differently), so queue depth in
chunks is a poor load signal; pending flow count tracks actual work.

**Supervision (ISSUE 9).** A dispatch error lands on the chunk's future —
but an error OUTSIDE that per-dispatch ``except`` (an injected crash via
the ``chaos`` hook, a bookkeeping bug) kills the worker thread, which
used to strand its FIFO silently. Now:

  * a dying worker marks its stream **dead**, migrates its queued chunks
    (and the un-started in-hand chunk) to surviving streams, and a
    respawn is scheduled with doubling backoff — transient crashes heal;
  * every stream carries a :class:`~repro.launch.health.CircuitBreaker`:
    consecutive dispatch failures trip it OPEN, ``_place`` routes around
    it and migrates nothing (the worker is alive, just quarantined —
    :meth:`_quarantine` moves its backlog), and a cooldown probe chunk
    auto-reinstates it;
  * workers found dead without supervision having seen the death are
    detected lazily in ``_place`` and at ``stats()`` time (surfaced as
    ``dead_streams``) and reaped the same way — the detection stands
    alone even if respawn never succeeds;
  * with ZERO healthy streams the pool degrades to **inline dispatch** on
    the submitting thread instead of queueing onto dead FIFOs (or
    deadlocking a caller that blocks on the future). Chunks that cannot
    migrate anywhere fail their futures with the crash error — the
    serving layer's bounded retry owns resubmission.

This is deliberately engine-agnostic — ``fn`` is any callable taking a
device. The serving layer passes ``lambda d: plan(*chunk, backend=be,
device=d)``; tests pass stubs.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError

from repro.analysis.sanitizer import (ThreadAffinity, ThreadAffinityError,
                                      make_lock)

from .health import CLOSED, OPEN, CircuitBreaker

__all__ = ["DeviceStreamPool"]


class _Stream:
    """One device's executor: worker thread + FIFO + load counters."""

    __slots__ = ("device", "index", "q", "pending_flows", "dispatched_chunks",
                 "dispatched_flows", "busy_s", "errors", "dead", "crashes",
                 "respawns", "thread", "breaker")

    def __init__(self, device, index: int, breaker: CircuitBreaker):
        self.device = device         # immutable after construction
        self.index = index           # immutable after construction
        self.breaker = breaker       # immutable ref (its own lock inside)
        self.q: deque = deque()      # guarded-by: _lock
        # queued + in-flight flows (the load signal)
        self.pending_flows = 0       # guarded-by: _lock
        self.dispatched_chunks = 0   # guarded-by: _lock
        self.dispatched_flows = 0    # guarded-by: _lock
        self.busy_s = 0.0            # guarded-by: _lock
        self.errors = 0              # guarded-by: _lock
        self.dead = False            # guarded-by: _lock
        self.crashes = 0             # guarded-by: _lock
        self.respawns = 0            # guarded-by: _lock
        self.thread: threading.Thread | None = None   # guarded-by: _lock


class DeviceStreamPool:
    """Per-device worker threads with least-loaded-by-flows placement and
    crash supervision (module docstring)."""

    def __init__(self, devices, *, chaos=None, breaker_failures: int = 3,
                 breaker_reset_s: float = 0.25,
                 respawn_backoff_s: float = 0.05,
                 max_respawn_backoff_s: float = 2.0):
        devices = tuple(devices)
        if not devices:
            raise ValueError("DeviceStreamPool needs at least one device")
        # chaos hook (see repro.launch.chaos): assigned before traffic,
        # read as a plain attribute on the worker hot path — None means
        # the hook costs one attribute load + is-None check per chunk
        self.chaos = chaos
        self.respawn_backoff_s = float(respawn_backoff_s)      # immutable
        self.max_respawn_backoff_s = float(max_respawn_backoff_s)  # immutable
        self._streams = tuple(
            _Stream(d, i, CircuitBreaker(
                f"stream-{i}", failure_threshold=breaker_failures,
                reset_timeout_s=breaker_reset_s))
            for i, d in enumerate(devices))
        self._lock = make_lock("devices._lock")
        self._work = threading.Condition(self._lock)
        self._closed = False         # guarded-by: _lock
        self._inline_dispatches = 0  # guarded-by: _lock
        self._migrated_chunks = 0    # guarded-by: _lock
        self._t0 = time.perf_counter()
        # marks the zero-healthy inline-dispatch path on ITS OWN thread so
        # assert_worker stays honest for every other thread
        self._inline_tls = threading.local()
        # sanitizer surface: each worker binds its affinity at thread start,
        # so "plan dispatch happens on a pool worker" is assertable
        # (assert_worker); all binds are no-ops unless PEGASUS_SANITIZE=1
        self._affinities = {i: ThreadAffinity(f"device-stream-{i}")
                            for i in range(len(self._streams))}
        for s in self._streams:
            self._spawn(s)

    @property
    def devices(self) -> tuple:
        return tuple(s.device for s in self._streams)

    def __len__(self) -> int:
        return len(self._streams)

    def _spawn(self, s: _Stream) -> None:
        t = threading.Thread(target=self._run, args=(s,),
                             name=f"device-stream-{s.index}", daemon=True)
        with self._lock:
            s.thread = t
        t.start()

    # -- placement -----------------------------------------------------------

    # holds: _lock
    def _place(self, flows: int, orphans: list) -> _Stream | None:
        """Pick the stream for a new chunk: least pending flows among live
        breaker-CLOSED streams. A quarantined (breaker-OPEN) stream whose
        cooldown elapsed takes the chunk as its reinstatement probe —
        recovery needs traffic. Workers found dead are reaped here (the
        standalone detection fix: their FIFOs migrate or fail instead of
        stranding); ``(future, error)`` pairs the CALLER must resolve
        outside the lock are appended to ``orphans``. Returns ``None``
        when no stream can take work — the caller degrades to inline
        dispatch."""
        live = []
        for s in self._streams:
            if not s.dead and (s.thread is None or not s.thread.is_alive()):
                exc = RuntimeError(
                    f"device-stream-{s.index} worker found dead (killed "
                    "outside the dispatch handler); chunk could not be "
                    "migrated")
                orphans.extend((f, exc) for f in self._mark_dead(s, None))
            if not s.dead:
                live.append(s)
        if not live:
            return None
        for s in live:
            if s.breaker.state != CLOSED and s.breaker.allow():
                return s               # cooldown elapsed: probe chunk
        ready = [s for s in live if s.breaker.state == CLOSED]
        if not ready:
            return None
        # min pending flows, tie → lowest index (tuple order is stable, and
        # min() keeps the first minimum, so index order IS the tiebreak)
        return min(ready, key=lambda s: s.pending_flows)

    def assert_worker(self) -> None:
        """Sanitizer checkpoint: raise :class:`ThreadAffinityError` unless
        the calling thread is one of this pool's workers (no-op with the
        sanitizer off — the affinities never bind) OR the pool is running
        this chunk inline on the caller's thread (zero-healthy degraded
        mode). The serving layer calls this from its dispatch closures,
        pinning the "ALL plan calls run on device workers" invariant at
        runtime."""
        if getattr(self._inline_tls, "active", False):
            return
        idents = {a.bound_ident for a in self._affinities.values()}
        idents.discard(None)
        if idents and threading.get_ident() not in idents:
            raise ThreadAffinityError(
                f"thread {threading.current_thread().name} is not a "
                "DeviceStreamPool worker")

    def submit(self, fn, flows: int) -> Future:
        """Place ``fn(device)`` on the least-loaded healthy stream; returns
        a Future.

        ``flows`` is the work size used for the load signal — pass the
        chunk's flow count (NOT the padded bucket size: the caller knows
        the real rows, and padding is uniform per bucket anyway).

        With zero healthy streams (every worker dead or quarantined) the
        chunk runs INLINE on this thread — degraded but never deadlocked —
        and ``stats()["inline_dispatches"]`` counts it.
        """
        fut: Future = Future()
        flows = int(flows)
        orphans: list = []
        inline_device = None
        with self._work:
            if self._closed:
                raise RuntimeError("DeviceStreamPool is closed")
            s = self._place(flows, orphans)
            if s is not None:
                s.pending_flows += flows
                s.q.append((fn, flows, fut))
                self._work.notify_all()
            else:
                self._inline_dispatches += 1
                inline_device = self._streams[0].device
        for ofut, oexc in orphans:
            _fail(ofut, oexc)
        if s is None:
            self._inline_tls.active = True
            try:
                if fut.set_running_or_notify_cancel():
                    try:
                        out = fn(inline_device)
                    except BaseException as exc:  # noqa: BLE001
                        fut.set_exception(exc)
                    else:
                        fut.set_result(out)
            finally:
                self._inline_tls.active = False
        return fut

    # -- worker --------------------------------------------------------------

    def _run(self, s: _Stream) -> None:
        self._affinities[s.index].bind()
        item = None
        try:
            while True:
                with self._work:
                    while not s.q and not self._closed:
                        self._work.wait()
                    if not s.q and self._closed:
                        return
                    item = s.q.popleft()
                fn, flows, fut = item
                # chaos hook OUTSIDE the per-dispatch except, deliberately:
                # an injected raise kills this worker exactly like any
                # unexpected error would, exercising the supervision path
                chaos = self.chaos
                if chaos is not None:
                    chaos.fire("stream_dispatch", stream=s.index)
                if not fut.set_running_or_notify_cancel():
                    with self._lock:
                        s.pending_flows -= flows
                    item = None
                    continue
                t0 = time.perf_counter()
                try:
                    out = fn(s.device)
                except BaseException as exc:  # noqa: BLE001 — future carries it
                    with self._lock:
                        s.pending_flows -= flows
                        s.errors += 1
                        s.busy_s += time.perf_counter() - t0
                    fut.set_exception(exc)
                    if s.breaker.record_failure() == OPEN:
                        self._quarantine(s)
                else:
                    with self._lock:
                        s.pending_flows -= flows
                        s.dispatched_chunks += 1
                        s.dispatched_flows += flows
                        s.busy_s += time.perf_counter() - t0
                    fut.set_result(out)
                    s.breaker.record_success()
                item = None
        except BaseException as exc:  # noqa: BLE001 — worker death: supervise
            self._affinities[s.index].release()
            with self._work:
                orphans = [(f, exc) for f in self._mark_dead(s, item)]
            for ofut, oexc in orphans:
                _fail(ofut, oexc)

    def _quarantine(self, s: _Stream) -> None:
        """A live stream's breaker just tripped OPEN: migrate its queued
        chunks to surviving CLOSED streams so they don't wait out the
        cooldown behind a failing device. With no survivor they stay — the
        worker is alive and keeps draining (better than dropping)."""
        with self._work:
            targets = [t for t in self._streams
                       if t is not s and not t.dead
                       and t.thread is not None and t.thread.is_alive()
                       and t.breaker.state == CLOSED]
            if not targets:
                return
            moved = False
            while s.q:
                it = s.q.popleft()
                s.pending_flows -= it[1]
                tgt = min(targets, key=lambda t: t.pending_flows)
                tgt.pending_flows += it[1]
                tgt.q.append(it)
                self._migrated_chunks += 1
                moved = True
            if moved:
                self._work.notify_all()

    # holds: _lock
    def _mark_dead(self, s: _Stream, item) -> list:
        """Reap a dead worker's stream: mark it dead, migrate its FIFO
        (plus the un-started in-hand ``item``, if any) to surviving
        streams, and schedule a respawn with doubling backoff. Returns the
        futures of chunks with nowhere to go — the caller MUST fail them
        outside the lock (resolving futures under it could run arbitrary
        done-callbacks while we hold it)."""
        s.dead = True
        s.crashes += 1
        s.errors += 1
        s.breaker.record_failure()
        doomed = []
        if item is not None:
            s.pending_flows -= item[1]
            if not item[2].done():
                doomed.append(item)
        while s.q:
            it = s.q.popleft()
            s.pending_flows -= it[1]
            if not it[2].done():
                doomed.append(it)
        targets = [t for t in self._streams
                   if t is not s and not t.dead
                   and t.thread is not None and t.thread.is_alive()]
        orphans, moved = [], False
        for it in doomed:
            # a future already RUNNING (death hit between set_running and
            # resolution) cannot be re-run elsewhere — fail it instead
            if targets and not it[2].running():
                tgt = min(targets, key=lambda t: t.pending_flows)
                tgt.pending_flows += it[1]
                tgt.q.append(it)
                self._migrated_chunks += 1
                moved = True
            else:
                orphans.append(it[2])
        if moved:
            self._work.notify_all()
        if not self._closed:
            backoff = min(self.respawn_backoff_s * (2 ** (s.crashes - 1)),
                          self.max_respawn_backoff_s)
            t = threading.Timer(backoff, self._respawn, args=(s,))
            t.daemon = True
            t.start()
        return orphans

    def _respawn(self, s: _Stream) -> None:
        """Backoff-timer callback: bring a dead stream's worker back."""
        with self._lock:
            if self._closed:
                return
            if s.thread is not None and s.thread.is_alive():
                return                 # already healthy (raced a respawn)
            s.dead = False
            s.respawns += 1
        self._spawn(s)

    # -- ops surface ---------------------------------------------------------

    def stats(self) -> dict:
        """``{"count": N, "dead_streams": ..., "per_device": [{...}, ...]}``
        — the ``devices`` section of the unified server ``stats()`` schema.
        Silently-dead workers are detected (and reaped) here too, so the
        stats surface never under-reports ``dead_streams``."""
        elapsed = max(time.perf_counter() - self._t0, 1e-9)
        orphans: list = []
        with self._lock:
            for s in self._streams:
                if (not s.dead
                        and (s.thread is None or not s.thread.is_alive())):
                    exc = RuntimeError(
                        f"device-stream-{s.index} worker found dead at "
                        "stats() time; chunk could not be migrated")
                    orphans.extend(
                        (f, exc) for f in self._mark_dead(s, None))
            doc = {
                "count": len(self._streams),
                "dead_streams": sum(1 for s in self._streams if s.dead),
                "healthy_streams": sum(
                    1 for s in self._streams
                    if not s.dead and s.breaker.state == CLOSED),
                "inline_dispatches": self._inline_dispatches,
                "migrated_chunks": self._migrated_chunks,
                "per_device": [
                    {
                        "device": str(s.device),
                        "dispatched_chunks": s.dispatched_chunks,
                        "dispatched_flows": s.dispatched_flows,
                        "queue_depth": len(s.q),
                        "pending_flows": s.pending_flows,
                        "errors": s.errors,
                        "busy_ms": s.busy_s * 1e3,
                        "utilization": s.busy_s / elapsed,
                        "dead": s.dead,
                        "crashes": s.crashes,
                        "respawns": s.respawns,
                        "state": s.breaker.state,
                    }
                    for s in self._streams
                ],
            }
        for ofut, oexc in orphans:
            _fail(ofut, oexc)
        return doc

    def close(self, timeout: float = 5.0) -> None:
        """Stop accepting work, let queued work finish, join the workers.
        Pending respawn timers see ``_closed`` and stand down."""
        with self._work:
            if self._closed:
                return
            self._closed = True
            self._work.notify_all()
            threads = [s.thread for s in self._streams
                       if s.thread is not None]
        for t in threads:
            t.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _fail(fut: Future, exc: BaseException) -> None:
    """Fail an orphaned chunk future, tolerating a racing cancel/resolve."""
    if fut.done():
        return
    try:
        fut.set_exception(exc)
    except InvalidStateError:
        pass
