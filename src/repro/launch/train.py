"""Distributed training driver: pjit'd train_step with FSDP×TP sharding,
microbatch accumulation, optional cross-pod gradient compression, async
checkpointing and crash recovery.

CLI (real run, small model):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_vl_2b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchConfig, get_config, smoke_config
from repro.models.transformer import init_model, lm_loss
from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule
from repro.train import checkpoint as ckpt_lib

from .mesh import batch_specs, fsdp_axes, named, param_specs

__all__ = ["make_train_step", "train_state_shardings", "TrainLoop"]


def make_train_step(
    cfg: ArchConfig,
    *,
    lr_fn=None,
    remat_policy: str = "nothing",
    microbatches: int = 1,
    grad_compression: str = "none",   # none | bf16
    weight_decay: float = 0.1,
):
    """Build the (params, opt, batch) → (params, opt, metrics) step fn.

    ``microbatches`` > 1 accumulates gradients with a lax.scan over batch
    slices — activation memory drops by the factor, compute unchanged.
    ``grad_compression="bf16"`` casts gradients before the (implicit,
    GSPMD-inserted) cross-pod reduction — halves DCN bytes on the "pod"
    axis at <1e-3 relative gradient error (measured in tests).
    """
    lr_fn = lr_fn or cosine_schedule(3e-4, 200, 10_000)

    def loss_fn(params, batch):
        return lm_loss(cfg, params, batch, remat_policy=remat_policy)

    def train_step(params, opt: AdamWState, batch):
        if microbatches > 1:
            def micro(one):
                return jax.grad(loss_fn)(params, one), loss_fn(params, one)

            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc, loss_acc = carry
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, grads = jax.value_and_grad(loss_fn)(params, mb_batch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(())), jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if grad_compression == "bf16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)

        params, opt, gnorm = adamw_update(
            params, grads, opt, lr=lr_fn(opt.step), weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": opt.step}
        return params, opt, metrics

    return train_step


def train_state_shardings(cfg: ArchConfig, params, mesh):
    """Param + optimizer shardings (m/v inherit param specs — ZeRO-3)."""
    pspecs = param_specs(cfg, params, mesh)
    opt_specs = AdamWState(step=P(), m=pspecs, v=pspecs)
    return named(mesh, pspecs), named(mesh, opt_specs)


class TrainLoop:
    """Fault-tolerant training loop: restore-if-present, periodic async
    checkpointing, simple straggler mitigation via step-time watchdog."""

    def __init__(self, cfg: ArchConfig, mesh, *, ckpt_dir: str | None = None,
                 ckpt_every: int = 50, microbatches: int = 1,
                 remat_policy: str = "nothing", grad_compression: str = "none",
                 dtype=jnp.float32, seed: int = 0):
        self.cfg, self.mesh = cfg, mesh
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        params = init_model(cfg, jax.random.PRNGKey(seed), dtype=dtype)
        opt = adamw_init(params)
        self.param_sh, self.opt_sh = train_state_shardings(cfg, params, mesh)
        self.params = jax.device_put(params, self.param_sh)
        self.opt = jax.device_put(opt, self.opt_sh)
        self.start_step = 0
        self.checkpointer = (
            ckpt_lib.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        )
        if ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
            (self.params, self.opt), self.start_step = ckpt_lib.restore(
                ckpt_dir, (self.params, self.opt),
                shardings=(self.param_sh, self.opt_sh))

        step_fn = make_train_step(cfg, microbatches=microbatches,
                                  remat_policy=remat_policy,
                                  grad_compression=grad_compression)
        self._step = jax.jit(
            step_fn,
            in_shardings=(self.param_sh, self.opt_sh, None),
            out_shardings=(self.param_sh, self.opt_sh, None),
            donate_argnums=(0, 1),
        )
        self.step_times: list[float] = []

    def run(self, batches, steps: int):
        it = iter(batches)
        metrics = None
        for i in range(self.start_step, self.start_step + steps):
            batch = next(it)
            t0 = time.perf_counter()
            self.params, self.opt, metrics = self._step(self.params, self.opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            # straggler watchdog: a step ≫ median indicates a slow/failing
            # worker; at scale this triggers checkpoint-and-reschedule.
            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > 5 * med:
                print(f"[watchdog] step {i} took {dt:.2f}s (median {med:.2f}s) — "
                      "straggler suspected; checkpointing")
                if self.checkpointer:
                    self.checkpointer.save(i + 1, (self.params, self.opt))
            if self.checkpointer and (i + 1) % self.ckpt_every == 0:
                self.checkpointer.save(i + 1, (self.params, self.opt))
        if self.checkpointer:
            self.checkpointer.save(self.start_step + steps, (self.params, self.opt))
            self.checkpointer.wait()
        return metrics


def synthetic_batches(cfg: ArchConfig, batch_size: int, seq: int, seed: int = 0):
    """Synthetic LM token stream (data pipeline stand-in with prefetch=1)."""
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, cfg.vocab_size, size=(batch_size, seq + 1), dtype=np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
        if cfg.frontend_stub and not cfg.encoder_layers:
            batch = {
                "embeds": jnp.asarray(
                    rng.normal(size=(batch_size, seq, cfg.d_model)).astype(np.float32)),
                "labels": batch["labels"],
            }
        elif cfg.encoder_layers:
            dl = min(seq, cfg.max_decoder_len)
            batch = {
                "embeds": jnp.asarray(
                    rng.normal(size=(batch_size, seq, cfg.d_model)).astype(np.float32)),
                "dec_tokens": jnp.asarray(toks[:, :dl]),
                "labels": jnp.asarray(toks[:, 1 : dl + 1]),
            }
        yield batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model")) if n_dev > 1 else (
        jax.make_mesh((1, 1), ("data", "model")))
    loop = TrainLoop(cfg, mesh, ckpt_dir=args.ckpt_dir,
                     microbatches=args.microbatches)
    batches = synthetic_batches(cfg, args.batch, args.seq)
    metrics = loop.run(batches, args.steps)
    print({k: float(v) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
