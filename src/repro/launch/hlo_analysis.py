"""HLO-text analysis: collective bytes with while-loop trip-count accounting.

``compiled.cost_analysis()`` and a flat scrape of ``compiled.as_text()`` both
count a ``lax.scan`` body ONCE — a 96-layer scanned model would look 96×
cheaper than it is. This module parses the HLO into computations, builds the
call graph (to_apply / calls / body / condition / branch_computations),
extracts each while's trip count from its condition's compare-constant, and
multiplies collective bytes by the product of enclosing trip counts.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes_hlo", "parse_computations"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")


def parse_computations(hlo: str) -> dict[str, list[str]]:
    """Split HLO text into {computation_name: [op lines]}."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None:
            m = _COMP_START.match(stripped.strip())
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if stripped.strip() == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    return comps


def _shape_bytes(token: str) -> int:
    m = _SHAPE_RE.search(token)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def _collectives_in(lines: list[str]) -> dict[str, int]:
    """Collective output bytes per op line.

    HLO line form: ``%all-gather.1 = f32[16,1024]{1,0} all-gather(%x), ...``
    — the OUTPUT shape sits between '=' and the op name. Output bytes are
    the wire-cost proxy (for all-gather the output is the gathered tensor;
    for reduce-scatter it's the scattered shard — both what the link moves
    per participant, up to the (n-1)/n ring factor we fold into the model).
    """
    out: dict[str, int] = defaultdict(int)
    pat = re.compile(
        r"=\s*(\(?[^=]*?)\b(" + "|".join(COLLECTIVES) + r")(?:-start)?\("
    )
    for line in lines:
        if "=" not in line:
            continue
        m = pat.search(line)
        if not m:
            continue
        prefix, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(prefix):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind] += nbytes
    return dict(out)


_CALL_ATTRS = ("to_apply=", "calls=", "body=", "condition=", "branch_computations=")


def _callees(lines: list[str]) -> dict[str, list[str]]:
    """{attr_kind: [computation names]} referenced by this computation."""
    refs: dict[str, list[str]] = defaultdict(list)
    for line in lines:
        for attr in _CALL_ATTRS:
            for m in re.finditer(re.escape(attr) + r"\{?%?([\w\.\-]+)", line):
                refs[attr.rstrip("=")].append(m.group(1))
        # branch_computations={%a, %b, ...}
        m = re.search(r"branch_computations=\{([^}]*)\}", line)
        if m:
            for name in re.findall(r"%?([\w\.\-]+)", m.group(1)):
                refs["branch_computations"].append(name)
    return refs


def _while_trip_counts(comps: dict[str, list[str]]) -> dict[str, int]:
    """{body_comp_name: trip_count} for every while op, from its condition."""
    trips: dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            if " while(" not in line and "while(" not in line:
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if not mb or not mc:
                continue
            cond_lines = comps.get(mc.group(1), [])
            consts = []
            for cl in cond_lines:
                if "constant(" in cl and ("compare" in cl or True):
                    consts += [int(x) for x in re.findall(r"constant\((\d+)\)", cl)]
            trips[mb.group(1)] = max(consts) if consts else 1
    return trips


def collective_bytes_hlo(hlo: str) -> dict[str, float]:
    """Per-device collective bytes by kind, with while-trip multiplication."""
    comps = parse_computations(hlo)
    trips = _while_trip_counts(comps)

    # effective multiplier per computation (BFS through the call graph)
    entry = None
    for name in comps:
        if ".main" in name or name.startswith("main"):
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological-ish relaxation (call graphs are acyclic in HLO)
    frontier = [entry]
    seen_edges = set()
    while frontier:
        cur = frontier.pop()
        refs = _callees(comps[cur])
        for kind, names in refs.items():
            for name in names:
                if name not in comps:
                    continue
                factor = trips.get(name, 1) if kind == "body" else 1
                edge = (cur, name, kind)
                if edge in seen_edges:
                    continue
                seen_edges.add(edge)
                mult[name] += mult[cur] * factor
                frontier.append(name)

    totals: dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for kind, nbytes in _collectives_in(lines).items():
            totals[kind] += m * nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return dict(totals)
