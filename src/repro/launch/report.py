"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from sweep JSONs.

Usage:
  PYTHONPATH=src python -m repro.launch.report \
      --single results_dryrun_single.json [--patch results_dryrun_moefix.json] \
      --multi results_dryrun_multi.json --out roofline_report.md
"""

from __future__ import annotations

import argparse
import json

from repro.configs.registry import ARCH_IDS, SHAPES, get_config
from repro.launch.roofline import V5E, format_row, roofline_terms


def load_results(single: str, patch: str | None = None) -> dict:
    with open(single) as f:
        rows = json.load(f)
    table = {(r["arch"], r["shape"]): r for r in rows}
    if patch:
        with open(patch) as f:
            for r in json.load(f):
                table[(r["arch"], r["shape"])] = r
    return table


def dryrun_table(results: dict, mesh_label: str) -> list[str]:
    lines = [
        f"### Mesh {mesh_label}",
        "",
        "| arch | shape | compile (s) | HLO flops (raw) | collective B/dev "
        "(while-corrected) | peak HBM/dev | status |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = results.get((arch, shape))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | — | SKIP ({r['skipped'][:40]}…) |")
            elif "error" in r:
                lines.append(f"| {arch} | {shape} | — | — | — | — | **FAIL** {r['error'][:60]} |")
            else:
                pk = r["memory"]["peak_bytes"] / 2**30
                lines.append(
                    f"| {arch} | {shape} | {r['compile_s']} | {r['flops']:.2e} | "
                    f"{r['collective_total']:.2e} | {pk:.1f} GiB | ok |")
    return lines


def roofline_table(results: dict) -> list[str]:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    picked = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            r = results.get((arch, shape))
            if r is None or "skipped" in r or "error" in r:
                continue
            terms = roofline_terms(cfg, shape, r["collective_total"])
            lines.append(format_row(arch, shape, terms))
            picked.append((arch, shape, terms))
    return lines


def narrative(results: dict) -> list[str]:
    """One sentence per cell on what would move the dominant term."""
    hints = {
        ("compute", "train"): "more chips / lower remat recompute (dots policy)",
        ("compute", "prefill"): "batch growth amortizes weight gathers; MXU already saturated",
        ("compute", "decode"): "batch up decode or fuse kernels; compute rarely dominates decode",
        ("memory", "train"): "microbatching + sequence-sharded activations cut HBM traffic",
        ("memory", "prefill"): "chunked attention + bf16 activations",
        ("memory", "decode"): "KV-cache/LUT quantization (int8) halves bytes — the Pegasus lever",
        ("collective", "train"): "overlap FSDP gathers with compute; bf16 grad reduce; bigger per-device batch",
        ("collective", "prefill"): "re-shard activations to cut resharding all-gathers",
        ("collective", "decode"): "replicate small weights instead of gathering per step",
    }
    lines = ["", "Per-cell notes (what moves the dominant term):", ""]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            r = results.get((arch, shape))
            if r is None or "skipped" in r or "error" in r:
                continue
            t = roofline_terms(cfg, shape, r["collective_total"])
            kind = SHAPES[shape][2]
            lines.append(f"- **{arch} × {shape}** ({t['dominant']}-bound): "
                         f"{hints[(t['dominant'], kind)]}.")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", required=True)
    ap.add_argument("--patch", default=None)
    ap.add_argument("--multi", default=None)
    ap.add_argument("--out", default="roofline_report.md")
    args = ap.parse_args()

    single = load_results(args.single, args.patch)
    out = ["## §Dry-run", ""]
    out += dryrun_table(single, "16×16 (single pod, 256 chips)")
    if args.multi:
        multi = load_results(args.multi)
        out += [""]
        out += dryrun_table(multi, "2×16×16 (two pods, 512 chips)")
    out += ["", "## §Roofline (single pod)", ""]
    out += roofline_table(single)
    out += narrative(single)
    with open(args.out, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
