"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices stand in for 2 pods × 256 chips; ``.lower().compile()``
must succeed for every supported cell, and the compiled artifact yields the
memory/cost analyses §Roofline reads.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --json out.json
"""

# MUST precede any jax import (device count locks on first init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, SHAPES, get_config
from repro.models.transformer import init_model
from repro.train.optimizer import adamw_init

from .mesh import (
    batch_specs, decode_state_specs, make_production_mesh, named, param_specs,
)
from .hlo_analysis import collective_bytes_hlo
from .specs import input_specs, skip_reason
from .train import make_train_step, train_state_shardings
from .serve import make_prefill_step, make_serve_step

__all__ = ["dryrun_cell", "main"]


def _param_structs(cfg, dtype=jnp.bfloat16):
    """Shape-only params (no allocation!)."""
    return jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0), dtype=dtype))


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.

    Parses shapes like ``bf16[16,512,128]`` on lines whose op is
    all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute. Returns bytes per collective kind.
    """
    dbytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
              "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
              "f8e5m2": 1, "s16": 2, "u16": 2}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    shape_re = re.compile(r"(f32|bf16|f16|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|f8e4m3|f8e5m2)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match ' = TYPE[SHAPE] all-gather(' style ops (skip -start/-done fusions)
        m = re.search(r"=\s*[^=]*?\b(" + "|".join(kinds) + r")(?:-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        # first shape on the line = output shape (good operand-size proxy;
        # for all-gather output > input — we take OUTPUT bytes, the wire cost)
        shapes = shape_re.findall(stripped.split("=")[0]) or shape_re.findall(stripped)
        if not shapes:
            continue
        dt, dims = shapes[0]
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out[kind] += n * dbytes.get(dt, 4)
    return out


def dryrun_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    remat_policy: str = "nothing",
    microbatches: int = 1,
    seq_parallel_attn: bool = False,
    layer_seq_shard: bool = False,
    cache_seq_shard: bool = False,
    decode_replicated_batch: bool = False,
    decode_feature_shard: bool = False,
    prefill_last_only: bool = False,
    optimized: bool = False,
    include_text: bool = False,
    extra_tags: dict | None = None,
) -> dict:
    """Lower + compile one cell; return roofline-relevant artifacts.

    ``optimized=True`` applies the per-kind winning configuration from the
    EXPERIMENTS.md §Perf hillclimbs:
      train   → microbatches=8 (plain FSDP×TP attention — SP refuted for train)
      prefill → last-token head + seq-parallel attention + SP layer boundaries
      decode  → split-KV cache sharding + weight-stationary 2D-TP activations
    """
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}
    if optimized:
        kind_ = SHAPES[shape_name][2]
        if kind_ == "train":
            microbatches = max(microbatches, 8)
        elif kind_ == "prefill":
            prefill_last_only = True
            seq_parallel_attn = True
            layer_seq_shard = True
        else:
            cache_seq_shard = True
            decode_feature_shard = True

    from repro.models import attention as attn_mod
    from repro.models import transformer as tf_mod

    attn_mod.SEQ_PARALLEL_ATTN = seq_parallel_attn
    tf_mod.LAYER_SEQ_SHARD = layer_seq_shard
    tf_mod.DECODE_FEATURE_SHARD = decode_feature_shard
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq, gb, kind = SHAPES[shape_name]
    t0 = time.time()

    params_s = _param_structs(cfg)
    pspec_sh = named(mesh, param_specs(cfg, params_s, mesh))

    with mesh:
        if kind == "train":
            opt_s = jax.eval_shape(lambda: adamw_init(params_s))
            _, opt_sh = train_state_shardings(cfg, params_s, mesh)
            batch = input_specs(arch, shape_name)
            batch_sh = named(mesh, batch_specs(cfg, batch, mesh, batch_size=gb))
            step = make_train_step(cfg, remat_policy=remat_policy,
                                   microbatches=microbatches)
            lowered = jax.jit(
                step,
                in_shardings=(pspec_sh, opt_sh, batch_sh),
                out_shardings=(pspec_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_s, opt_s, batch)
        elif kind == "prefill":
            batch = input_specs(arch, shape_name)
            batch_sh = named(mesh, batch_specs(cfg, batch, mesh, batch_size=gb))
            step = make_prefill_step(cfg, last_only=prefill_last_only)
            lowered = jax.jit(
                step, in_shardings=(pspec_sh, batch_sh), out_shardings=None,
            ).lower(params_s, batch)
        else:  # decode
            specs = input_specs(arch, shape_name)
            state_s = specs["state"]
            state_sh = named(
                mesh, decode_state_specs(cfg, state_s, mesh, batch_size=gb,
                                         cache_seq_shard=cache_seq_shard))
            step = make_serve_step(cfg)
            args = (params_s, state_s, specs["tokens"], specs["pos"])
            tok_sh = None
            if decode_replicated_batch:
                # tokens/activations replicated; weights stay 2D-sharded →
                # tiny activation all-reduces replace per-step weight gathers
                from jax.sharding import NamedSharding, PartitionSpec as P
                tok_sh = NamedSharding(mesh, P(None, None))
            in_sh = (pspec_sh, state_sh, tok_sh, None)
            if "enc_out" in specs:
                args = args + (specs["enc_out"],)
                in_sh = in_sh + (None,)
            lowered = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(None, state_sh),
                donate_argnums=(1,),
            ).lower(*args)

        compiled = lowered.compile()

    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    hlo = compiled.as_text()
    coll = collective_bytes_hlo(hlo)        # while-trip-aware (see hlo_analysis)
    coll_flat = collective_bytes(hlo)       # naive single-count, for reference

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": {k: v for k, v in coll.items() if k != "total"},
        "collective_total": int(coll.get("total", 0)),
        "collective_total_uncorrected": int(sum(coll_flat.values())),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
            ),
        },
    }
    if extra_tags:
        result.update(extra_tags)
    if include_text:
        result["hlo"] = hlo
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--json", default=None, help="append results to this file")
    ap.add_argument("--seq-parallel-attn", action="store_true")
    ap.add_argument("--layer-seq-shard", action="store_true")
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--decode-replicated-batch", action="store_true")
    ap.add_argument("--decode-feature-shard", action="store_true")
    ap.add_argument("--prefill-last-only", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="per-kind winning flags from §Perf")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    fail = 0
    for arch, shape in cells:
        try:
            r = dryrun_cell(arch, shape, multi_pod=args.multi_pod,
                            remat_policy=args.remat,
                            microbatches=args.microbatches,
                            seq_parallel_attn=args.seq_parallel_attn,
                            layer_seq_shard=args.layer_seq_shard,
                            cache_seq_shard=args.cache_seq_shard,
                            decode_replicated_batch=args.decode_replicated_batch,
                            decode_feature_shard=args.decode_feature_shard,
                            prefill_last_only=args.prefill_last_only,
                            optimized=args.optimized)
        except Exception as e:  # noqa: BLE001 — report, continue, fail at end
            r = {"arch": arch, "shape": shape, "error": f"{type(e).__name__}: {e}"}
            fail += 1
        tag = ("SKIP" if "skipped" in r else
               "FAIL" if "error" in r else "ok")
        summary = r.get("skipped") or r.get("error") or (
            f"compile={r['compile_s']}s flops={r['flops']:.3e} "
            f"coll={r['collective_total']:.3e}B peak={r['memory']['peak_bytes']/2**30:.1f}GiB")
        print(f"[{tag}] {arch:<20} {shape:<12} {summary}", flush=True)
        results.append(r)

    if args.json:
        existing = []
        if os.path.exists(args.json):
            with open(args.json) as f:
                existing = json.load(f)
        with open(args.json, "w") as f:
            json.dump(existing + results, f, indent=1)
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
