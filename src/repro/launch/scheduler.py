"""Serving scheduler: thread-safe bounded queues + weighted fair queueing.

The dataplane premise is continuous line-rate traffic — requests arrive
whenever they arrive, not when the host happens to call ``drain()``. This
module is the contention-management core the serving layer
(:mod:`repro.launch.serve`) builds on:

  * :class:`WFQScheduler` — owns every per-model request queue behind ONE
    lock. ``submit`` is safe from any thread; ``pull_round`` hands the
    dispatcher (the sync ``drain()`` loop or the async background thread)
    the next slice of work according to **deficit round-robin** (DRR), the
    classic O(1) weighted-fair-queueing realization: per round, each
    backlogged model's deficit counter grows by ``quantum x weight`` and the
    model releases queued requests until the counter is spent. Under
    saturation every model's served flows/s converge to its weight share —
    a 4:1 weight skew is a 4:1 flow share — while an idle model's credit
    resets (no banking unused bandwidth). Requests are the atomic pull
    unit; the dispatcher cuts each pulled slice into bucket-aligned
    micro-batches (``repro.engine.bucket_chunks``), so deficit accounting
    in flows is exactly accounting in micro-batch work.
  * **Priority classes** — named weights (:data:`PRIORITY_WEIGHTS`:
    ``high=4, normal=1, low=0.25``). Within a DRR round, backlogged models
    are visited in descending-weight order (stable on ties), so a
    high-priority model's requests both dispatch earlier in every round and
    get a larger flow share across rounds: its queue-wait percentiles sit
    strictly below a low-priority model's under saturation.
  * **Backpressure** — queues are optionally bounded (``depth``). Policy
    ``"reject"`` fails an over-limit ``submit`` immediately with
    :class:`QueueFullError`; ``"block"`` parks the submitting thread until
    the dispatcher frees space (or ``timeout`` elapses, then
    ``QueueFullError``). Unbounded (``depth=None``) keeps the PR-3
    submit-never-fails behavior for the synchronous server.
  * **Latency instrumentation** — every request is stamped at submit;
    ``pull_round`` stamps a PROVISIONAL dispatch time, and the dispatcher
    may re-stamp ``t_dispatch`` when the slice actually starts dispatching
    (``MultiModelServer._begin_group`` does — a round's groups run
    sequentially, so later groups keep waiting past their pull) before
    reporting the slice's service wall time via :meth:`record_service`.
    Per-model bounded reservoirs yield queue-wait / service-time
    percentiles (:meth:`latency_stats`) — the observable the WFQ tests and
    the ``async_serve`` bench gate assert on.

The scheduler never touches a plan: dispatching (every compiled-plan call)
stays in the server, so the async runtime funnels plan execution through
one thread while ingestion fans across many (producers pay only the queue
lock and their own inputs' host→device staging).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

__all__ = [
    "LATENCY_WINDOW",
    "PRIORITY_WEIGHTS",
    "ModelQueue",
    "QueueFullError",
    "WFQScheduler",
]

# priority class → WFQ weight; an explicit float weight overrides the class
PRIORITY_WEIGHTS = {"high": 4.0, "normal": 1.0, "low": 0.25}

# per-model reservoir size for queue-wait / service-time samples: percentiles
# over the last ~2k requests, bounded so a long-lived server never grows it
LATENCY_WINDOW = 2048

# weights are clamped ≥ this: a zero weight would never accumulate deficit
# and its backlogged queue could never release an oversize request
_MIN_WEIGHT = 1e-3

# distinguishes "depth not passed" from the legitimate depth=None (unbounded)
_UNSET = object()


def _resolve_weight(weight: float | None, priority: str | None) -> float:
    """weight/priority → clamped WFQ weight; explicit weight wins."""
    if weight is None:
        if priority is not None and priority not in PRIORITY_WEIGHTS:
            raise ValueError(
                f"unknown priority {priority!r}; expected one of "
                f"{sorted(PRIORITY_WEIGHTS)} (or pass weight=)")
        weight = PRIORITY_WEIGHTS[priority or "normal"]
    return max(float(weight), _MIN_WEIGHT)


class QueueFullError(RuntimeError):
    """A bounded model queue rejected (or timed out blocking on) a submit."""


class _Request:
    """One queued request: the input tuple plus its lifecycle stamps."""

    __slots__ = ("inputs", "size", "future", "t_submit", "t_dispatch")

    def __init__(self, inputs: tuple, size: int, future: Future | None):
        self.inputs = inputs
        self.size = size
        self.future = future
        self.t_submit = time.perf_counter()
        self.t_dispatch = 0.0


class ModelQueue:
    """One model's FIFO + its scheduling config. All access goes through the
    owning :class:`WFQScheduler`'s lock — this class adds no locking."""

    __slots__ = ("name", "weight", "depth", "policy", "reqs")

    def __init__(self, name: str, *, weight: float = 1.0,
                 depth: int | None = None, policy: str = "block"):
        if policy not in ("block", "reject"):
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             "expected 'block' or 'reject'")
        if depth is not None and depth < 1:
            raise ValueError(f"queue depth must be ≥ 1 or None, got {depth}")
        self.name = name
        self.weight = max(float(weight), _MIN_WEIGHT)
        self.depth = depth
        self.policy = policy
        self.reqs: deque[_Request] = deque()


class WFQScheduler:
    """Thread-safe request queues scheduled by deficit round-robin.

    One lock guards the queue map, every queue's deque, the deficit
    counters, and the latency reservoirs; the two conditions share it
    (``_space``: submitters blocked on a full queue; ``_work``: a dispatcher
    waiting for anything to do). Plan dispatch happens OUTSIDE the lock —
    ``pull_round`` pops requests and returns, so a multi-millisecond XLA
    call never blocks ingestion.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._space = threading.Condition(self._lock)
        self._work = threading.Condition(self._lock)
        self._queues: dict[str, ModelQueue] = {}
        self._deficit: dict[str, float] = {}
        self._latency: dict[str, dict] = {}

    # -- queue management ---------------------------------------------------

    def add_queue(self, name: str, *, weight: float | None = None,
                  priority: str | None = None, depth=_UNSET,
                  policy: str | None = None) -> ModelQueue:
        """Create the queue for ``name`` (``priority`` names a class in
        :data:`PRIORITY_WEIGHTS`; an explicit ``weight`` wins). If the
        queue already exists, any EXPLICITLY-passed field is applied to it
        via :meth:`configure` (so re-registering a model with a new
        priority, bound, or policy is honored)."""
        w = _resolve_weight(weight, priority)
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                q = ModelQueue(name, weight=w,
                               depth=None if depth is _UNSET else depth,
                               policy=policy or "block")
                self._queues[name] = q
                self._deficit[name] = 0.0
            else:
                if weight is not None or priority is not None:
                    q.weight = w
                if depth is not _UNSET or policy is not None:
                    self.configure(name, depth=depth, policy=policy)
            return q

    def configure(self, name: str, *, weight: float | None = None,
                  priority: str | None = None, depth=_UNSET,
                  policy: str | None = None) -> None:
        """Re-configure a live queue; only explicitly-passed fields change
        (``depth=None`` means unbounded, so absence is a sentinel)."""
        with self._lock:
            q = self._queues[name]
            if weight is not None or priority is not None:
                q.weight = _resolve_weight(weight, priority)
            if depth is not _UNSET:
                if depth is not None and depth < 1:
                    raise ValueError(
                        f"queue depth must be ≥ 1 or None, got {depth}")
                q.depth = depth
                self._space.notify_all()     # a raised bound frees submitters
            if policy is not None:
                if policy not in ("block", "reject"):
                    raise ValueError(
                        f"unknown backpressure policy {policy!r}; expected "
                        "'block' or 'reject'")
                q.policy = policy

    def remove_queue(self, name: str) -> list[_Request]:
        """Drop a queue; returns its still-pending requests so the caller can
        fail their futures."""
        with self._lock:
            q = self._queues.pop(name, None)
            self._deficit.pop(name, None)
            self._latency.pop(name, None)
            if q is None:
                return []
            reqs = list(q.reqs)
            q.reqs.clear()
            # anyone blocked submitting to this queue must wake and notice
            self._space.notify_all()
            return reqs

    def set_weight(self, name: str, *, weight: float | None = None,
                   priority: str | None = None) -> float:
        """Re-class a live queue (takes effect next DRR round). One of
        ``weight``/``priority`` is required — a bare call must not silently
        demote the queue to the normal class."""
        if weight is None and priority is None:
            raise ValueError("pass weight= or priority= (a bare set_weight "
                             "would silently reset to the normal class)")
        with self._lock:
            q = self._queues[name]
            q.weight = _resolve_weight(weight, priority)
            return q.weight

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._queues

    def pending(self) -> dict[str, int]:
        with self._lock:
            return {n: len(q.reqs) for n, q in self._queues.items() if q.reqs}

    def describe(self) -> dict:
        """Static scheduling config + live backlog (the stats surface)."""
        with self._lock:
            return {
                name: {"weight": q.weight, "depth": q.depth,
                       "policy": q.policy, "pending": len(q.reqs)}
                for name, q in sorted(self._queues.items())
            }

    # -- ingestion ----------------------------------------------------------

    def submit(self, name: str, inputs: tuple, size: int, *,
               future: Future | None = None,
               timeout: float | None = None) -> int:
        """Enqueue one request; returns its queue position at append time.
        Backpressure per the queue's policy: ``reject`` raises
        :class:`QueueFullError` when full; ``block`` waits for space up to
        ``timeout`` seconds (``None`` = forever), then raises."""
        with self._lock:
            q = self._queues[name]
            if q.depth is not None and len(q.reqs) >= q.depth:
                if q.policy == "reject":
                    raise QueueFullError(
                        f"queue for {name!r} full ({q.depth} pending, "
                        "policy=reject)")
                deadline = (None if timeout is None
                            else time.monotonic() + timeout)
                # re-check depth each wake: configure() may have lifted the
                # bound to None (unbounded) while this submitter slept
                while q.depth is not None and len(q.reqs) >= q.depth:
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise QueueFullError(
                            f"queue for {name!r} still full ({q.depth} "
                            f"pending) after blocking {timeout}s")
                    self._space.wait(remaining)
                    if name not in self._queues:   # removed while we slept
                        raise KeyError(
                            f"model {name!r} was removed while its queue "
                            "was full")
                    q = self._queues[name]
            req = _Request(inputs, int(size), future)
            q.reqs.append(req)
            self._work.notify_all()
            return len(q.reqs) - 1

    def requeue_front(self, name: str, reqs: list[_Request]) -> None:
        """Put a failed slice back at the FRONT of its queue, in order —
        the sync drain's retry semantics (counters untouched, FIFO kept)."""
        if not reqs:
            return
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                return
            q.reqs.extendleft(reversed(reqs))
            self._work.notify_all()

    def discard(self, name: str) -> list[_Request]:
        """Clear a queue (poisoned-request escape hatch); returns the dropped
        requests so the caller can fail their futures. The queue's deficit
        resets with it — an emptied queue must not bank credit (an oversize
        head may have inflated it via the catch-up jump)."""
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                return []
            reqs = list(q.reqs)
            q.reqs.clear()
            self._deficit[name] = 0.0
            self._space.notify_all()
            return reqs

    # -- scheduling ---------------------------------------------------------

    def pull_round(self, quantum: float,
                   exclude: frozenset | set = frozenset()
                   ) -> list[tuple[str, list[_Request]]]:
        """One deficit-round-robin round: every backlogged model (minus
        ``exclude``), in descending-weight order, earns ``quantum x weight``
        credit and releases FIFO requests while the next one fits.

        Guarantees progress: if no backlogged head fits its credit this
        round (a request larger than one quantum), every backlogged queue
        is advanced the minimal whole number of rounds that lets SOME head
        fit — one O(1) jump instead of busy-looping round by round under
        the lock, with the same weight-proportional credit each queue would
        have earned. A model whose queue empties forfeits leftover credit
        (classic DRR: idle models don't bank bandwidth). Returns
        ``[(name, [requests]), ...]`` in dispatch order; empty means
        nothing eligible is pending.
        """
        with self._lock:
            out: list[tuple[str, list[_Request]]] = []
            while not out:
                backlogged = [q for q in self._queues.values()
                              if q.reqs and q.name not in exclude]
                if not backlogged:
                    break
                # descending weight, stable on ties (dict = insertion order)
                backlogged.sort(key=lambda q: -q.weight)
                now = time.perf_counter()
                for q in backlogged:
                    credit = self._deficit[q.name] + quantum * q.weight
                    pulled: list[_Request] = []
                    while q.reqs and q.reqs[0].size <= credit:
                        r = q.reqs.popleft()
                        credit -= r.size
                        r.t_dispatch = now
                        pulled.append(r)
                    # empty queue forfeits credit; a backlogged one keeps it
                    self._deficit[q.name] = credit if q.reqs else 0.0
                    if pulled:
                        out.append((q.name, pulled))
                if not out:
                    # every head is oversize: jump the minimal number of
                    # extra rounds (per-queue credit stays ∝ weight)
                    k = max(1, min(
                        -(-(q.reqs[0].size - self._deficit[q.name])
                          // (quantum * q.weight))
                        for q in backlogged))
                    for q in backlogged:
                        self._deficit[q.name] += k * quantum * q.weight
            if out:
                self._space.notify_all()
            return out

    def wait_for_work(self, timeout: float | None) -> bool:
        """Park until any queue is non-empty (or timeout); returns whether
        work is pending. The async drain loop's idle wait."""
        with self._lock:
            if any(q.reqs for q in self._queues.values()):
                return True
            self._work.wait(timeout)
            return any(q.reqs for q in self._queues.values())

    def kick(self) -> None:
        """Wake a parked dispatcher (used by stop())."""
        with self._lock:
            self._work.notify_all()

    # -- latency instrumentation --------------------------------------------

    def record_service(self, name: str, reqs: list[_Request],
                       service_ms: float) -> None:
        """Fold one served slice into the reservoirs: each request's
        queue-wait (submit → pull) and the slice's service wall time."""
        with self._lock:
            lat = self._latency.get(name)
            if lat is None:
                lat = self._latency[name] = {
                    "queue_wait_ms": deque(maxlen=LATENCY_WINDOW),
                    "service_ms": deque(maxlen=LATENCY_WINDOW),
                }
            for r in reqs:
                lat["queue_wait_ms"].append(
                    (r.t_dispatch - r.t_submit) * 1e3)
                lat["service_ms"].append(service_ms)

    def reset_latency(self) -> None:
        """Drop the reservoirs (benchmarks reset after warmup)."""
        with self._lock:
            self._latency.clear()

    def latency_stats(self) -> dict:
        """Per-model queue-wait + service-time percentiles over the
        reservoir window."""
        with self._lock:
            snap = {name: {k: list(v) for k, v in lat.items()}
                    for name, lat in self._latency.items()}
        out = {}
        for name, lat in sorted(snap.items()):
            entry = {"samples": len(lat["queue_wait_ms"])}
            for key, samples in lat.items():
                if samples:
                    p50, p90, p99 = np.percentile(
                        np.asarray(samples, np.float64), [50, 90, 99])
                    entry[key] = {"p50": round(float(p50), 3),
                                  "p90": round(float(p90), 3),
                                  "p99": round(float(p99), 3)}
            out[name] = entry
        return out
