"""Serving scheduler: thread-safe bounded queues + weighted fair queueing.

The dataplane premise is continuous line-rate traffic — requests arrive
whenever they arrive, not when the host happens to call ``drain()``. This
module is the contention-management core the serving layer
(:mod:`repro.launch.serve`) builds on:

  * :class:`WFQScheduler` — owns every per-model request queue behind ONE
    lock. ``submit`` is safe from any thread; ``pull_round`` hands the
    dispatcher (the sync ``drain()`` loop or the async background thread)
    the next slice of work according to **deficit round-robin** (DRR), the
    classic O(1) weighted-fair-queueing realization: per round, each
    backlogged model's deficit counter grows by ``quantum x weight`` and the
    model releases queued requests until the counter is spent. Under
    saturation every model's served flows/s converge to its weight share —
    a 4:1 weight skew is a 4:1 flow share — while an idle model's credit
    resets (no banking unused bandwidth). Requests are the atomic pull
    unit; the dispatcher cuts each pulled slice into bucket-aligned
    micro-batches (``repro.engine.bucket_chunks``), so deficit accounting
    in flows is exactly accounting in micro-batch work.
  * **Priority classes** — named weights (:data:`PRIORITY_WEIGHTS`:
    ``high=4, normal=1, low=0.25``). Within a DRR round, backlogged models
    are visited in descending-weight order (stable on ties), so a
    high-priority model's requests both dispatch earlier in every round and
    get a larger flow share across rounds: its queue-wait percentiles sit
    strictly below a low-priority model's under saturation.
  * **Backpressure** — queues are optionally bounded (``depth``). Policy
    ``"reject"`` fails an over-limit ``submit`` immediately with
    :class:`QueueFullError`; ``"block"`` parks the submitting thread until
    the dispatcher frees space (or ``timeout`` elapses, then
    ``QueueFullError``). Unbounded (``depth=None``) keeps the PR-3
    submit-never-fails behavior for the synchronous server.
  * **Deadlines + slack-based shedding** — a request may carry a
    ``deadline_ms`` budget (milliseconds from submit to completion). At
    pull time a queue head whose queue-wait already exceeds its *slack*
    (``deadline_ms`` minus the model's EWMA slice service time) is SHED
    instead of dispatched: its future fails with a typed
    :class:`DeadlineExceededError` and the dispatcher never sees it —
    under overload the scheduler spends capacity only on requests that can
    still finish in time, so goodput-within-deadline plateaus at capacity
    instead of collapsing to zero as every queue ages past its budget.
  * **Admission control** — the reservoirs observe each model's service
    rate (EWMA flows/s), so at submit time the backlog already queued
    predicts the newcomer's queue-wait. A deadline-bearing request whose
    predicted wait exceeds its own budget is rejected up front
    (:class:`DeadlineExceededError` — fail fast, don't queue doomed work),
    and a queue configured with ``admit_ms`` caps its backlog at
    ``service_rate x admit_ms`` worth of flows for ALL requests
    (:class:`QueueFullError`): the backlog cap derives from measured
    capacity, not a guessed depth.
  * **SLO counters** — per-model ``admitted`` / ``rejected`` / ``shed`` /
    ``goodput_flows`` / ``late_flows`` counters (:meth:`counters`) plus
    starvation metrics (current head wait and max observed wait) that make
    a weight≫1 skew's starvation of low-weight queues measurable.
  * **Latency instrumentation** — every request is stamped at submit;
    ``pull_round`` stamps a PROVISIONAL dispatch time, and the dispatcher
    may re-stamp ``t_dispatch`` when the slice actually starts dispatching
    (``MultiModelServer._begin_group`` does — a round's groups run
    sequentially, so later groups keep waiting past their pull) before
    reporting the slice's service wall time via :meth:`record_service`.
    Per-model bounded reservoirs yield queue-wait / service-time
    percentiles (:meth:`latency_stats`) — the observable the WFQ tests and
    the ``async_serve`` bench gate assert on.

The scheduler never touches a plan: dispatching (every compiled-plan call)
stays in the server, so the async runtime funnels plan execution through
one thread while ingestion fans across many (producers pay only the queue
lock and their own inputs' host→device staging).
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future

from repro.analysis.sanitizer import make_lock

import numpy as np

__all__ = [
    "LATENCY_WINDOW",
    "PRIORITY_RANK",
    "PRIORITY_WEIGHTS",
    "DeadlineExceededError",
    "ModelQueue",
    "QueueFullError",
    "WFQScheduler",
]

# priority class → WFQ weight; an explicit float weight overrides the class
PRIORITY_WEIGHTS = {"high": 4.0, "normal": 1.0, "low": 0.25}

# per-model reservoir size for queue-wait / service-time samples: percentiles
# over the last ~2k requests, bounded so a long-lived server never grows it
LATENCY_WINDOW = 2048

# weights are clamped ≥ this: a zero weight would never accumulate deficit
# and its backlogged queue could never release an oversize request
_MIN_WEIGHT = 1e-3

# distinguishes "depth not passed" from the legitimate depth=None (unbounded)
_UNSET = object()


def _resolve_weight(weight: float | None, priority: str | None) -> float:
    """weight/priority → clamped WFQ weight; explicit weight wins."""
    if weight is None:
        if priority is not None and priority not in PRIORITY_WEIGHTS:
            raise ValueError(
                f"unknown priority {priority!r}; expected one of "
                f"{sorted(PRIORITY_WEIGHTS)} (or pass weight=)")
        weight = PRIORITY_WEIGHTS[priority or "normal"]
    return max(float(weight), _MIN_WEIGHT)


class QueueFullError(RuntimeError):
    """A bounded model queue rejected (or timed out blocking on) a submit.

    Also raised by rate-based admission control when a queue configured
    with ``admit_ms`` already holds more backlog than its observed service
    rate can clear within that horizon."""


class DeadlineExceededError(RuntimeError):
    """A deadline-bearing request was shed (or refused admission).

    Raised on the request's future when its queue-wait exceeded its slack
    at pull time (``deadline_ms`` minus the model's EWMA service time —
    dispatching it would only produce a late, worthless verdict), or
    synchronously from ``submit`` when admission control predicts the
    backlog already queued makes the deadline unreachable. Either way the
    request NEVER dispatches: no plan call, no counters committed beyond
    the shed/rejected tallies."""


# EWMA smoothing for the per-model service-rate / service-time estimates
# that drive admission control and shed slack. 0.3 ≈ "the last ~5 slices
# dominate": fast enough to track a recompile or host-throttle shift,
# smooth enough that one outlier slice cannot swing admission decisions.
_EWMA_ALPHA = 0.3


#: Per-REQUEST urgency rank within one model's queue (orthogonal to the
#: per-model PRIORITY_WEIGHTS class that sets the cross-model WFQ share):
#: a submit with a higher rank queue-jumps ahead of strictly-lower-rank
#: entries, FIFO among equals.
PRIORITY_RANK = {"low": 0, "normal": 1, "high": 2}


class _Request:
    """One queued request: the input tuple plus its lifecycle stamps.
    ``deadline_ms`` is the completion budget in milliseconds from submit
    (None = no deadline: never shed, never admission-checked); ``rank``
    is the per-request urgency (:data:`PRIORITY_RANK`)."""

    __slots__ = ("inputs", "size", "future", "deadline_ms",
                 "t_submit", "t_dispatch", "rank", "requeues")

    def __init__(self, inputs: tuple, size: int, future: Future | None,
                 deadline_ms: float | None = None, rank: int = 1):
        self.inputs = inputs
        self.size = size
        self.future = future
        self.deadline_ms = deadline_ms
        self.t_submit = time.perf_counter()
        self.t_dispatch = 0.0
        self.rank = rank
        # failure-retry count (bounded by the server's max_requeues; a
        # request past the cap fails typed PoisonedRequestError) — bumped
        # by the dispatch thread only, between scheduler ownership spans
        self.requeues = 0


class ModelQueue:
    """One model's FIFO + its scheduling config. All access goes through the
    owning :class:`WFQScheduler`'s lock — this class adds no locking.
    ``flows`` tracks the queued backlog in flows (sum of request sizes) so
    admission control predicts queue-wait in O(1)."""

    __slots__ = ("name", "weight", "depth", "policy", "admit_ms", "reqs",
                 "flows")

    def __init__(self, name: str, *, weight: float = 1.0,
                 depth: int | None = None, policy: str = "block",
                 admit_ms: float | None = None):
        if policy not in ("block", "reject"):
            raise ValueError(f"unknown backpressure policy {policy!r}; "
                             "expected 'block' or 'reject'")
        if depth is not None and depth < 1:
            raise ValueError(f"queue depth must be ≥ 1 or None, got {depth}")
        if admit_ms is not None and admit_ms <= 0:
            raise ValueError(f"admit_ms must be > 0 or None, got {admit_ms}")
        self.name = name
        # every field below is owned by the scheduler that holds this queue
        # — ModelQueue adds no locking of its own
        self.weight = max(float(weight), _MIN_WEIGHT)   # guarded-by: _lock
        self.depth = depth                              # guarded-by: _lock
        self.policy = policy                            # guarded-by: _lock
        self.admit_ms = admit_ms                        # guarded-by: _lock
        self.reqs: deque[_Request] = deque()            # guarded-by: _lock
        self.flows = 0                                  # guarded-by: _lock


class WFQScheduler:
    """Thread-safe request queues scheduled by deficit round-robin.

    One lock guards the queue map, every queue's deque, the deficit
    counters, and the latency reservoirs; the two conditions share it
    (``_space``: submitters blocked on a full queue; ``_work``: a dispatcher
    waiting for anything to do). Plan dispatch happens OUTSIDE the lock —
    ``pull_round`` pops requests and returns, so a multi-millisecond XLA
    call never blocks ingestion.
    """

    def __init__(self):
        self._lock = make_lock("scheduler._lock", reentrant=True)
        self._space = threading.Condition(self._lock)
        self._work = threading.Condition(self._lock)
        self._queues: dict[str, ModelQueue] = {}        # guarded-by: _lock
        self._deficit: dict[str, float] = {}            # guarded-by: _lock
        self._latency: dict[str, dict] = {}             # guarded-by: _lock
        # SLO bookkeeping: per-model counters, EWMA service rate (flows/s)
        # and slice service time (ms), and the shed requests awaiting
        # collection by the dispatcher (bounded: an uncollected backlog of
        # shed bookkeeping must not leak on a standalone scheduler)
        self._counters: dict[str, dict] = {}            # guarded-by: _lock
        self._rate: dict[str, float] = {}               # guarded-by: _lock
        self._svc_ms: dict[str, float] = {}             # guarded-by: _lock
        self._shed_pending: dict[str, deque] = {}       # guarded-by: _lock

    # -- queue management ---------------------------------------------------

    def add_queue(self, name: str, *, weight: float | None = None,
                  priority: str | None = None, depth=_UNSET,
                  policy: str | None = None, admit_ms=_UNSET) -> ModelQueue:
        """Create the queue for ``name`` (``priority`` names a class in
        :data:`PRIORITY_WEIGHTS`; an explicit ``weight`` wins;
        ``admit_ms`` caps the backlog at the observed service rate times
        that horizon — see :meth:`submit`). If the queue already exists,
        any EXPLICITLY-passed field is applied to it via :meth:`configure`
        (so re-registering a model with a new priority, bound, or policy
        is honored)."""
        w = _resolve_weight(weight, priority)
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                q = ModelQueue(name, weight=w,
                               depth=None if depth is _UNSET else depth,
                               policy=policy or "block",
                               admit_ms=None if admit_ms is _UNSET
                               else admit_ms)
                self._queues[name] = q
                self._deficit[name] = 0.0
            else:
                if weight is not None or priority is not None:
                    q.weight = w
                if depth is not _UNSET or policy is not None \
                        or admit_ms is not _UNSET:
                    self.configure(name, depth=depth, policy=policy,
                                   admit_ms=admit_ms)
            return q

    def configure(self, name: str, *, weight: float | None = None,
                  priority: str | None = None, depth=_UNSET,
                  policy: str | None = None, admit_ms=_UNSET) -> None:
        """Re-configure a live queue; only explicitly-passed fields change
        (``depth=None`` means unbounded and ``admit_ms=None`` disables
        admission control, so absence is a sentinel)."""
        with self._lock:
            q = self._queues[name]
            if weight is not None or priority is not None:
                q.weight = _resolve_weight(weight, priority)
            if depth is not _UNSET:
                if depth is not None and depth < 1:
                    raise ValueError(
                        f"queue depth must be ≥ 1 or None, got {depth}")
                q.depth = depth
                self._space.notify_all()     # a raised bound frees submitters
            if policy is not None:
                if policy not in ("block", "reject"):
                    raise ValueError(
                        f"unknown backpressure policy {policy!r}; expected "
                        "'block' or 'reject'")
                q.policy = policy
            if admit_ms is not _UNSET:
                if admit_ms is not None and admit_ms <= 0:
                    raise ValueError(
                        f"admit_ms must be > 0 or None, got {admit_ms}")
                q.admit_ms = admit_ms

    def remove_queue(self, name: str) -> list[_Request]:
        """Drop a queue; returns its still-pending requests so the caller can
        fail their futures."""
        with self._lock:
            q = self._queues.pop(name, None)
            self._deficit.pop(name, None)
            self._latency.pop(name, None)
            self._counters.pop(name, None)
            self._rate.pop(name, None)
            self._svc_ms.pop(name, None)
            self._shed_pending.pop(name, None)
            if q is None:
                return []
            reqs = list(q.reqs)
            q.reqs.clear()
            q.flows = 0
            # anyone blocked submitting to this queue must wake and notice
            self._space.notify_all()
            return reqs

    def set_weight(self, name: str, *, weight: float | None = None,
                   priority: str | None = None) -> float:
        """Re-class a live queue (takes effect next DRR round). One of
        ``weight``/``priority`` is required — a bare call must not silently
        demote the queue to the normal class."""
        if weight is None and priority is None:
            raise ValueError("pass weight= or priority= (a bare set_weight "
                             "would silently reset to the normal class)")
        with self._lock:
            q = self._queues[name]
            q.weight = _resolve_weight(weight, priority)
            return q.weight

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._queues

    def pending(self) -> dict[str, int]:
        with self._lock:
            return {n: len(q.reqs) for n, q in self._queues.items() if q.reqs}

    def describe(self) -> dict:
        """Static scheduling config + live backlog (the stats surface)."""
        with self._lock:
            return {
                name: {"weight": q.weight, "depth": q.depth,
                       "policy": q.policy, "admit_ms": q.admit_ms,
                       "pending": len(q.reqs), "pending_flows": q.flows}
                for name, q in sorted(self._queues.items())
            }

    # -- ingestion ----------------------------------------------------------

    def submit(self, name: str, inputs: tuple, size: int, *,
               future: Future | None = None,
               timeout: float | None = None,
               deadline_ms: float | None = None,
               priority: str = "normal") -> int:
        """Enqueue one request; returns its queue position at insert time.

        ``size`` is the request's flow count (its leading batch dim — the
        unit every scheduling quantity is denominated in); ``timeout`` is
        in seconds, ``deadline_ms`` in milliseconds from NOW to completion.
        ``priority`` is the PER-REQUEST urgency within this model's queue
        (:data:`PRIORITY_RANK`): a ``"high"`` request is inserted ahead of
        every queued ``normal``/``low`` entry (FIFO among equal ranks);
        the default ``"normal"`` path stays an O(1) append whenever the
        queue tail is not lower-ranked. Cross-MODEL share is still the
        queue's weight class — this knob never changes it.

        Failure modes, in check order:

          * **Admission control** (before any queueing or blocking) — once
            the queue has an observed service rate, the backlog predicts
            the newcomer's queue-wait. A ``deadline_ms`` request predicted
            to miss its own budget raises :class:`DeadlineExceededError`;
            a queue with ``admit_ms`` set rejects ANY request once its
            backlog exceeds ``rate x admit_ms`` worth of flows
            (:class:`QueueFullError`). Before the first served slice there
            is no rate estimate and everything is admitted.
          * **Depth backpressure** — per the queue's policy: ``reject``
            raises :class:`QueueFullError` when full; ``block`` waits for
            space up to ``timeout`` seconds (``None`` = forever), then
            raises. ``KeyError`` if the model is removed while blocked.
        """
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0 or None, "
                             f"got {deadline_ms}")
        try:
            rank = PRIORITY_RANK[priority]
        except KeyError:
            raise ValueError(
                f"priority must be one of {tuple(PRIORITY_RANK)}, "
                f"got {priority!r}") from None
        with self._lock:
            q = self._queues[name]
            rate = self._rate.get(name)
            if rate and (deadline_ms is not None or q.admit_ms is not None):
                predicted_ms = q.flows / rate * 1e3
                if q.admit_ms is not None and predicted_ms > q.admit_ms:
                    self._ctr(name)["rejected"] += 1
                    raise QueueFullError(
                        f"admission control: {name!r} backlog of {q.flows} "
                        f"flows predicts {predicted_ms:.0f} ms queue-wait > "
                        f"admit_ms {q.admit_ms:.0f} at the observed "
                        f"{rate:.0f} flows/s")
                if deadline_ms is not None and predicted_ms > deadline_ms:
                    self._ctr(name)["rejected"] += 1
                    raise DeadlineExceededError(
                        f"admission control: {name!r} backlog predicts "
                        f"{predicted_ms:.0f} ms queue-wait > the request's "
                        f"{deadline_ms:.0f} ms deadline — refusing doomed "
                        "work")
            if q.depth is not None and len(q.reqs) >= q.depth:
                if q.policy == "reject":
                    raise QueueFullError(
                        f"queue for {name!r} full ({q.depth} pending, "
                        "policy=reject)")
                deadline = (None if timeout is None
                            else time.monotonic() + timeout)
                # re-check depth each wake: configure() may have lifted the
                # bound to None (unbounded) while this submitter slept
                while q.depth is not None and len(q.reqs) >= q.depth:
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise QueueFullError(
                            f"queue for {name!r} still full ({q.depth} "
                            f"pending) after blocking {timeout}s")
                    self._space.wait(remaining)
                    if name not in self._queues:   # removed while we slept
                        raise KeyError(
                            f"model {name!r} was removed while its queue "
                            "was full")
                    q = self._queues[name]
            req = _Request(inputs, int(size), future, deadline_ms, rank)
            pos = len(q.reqs)
            if rank > 0 and pos and q.reqs[-1].rank < rank:
                # queue-jump: slot ahead of every strictly-lower-rank entry
                # (scan from the back so equal ranks stay FIFO); the default
                # all-normal queue never enters this branch
                while pos > 0 and q.reqs[pos - 1].rank < rank:
                    pos -= 1
                q.reqs.insert(pos, req)
            else:
                q.reqs.append(req)
            q.flows += req.size
            self._ctr(name)["admitted"] += 1
            self._work.notify_all()
            return pos

    def requeue_front(self, name: str, reqs: list[_Request]) -> None:
        """Put a failed slice back at the FRONT of its queue, in order —
        the sync drain's retry semantics (counters untouched, FIFO kept)."""
        if not reqs:
            return
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                return
            q.reqs.extendleft(reversed(reqs))
            q.flows += sum(r.size for r in reqs)
            self._work.notify_all()

    def discard(self, name: str) -> list[_Request]:
        """Clear a queue (poisoned-request escape hatch); returns the dropped
        requests so the caller can fail their futures. The queue's deficit
        resets with it — an emptied queue must not bank credit (an oversize
        head may have inflated it via the catch-up jump)."""
        with self._lock:
            q = self._queues.get(name)
            if q is None:
                return []
            reqs = list(q.reqs)
            q.reqs.clear()
            q.flows = 0
            self._deficit[name] = 0.0
            self._space.notify_all()
            return reqs

    # -- scheduling ---------------------------------------------------------

    def pull_round(self, quantum: float,
                   exclude: frozenset | set = frozenset()
                   ) -> list[tuple[str, list[_Request]]]:
        """One deficit-round-robin round: every backlogged model (minus
        ``exclude``), in descending-weight order, earns ``quantum x weight``
        credit and releases FIFO requests while the next one fits.

        **Deadline shedding happens here**: before a queue head is
        considered for dispatch, a deadline-bearing head whose queue-wait
        already exceeds its slack (``deadline_ms`` minus the model's EWMA
        slice service time — dispatching it now would still finish late)
        is popped, its future failed with :class:`DeadlineExceededError`,
        and NO credit is charged. Shed requests are retrievable once via
        :meth:`take_shed` for dispatcher bookkeeping. Requests without a
        deadline are never shed.

        Guarantees progress: if no backlogged head fits its credit this
        round (a request larger than one quantum), every backlogged queue
        is advanced the minimal whole number of rounds that lets SOME head
        fit — one O(1) jump instead of busy-looping round by round under
        the lock, with the same weight-proportional credit each queue would
        have earned. A model whose queue empties forfeits leftover credit
        (classic DRR: idle models don't bank bandwidth). Returns
        ``[(name, [requests]), ...]`` in dispatch order; empty means
        nothing eligible is pending (everything pending may have been
        shed).
        """
        with self._lock:
            out: list[tuple[str, list[_Request]]] = []
            while not out:
                backlogged = [q for q in self._queues.values()
                              if q.reqs and q.name not in exclude]
                if not backlogged:
                    break
                # descending weight, stable on ties (dict = insertion order)
                backlogged.sort(key=lambda q: -q.weight)
                now = time.perf_counter()
                for q in backlogged:
                    credit = self._deficit[q.name] + quantum * q.weight
                    pulled: list[_Request] = []
                    while q.reqs:
                        head = q.reqs[0]
                        if self._past_slack(q.name, head, now):
                            q.reqs.popleft()
                            q.flows -= head.size
                            self._shed(q.name, head, now)
                            continue
                        if head.size > credit:
                            break
                        q.reqs.popleft()
                        q.flows -= head.size
                        credit -= head.size
                        head.t_dispatch = now
                        pulled.append(head)
                    # empty queue forfeits credit; a backlogged one keeps it
                    self._deficit[q.name] = credit if q.reqs else 0.0
                    if pulled:
                        out.append((q.name, pulled))
                        c = self._ctr(q.name)
                        c["dispatched_flows"] += sum(r.size for r in pulled)
                        c["max_wait_ms"] = max(
                            c["max_wait_ms"],
                            (now - pulled[0].t_submit) * 1e3)
                if not out:
                    # every head is oversize: jump the minimal number of
                    # extra rounds (per-queue credit stays ∝ weight).
                    # Re-filter: shedding above may have emptied queues.
                    backlogged = [q for q in backlogged
                                  if q.reqs and q.name not in exclude]
                    if not backlogged:
                        continue
                    k = max(1, min(
                        -(-(q.reqs[0].size - self._deficit[q.name])
                          // (quantum * q.weight))
                        for q in backlogged))
                    for q in backlogged:
                        self._deficit[q.name] += k * quantum * q.weight
            if out:
                self._space.notify_all()
            return out

    # holds: _lock
    def _past_slack(self, name: str, req: _Request, now: float) -> bool:
        """True when dispatching ``req`` now would still miss its deadline:
        queue-wait so far > deadline minus the model's EWMA service time
        (no estimate yet → the raw deadline is the slack).

        The estimate's claim on the slack is capped at HALF the request's
        budget — a request always gets at least ``deadline/2`` of queue
        time before shedding. Uncapped, a transiently-inflated estimate (a
        trace compile timed into a slice, one throttled run) exceeding the
        deadline sheds EVERY request instantly — and since only served
        slices update the EWMA, nothing ever corrects it: the queue sheds
        forever on a stale number. The cap keeps at least the fresh tail
        dispatching, whose real service times decay the estimate back
        down (self-healing observed vs permanent starvation without it)."""
        if req.deadline_ms is None:
            return False
        wait_ms = (now - req.t_submit) * 1e3
        est_ms = min(self._svc_ms.get(name, 0.0), 0.5 * req.deadline_ms)
        return wait_ms > req.deadline_ms - est_ms

    # holds: _lock
    def _shed(self, name: str, req: _Request, now: float) -> None:
        """Shed bookkeeping (caller holds the lock): counters, the
        take_shed() handoff, and the future's typed failure."""
        wait_ms = (now - req.t_submit) * 1e3
        c = self._ctr(name)
        c["shed"] += 1
        c["shed_flows"] += req.size
        c["max_wait_ms"] = max(c["max_wait_ms"], wait_ms)
        pend = self._shed_pending.get(name)
        if pend is None:
            pend = self._shed_pending[name] = deque(maxlen=LATENCY_WINDOW)
        pend.append(req)
        self._space.notify_all()        # shedding frees bounded-queue space
        fut = req.future
        if fut is not None and not fut.done():
            try:
                fut.set_exception(DeadlineExceededError(
                    f"request to {name!r} shed after {wait_ms:.1f} ms "
                    f"queue-wait against a {req.deadline_ms:.0f} ms deadline "
                    f"(est. service {self._svc_ms.get(name, 0.0):.1f} ms)"))
            except Exception:           # cancelled mid-shed: caller owns it
                pass

    def take_shed(self) -> dict[str, list]:
        """Hand the dispatcher every request shed since the last call
        (``{name: [requests]}``) and clear the pending list. Futures are
        already failed at shed time — this exists for dispatcher-side
        bookkeeping (e.g. ``serve()``'s PartialDrainError shed report)."""
        with self._lock:
            out = {name: list(reqs)
                   for name, reqs in self._shed_pending.items() if reqs}
            self._shed_pending.clear()
            return out

    def wait_for_work(self, timeout: float | None) -> bool:
        """Park until any queue is non-empty (or timeout); returns whether
        work is pending. The async drain loop's idle wait."""
        with self._lock:
            if any(q.reqs for q in self._queues.values()):
                return True
            self._work.wait(timeout)
            return any(q.reqs for q in self._queues.values())

    def kick(self) -> None:
        """Wake a parked dispatcher (used by stop())."""
        with self._lock:
            self._work.notify_all()

    # -- latency + SLO instrumentation --------------------------------------

    # holds: _lock
    def _ctr(self, name: str) -> dict:
        """Per-model SLO counter record (caller holds the lock)."""
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = {
                "admitted": 0, "rejected": 0, "shed": 0, "shed_flows": 0,
                "dispatched_flows": 0, "served_flows": 0,
                "goodput_flows": 0, "late_flows": 0, "max_wait_ms": 0.0,
            }
        return c

    def record_service(self, name: str, reqs: list[_Request],
                       service_ms: float) -> None:
        """Fold one served slice into the reservoirs: each request's
        queue-wait (submit → pull), the slice's service wall time, the
        EWMA service-rate/-time estimates admission control and shed slack
        read, and the goodput split (a deadline-bearing request completing
        within its budget counts its flows as goodput; past it, as late)."""
        now = time.perf_counter()
        with self._lock:
            lat = self._latency.get(name)
            if lat is None:
                lat = self._latency[name] = {
                    "queue_wait_ms": deque(maxlen=LATENCY_WINDOW),
                    "service_ms": deque(maxlen=LATENCY_WINDOW),
                }
            flows = 0
            c = self._ctr(name)
            for r in reqs:
                lat["queue_wait_ms"].append(
                    (r.t_dispatch - r.t_submit) * 1e3)
                lat["service_ms"].append(service_ms)
                flows += r.size
                if r.deadline_ms is not None:
                    if (now - r.t_submit) * 1e3 <= r.deadline_ms:
                        c["goodput_flows"] += r.size
                    else:
                        c["late_flows"] += r.size
            c["served_flows"] += flows
            if service_ms > 0 and flows:
                rate = flows / (service_ms / 1e3)
                prev = self._rate.get(name)
                self._rate[name] = (rate if prev is None else
                                    (1 - _EWMA_ALPHA) * prev
                                    + _EWMA_ALPHA * rate)
                prev_ms = self._svc_ms.get(name)
                self._svc_ms[name] = (service_ms if prev_ms is None else
                                      (1 - _EWMA_ALPHA) * prev_ms
                                      + _EWMA_ALPHA * service_ms)

    def counters(self) -> dict:
        """Per-model SLO counters (admission/shed/goodput) plus live
        starvation metrics, all denominated in flows unless named ``_ms``:

          * ``admitted`` / ``rejected`` — requests accepted vs refused by
            admission control (depth-policy rejections raise out of
            ``submit`` and are NOT counted here),
          * ``shed`` / ``shed_flows`` — requests dropped at pull time for
            a missed deadline slack,
          * ``dispatched_flows`` / ``served_flows`` — flows handed to the
            dispatcher vs flows whose slice completed,
          * ``goodput_flows`` / ``late_flows`` — served flows that made vs
            missed their deadline (no-deadline flows count in neither),
          * ``max_wait_ms`` — worst queue-wait ever observed (dispatch or
            shed) — the starvation high-water mark for weight≫1 skews,
          * ``head_wait_ms`` — the CURRENT oldest pending request's wait
            (0 when idle): a growing value on a backlogged low-weight
            queue is starvation happening right now,
          * ``service_rate_flows_s`` / ``service_ms_ewma`` — the EWMA
            estimates driving admission control and shed slack.
        """
        now = time.perf_counter()
        with self._lock:
            out = {}
            for name in sorted(set(self._counters) | set(self._queues)):
                c = dict(self._ctr(name))
                q = self._queues.get(name)
                c["head_wait_ms"] = (
                    (now - q.reqs[0].t_submit) * 1e3
                    if q is not None and q.reqs else 0.0)
                c["service_rate_flows_s"] = self._rate.get(name)
                c["service_ms_ewma"] = self._svc_ms.get(name)
                out[name] = c
            return out

    def reset_counters(self) -> None:
        """Zero the SLO counters (benchmarks reset between phases); the
        EWMA rate/service estimates persist — they describe the model, not
        the measurement window."""
        with self._lock:
            self._counters.clear()

    def reset_latency(self) -> None:
        """Drop the reservoirs (benchmarks reset after warmup)."""
        with self._lock:
            self._latency.clear()

    def latency_stats(self) -> dict:
        """Per-model queue-wait + service-time percentiles over the
        reservoir window."""
        with self._lock:
            snap = {name: {k: list(v) for k, v in lat.items()}
                    for name, lat in self._latency.items()}
        out = {}
        for name, lat in sorted(snap.items()):
            entry = {"samples": len(lat["queue_wait_ms"])}
            for key, samples in lat.items():
                if samples:
                    p50, p90, p99 = np.percentile(
                        np.asarray(samples, np.float64), [50, 90, 99])
                    entry[key] = {"p50": round(float(p50), 3),
                                  "p90": round(float(p90), 3),
                                  "p99": round(float(p99), 3)}
            out[name] = entry
        return out
