"""Production mesh + sharding rules for the 10-arch LM stack.

Mesh shapes (TPU v5e pods):
  single-pod:  (16, 16)      axes ("data", "model")
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")  — "pod" is an
               outer data-parallel axis whose collectives cross DCN.

Sharding policy (GSPMD):
  * TP: one matrix axis on "model" (heads / d_ff / vocab).
  * FSDP/ZeRO-3: the OTHER matrix axis on ("pod","data") — params, grads
    and Adam m/v all shard over the full mesh; XLA inserts the all-gather /
    reduce-scatter pairs.
  * Activations: batch on ("pod","data"); internal shardings left to SPMD.
  * KV caches: batch on data; kv-heads on "model" when divisible, else
    head_dim (GQA archs with few KV heads).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchConfig

__all__ = ["make_production_mesh", "param_specs", "batch_specs",
           "decode_state_specs", "fsdp_axes", "named", "MODEL_AXIS_SIZE"]

MODEL_AXIS_SIZE = 16


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _maybe(n: int, mesh: Mesh, axis):
    """Shard dim of size n on axis if divisible, else replicate."""
    return axis if _div(n, mesh, axis) else None


def param_specs(cfg: ArchConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching the param tree (TP × FSDP)."""
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]

    def spec_for(path: str, x: jax.Array) -> P:
        shape = x.shape
        stacked = path.startswith(("layers/", "enc_layers/"))
        dims = shape[1:] if stacked else shape
        leaf = path.rsplit("/", 1)[-1]

        def out(*spec):
            spec = list(spec) + [None] * (len(dims) - len(spec))
            if stacked:
                spec = [None] + spec
            return P(*spec)

        if leaf in ("embed",):
            return out(_maybe(dims[0], mesh, "model"), _maybe(dims[1], mesh, fsdp))
        if leaf == "lm_head":
            return out(_maybe(dims[0], mesh, fsdp), _maybe(dims[1], mesh, "model"))
        if len(dims) == 0 or leaf.startswith("ln") or leaf in ("a_log",):
            return out()
        if leaf in ("wq", "wk", "wv", "wz", "wi", "wf", "wo_gate", "w_in", "w_gate",
                    "w_dt", "w_B", "w_C"):
            if len(dims) == 3:  # MoE [E, D, F]: EP on experts when divisible
                if _div(dims[0], mesh, "model"):
                    return out("model", _maybe(dims[1], mesh, fsdp), None)
                return out(None, _maybe(dims[1], mesh, fsdp),
                           _maybe(dims[2], mesh, "model"))
            if len(dims) == 1:
                return out(_maybe(dims[0], mesh, "model"))
            return out(_maybe(dims[0], mesh, fsdp), _maybe(dims[1], mesh, "model"))
        if leaf in ("wo", "w_out", "r"):
            if len(dims) == 3:  # MoE [E, F, D]
                if _div(dims[0], mesh, "model"):
                    return out("model", None, _maybe(dims[2], mesh, fsdp))
                return out(None, _maybe(dims[1], mesh, "model"),
                           _maybe(dims[2], mesh, fsdp))
            return out(_maybe(dims[0], mesh, "model"), _maybe(dims[1], mesh, fsdp))
        if leaf in ("router",):
            return out(_maybe(dims[0], mesh, fsdp), None)
        if leaf in ("bq", "bk", "bv"):
            return out(_maybe(dims[0], mesh, "model"))
        return out()

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)

    def keystr(kp):
        return "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp
        )

    specs = [spec_for(keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(cfg: ArchConfig, batch: Any, mesh: Mesh, *, batch_size: int) -> Any:
    """Batch inputs: batch dim on ("pod","data") when divisible, else replicated
    (long_500k has global_batch=1 — model-parallel only, by design)."""
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    bspec = _maybe(batch_size, mesh, fsdp)

    def spec_for(x):
        if hasattr(x, "ndim") and x.ndim >= 1:
            return P(bspec, *([None] * (x.ndim - 1)))
        return P()

    return jax.tree.map(spec_for, batch)


def decode_state_specs(cfg: ArchConfig, state: Any, mesh: Mesh, *, batch_size: int,
                       cache_seq_shard: bool = False) -> Any:
    """Caches/states: [L, B, ...] — B on fsdp axes; kv-heads or head_dim on model.

    ``cache_seq_shard`` (§Perf): shard the KV cache over SEQUENCE on 'model'
    instead of head_dim — flash-decoding-style split-KV. Scores/PV reduce
    locally per shard; only tiny softmax stats + the [B,1,D] output cross
    devices, replacing the per-layer [B,kv,g,T] score all-reduce.
    """
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else fsdp[0]
    bspec = _maybe(batch_size, mesh, fsdp)

    def spec_for(path: str, x: jax.Array) -> P:
        dims = x.shape
        leaf = path.rsplit("/", 1)[-1]
        if leaf in ("cache_k", "cache_v"):
            # [L, B, S, kv, hd]
            if cache_seq_shard and _div(dims[2], mesh, "model"):
                return P(None, bspec, "model", None, None)
            kv_spec = _maybe(dims[3], mesh, "model")
            hd_spec = _maybe(dims[4], mesh, "model") if kv_spec is None else None
            return P(None, bspec, None, kv_spec, hd_spec)
        if leaf in ("mlstm_S",):   # [L, B, H, hd, hd]
            return P(None, bspec, None, _maybe(dims[3], mesh, "model"), None)
        if leaf in ("mlstm_n",):   # [L, B, H, hd]
            return P(None, bspec, None, _maybe(dims[3], mesh, "model"))
        if leaf in ("mamba_h",):   # [L, B, di, N]
            return P(None, bspec, _maybe(dims[2], mesh, "model"), None)
        if leaf.startswith("slstm"):  # [L, B, D]
            return P(None, bspec, _maybe(dims[2], mesh, "model"))
        return P(*([None] * len(dims)))

    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    treedef = jax.tree_util.tree_structure(state)

    def keystr(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)

    specs = [spec_for(keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
