"""Roofline analysis (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in SECONDS per step:

  compute    = FLOPs_per_device / 197e12      (TPU v5e bf16 peak)
  memory     = HBM_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9   (ICI link bw)

FLOPs/bytes use an ANALYTIC per-arch model (formulas below) because
``cost_analysis()`` counts ``lax.scan`` bodies once (verified: flops are
~constant in depth — see EXPERIMENTS.md §Dry-run methodology); the raw
cost_analysis numbers are recorded alongside for reference. Collective bytes
come from the compiled HLO with while-trip-count correction
(``hlo_analysis.collective_bytes_hlo``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.registry import SHAPES, ArchConfig, get_config

__all__ = ["HW", "analytic_cell", "roofline_terms", "format_row"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw: float = 50e9              # B/s / link
    hbm_bytes: float = 16 * 2**30     # v5e capacity
    chips: int = 256                  # single pod


V5E = HW()


def _n_matmul(cfg: ArchConfig, active: bool) -> float:
    """Params participating in matmuls (embedding GATHER excluded, LM head
    included — for tied embeddings the single table plays both roles)."""
    n = cfg.active_param_count() if active else cfg.param_count()
    from repro.models.transformer import padded_vocab

    emb = padded_vocab(cfg) * cfg.d_model
    if not cfg.tie_embeddings:
        n -= emb  # gather side
    return float(n)


def analytic_cell(cfg: ArchConfig, shape_name: str, *, remat: str = "nothing",
                  lut_serving: bool = False) -> dict:
    """Per-DEVICE analytic flops & HBM bytes for one cell (single pod)."""
    seq, gb, kind = SHAPES[shape_name]
    devs = V5E.chips
    hd = cfg.resolved_head_dim
    heads = cfg.num_heads
    L = cfg.num_layers + cfg.encoder_layers
    dtype_b = 2  # bf16

    n_act = _n_matmul(cfg, active=True)
    param_bytes = cfg.param_count() * dtype_b

    if kind in ("train", "prefill"):
        if cfg.encoder_layers:
            tokens = gb * (seq + cfg.max_decoder_len)   # enc frames + dec text
            attn_tokens_sq = gb * (seq**2 + cfg.max_decoder_len**2 / 2
                                   + seq * cfg.max_decoder_len)  # enc + dec + cross
        else:
            tokens = gb * seq
            eff = min(seq, cfg.window) if cfg.window else seq
            attn_tokens_sq = gb * seq * eff / 2          # causal (window-capped)

        matmul_fwd = 2.0 * n_act * tokens
        attn_fwd = 2.0 * heads * hd * attn_tokens_sq * 2  # qk + pv
        if cfg.family == "ssm":
            # mLSTM chunked: intra-chunk (c=256) + state update per chunk
            c = 256
            attn_fwd = gb * seq * heads * (4 * c * hd + 4 * hd * hd) * cfg.num_layers
        if cfg.family == "hybrid":
            attn_fwd += 2.0 * gb * seq * (2 * cfg.d_model) * cfg.ssm_state * 4 * cfg.num_layers

        fwd = matmul_fwd + attn_fwd
        if kind == "train":
            remat_mult = {"nothing": 1.0, "dots": 0.4, "none": 0.0}[remat]
            total = fwd * (3.0 + remat_mult)  # fwd + bwd(2×) + remat refwd
            # HBM: weights (fwd+bwd+remat reads, grad rs) + opt (f32 m,v,p)
            w_traffic = param_bytes * (2 + remat_mult) + cfg.param_count() * 4
            opt_traffic = cfg.param_count() * (4 + 4) * 2          # m,v read+write
            act_traffic = 2 * L * tokens / devs * cfg.d_model * dtype_b * 4
            bytes_dev = (w_traffic + opt_traffic) / devs + act_traffic
            flops_dev = total / devs
            model_flops = 6.0 * n_act * tokens + 0 * attn_fwd
        else:  # prefill
            flops_dev = fwd / devs
            act_traffic = L * tokens / devs * cfg.d_model * dtype_b * 3
            bytes_dev = param_bytes / devs + act_traffic
            model_flops = 2.0 * n_act * tokens
    else:  # decode: one token for the whole batch
        tokens = gb
        cache_len = min(seq, cfg.window) if cfg.window else seq
        if cfg.encoder_layers:
            cache_len = cfg.max_decoder_len
        matmul = 2.0 * n_act * tokens
        if lut_serving:
            # Pegasus LUT path: matmul flops collapse to comparisons+gathers
            matmul = matmul * 0.0
        if cfg.family == "ssm":
            attn = tokens * heads * (4 * hd * hd) * cfg.num_layers
            cache_bytes = (cfg.num_layers * gb * heads * hd * (hd + 1) * 4) * 2
        else:
            attn = 4.0 * tokens * heads * hd * cache_len * cfg.num_layers
            kv = cfg.num_kv_heads
            cache_bytes = 2 * cfg.num_layers * gb * cache_len * kv * hd * dtype_b
            if cfg.family == "hybrid":
                cache_bytes += cfg.num_layers * gb * 2 * cfg.d_model * cfg.ssm_state * 4 * 2
        flops_dev = (matmul + attn) / devs
        weight_bytes = n_act * (1 if lut_serving else dtype_b)  # int8 LUT option
        bytes_dev = (weight_bytes + cache_bytes) / devs
        model_flops = 2.0 * n_act * tokens

    return dict(
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        model_flops_total=model_flops,
        tokens=tokens,
    )


def roofline_terms(cfg: ArchConfig, shape_name: str, collective_bytes: float,
                   *, remat: str = "nothing", hw: HW = V5E,
                   lut_serving: bool = False) -> dict:
    a = analytic_cell(cfg, shape_name, remat=remat, lut_serving=lut_serving)
    compute_s = a["flops_per_device"] / hw.peak_flops
    memory_s = a["bytes_per_device"] / hw.hbm_bw
    coll_s = collective_bytes / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    total_flops = a["flops_per_device"] * hw.chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops": a["model_flops_total"],
        "hlo_flops_analytic": total_flops,
        "useful_ratio": a["model_flops_total"] / max(total_flops, 1.0),
        "bound_step_s": max(terms.values()),
        "roofline_frac": terms[dominant] and (
            min(compute_s / max(terms.values()), 1.0)),
        "tokens": a["tokens"],
    }


def format_row(arch: str, shape: str, r: dict) -> str:
    return (f"| {arch} | {shape} | {r['compute_s']*1e3:.1f} | "
            f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']*100:.0f}% |")
