"""Pegasus-LM integration: LUT-based approximate linear layers for serving.

This is the paper's technique as a first-class LM feature (DESIGN.md §2):
selected FFN matmuls of a *trained* model are replaced, at deployment, by
Partition→fuzzy-index→LUT-gather→SumReduce banks built from the weights +
a calibration pass. On TPU the banks execute via ``kernels.fuzzy_lut``
(MXU one-hot form) — matmul FLOPs collapse to comparisons+gathers and the
weight bytes become (C/v)·D·N LUT bytes (int8-able), which is the decode
roofline lever measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from repro.core.amm import PegasusLinear, init_pegasus_linear, pegasus_linear_apply
from repro.models.layers import activation, rms_norm

__all__ = ["PegasusFFN", "pegasusify_ffn_layer", "pegasus_ffn_apply",
           "lut_bytes", "dense_ffn_bytes"]


@dataclasses.dataclass
class PegasusFFN:
    """LUT form of one (gated) FFN: in/gate/out banks."""

    w_in: PegasusLinear
    w_gate: PegasusLinear | None
    w_out: PegasusLinear
    act: str


def pegasusify_ffn_layer(
    cfg: ArchConfig,
    ffn_params: dict,
    calib_x: np.ndarray,          # [S, d_model] representative activations
    *,
    group_size: int = 4,
    depth: int = 4,
    lut_dtype=jnp.bfloat16,
) -> PegasusFFN:
    """Lower one layer's FFN weights to Pegasus banks."""
    act = activation(cfg.act)
    w_in = np.asarray(ffn_params["w_in"], np.float32)
    w_gate = ffn_params.get("w_gate")
    w_out = np.asarray(ffn_params["w_out"], np.float32)

    in_bank = init_pegasus_linear(
        w_in, None, calib_x, group_size=group_size, depth=depth,
        lut_bits=None, lut_dtype=lut_dtype)
    gate_bank = None
    if w_gate is not None:
        gate_bank = init_pegasus_linear(
            np.asarray(w_gate, np.float32), None, calib_x,
            group_size=group_size, depth=depth, lut_bits=None, lut_dtype=lut_dtype)
    # calibrate the out bank on the hidden activations
    xin = jnp.asarray(calib_x) @ w_in
    if w_gate is not None:
        h = act(jnp.asarray(calib_x) @ np.asarray(w_gate, np.float32)) * xin
    else:
        h = act(xin)
    out_bank = init_pegasus_linear(
        w_out, None, np.asarray(h), group_size=group_size, depth=depth,
        lut_bits=None, lut_dtype=lut_dtype)
    return PegasusFFN(w_in=in_bank, w_gate=gate_bank, w_out=out_bank, act=cfg.act)


def pegasus_ffn_apply(p: PegasusFFN, x: jax.Array, *, path: str = "onehot") -> jax.Array:
    act = activation(p.act)
    xin = pegasus_linear_apply(p.w_in, x, path=path)
    if p.w_gate is not None:
        h = act(pegasus_linear_apply(p.w_gate, x, path=path)) * xin
    else:
        h = act(xin)
    return pegasus_linear_apply(p.w_out, h, path=path)


def lut_bytes(cfg: ArchConfig, *, group_size: int = 8, depth: int = 4,
              lut_dtype_bytes: int = 1) -> float:
    """Per-layer FFN LUT bytes: (D/v)·C·F·(…) per bank (the §Perf lever)."""
    c = 2**depth
    n_banks = 3 if cfg.is_gated_ffn else 2
    per_in = cfg.d_model / group_size * c * cfg.d_ff * lut_dtype_bytes
    per_out = cfg.d_ff / group_size * c * cfg.d_model * lut_dtype_bytes
    return (n_banks - 1) * per_in + per_out


def dense_ffn_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> float:
    n_banks = 3 if cfg.is_gated_ffn else 2
    return n_banks * cfg.d_model * cfg.d_ff * dtype_bytes
