"""Recurrent blocks: xLSTM's mLSTM (chunked-parallel) + sLSTM (sequential),
and a simplified Mamba-style selective-SSM head for Hymba's hybrid layers.

mLSTM uses the chunkwise-parallel form (matrix state S ∈ R^{dk×dv}, scalar
sigmoid gates per head): within a chunk the decay matrix is materialized and
everything is batched matmuls (MXU-friendly); across chunks a lax.scan
carries (S, n). O(T·c) compute, O(1) state — this is what makes the
``long_500k`` decode cell run for the SSM/hybrid archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init

__all__ = [
    "init_mlstm", "mlstm_forward", "mlstm_decode_step",
    "init_slstm", "slstm_forward",
    "init_mamba_head", "mamba_forward", "mamba_decode_step",
]


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, num_heads: int, head_dim: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 7)
    hd = head_dim
    return {
        "wq": dense_init(ks[0], (d_model, num_heads * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, num_heads * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, num_heads * hd), dtype=dtype),
        "wi": dense_init(ks[3], (d_model, num_heads), dtype=jnp.float32),
        "wf": dense_init(ks[4], (d_model, num_heads), dtype=jnp.float32),
        "wo_gate": dense_init(ks[5], (d_model, num_heads * hd), dtype=dtype),
        "wo": dense_init(ks[6], (num_heads * hd, d_model), dtype=dtype),
    }


def _mlstm_chunk(q, k, v, logf, i_gate, carry_S, carry_n):
    """One chunk. q,k,v: [B,H,c,hd]; logf,i: [B,H,c]; S: [B,H,hd,hd]; n: [B,H,hd]."""
    c = q.shape[2]
    l = jnp.cumsum(logf, axis=-1)                       # [B,H,c] cumulative log decay
    # intra-chunk: A[j,u] = exp(l_j - l_u) * i_u   (u <= j)
    lj = l[..., :, None]
    lu = l[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    amat = jnp.where(mask, jnp.exp(lj - lu), 0.0) * i_gate[..., None, :]
    scores = jnp.einsum("bhjd,bhud->bhju", q.astype(jnp.float32), k.astype(jnp.float32))
    intra = jnp.einsum("bhju,bhud->bhjd", scores * amat, v.astype(jnp.float32))
    # inter-chunk: decayed carry
    decay_j = jnp.exp(l)[..., None]                     # [B,H,c,1]
    inter = jnp.einsum("bhjd,bhde->bhje", q.astype(jnp.float32), carry_S) * decay_j
    # normalizer n_j = exp(l_j) n_prev + Σ_{u≤j} exp(l_j−l_u) i_u k_u
    n_intra = jnp.einsum("bhju,bhud->bhjd", amat, k.astype(jnp.float32))
    n_j = decay_j * carry_n[..., None, :] + n_intra
    denom = jnp.abs(jnp.einsum("bhjd,bhjd->bhj", q.astype(jnp.float32), n_j))
    h = (intra + inter) / jnp.maximum(denom, 1.0)[..., None]
    # carry update
    decay_c = jnp.exp(l[..., -1])[..., None, None]      # [B,H,1,1]
    w_u = jnp.exp(l[..., -1:] - l) * i_gate             # [B,H,c]
    S_new = decay_c * carry_S + jnp.einsum(
        "bhud,bhue,bhu->bhde", k.astype(jnp.float32), v.astype(jnp.float32), w_u
    )
    n_new = decay_c[..., 0] * carry_n + jnp.einsum(
        "bhud,bhu->bhd", k.astype(jnp.float32), w_u
    )
    return h, S_new, n_new


def mlstm_forward(p: dict, x: jax.Array, *, num_heads: int, head_dim: int,
                  chunk: int = 256) -> jax.Array:
    """Full-sequence chunked mLSTM. x: [B, S, D] → [B, S, D]."""
    b, s, d = x.shape
    hd = head_dim
    c = min(chunk, s)
    assert s % c == 0, (s, c)

    def heads(w):
        return (x @ w).reshape(b, s, num_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(p["wq"]) / np.sqrt(hd), heads(p["wk"]), heads(p["wv"])
    logf = jax.nn.log_sigmoid((x.astype(jnp.float32) @ p["wf"])).transpose(0, 2, 1)
    i_gate = jnp.exp(-jax.nn.softplus(-(x.astype(jnp.float32) @ p["wi"]))).transpose(0, 2, 1)

    nchunks = s // c
    qc = q.reshape(b, num_heads, nchunks, c, hd).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, num_heads, nchunks, c, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, num_heads, nchunks, c, hd).transpose(2, 0, 1, 3, 4)
    fc = logf.reshape(b, num_heads, nchunks, c).transpose(2, 0, 1, 3)
    ic = i_gate.reshape(b, num_heads, nchunks, c).transpose(2, 0, 1, 3)

    S0 = jnp.zeros((b, num_heads, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, num_heads, hd), jnp.float32)

    def step(carry, inp):
        S, n = carry
        qj, kj, vj, fj, ij = inp
        h, S, n = _mlstm_chunk(qj, kj, vj, fj, ij, S, n)
        return (S, n), h

    (_, _), hs = jax.lax.scan(step, (S0, n0), (qc, kc, vc, fc, ic))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, num_heads, s, hd)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, num_heads * hd)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return ((h.astype(x.dtype) * o) @ p["wo"]).astype(x.dtype)


def mlstm_decode_step(p: dict, x: jax.Array, S: jax.Array, n: jax.Array,
                      *, num_heads: int, head_dim: int):
    """One-token step. x: [B, 1, D]; S: [B,H,hd,hd]; n: [B,H,hd]."""
    b = x.shape[0]
    hd = head_dim
    xt = x[:, 0]

    def head(w):
        return (xt @ w).reshape(b, num_heads, hd)

    q, k, v = head(p["wq"]) / np.sqrt(hd), head(p["wk"]), head(p["wv"])
    f = jax.nn.sigmoid(xt.astype(jnp.float32) @ p["wf"])        # [B,H]
    i = jnp.exp(-jax.nn.softplus(-(xt.astype(jnp.float32) @ p["wi"])))
    S = f[..., None, None] * S + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = f[..., None] * n + i[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), S)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)), 1.0)
    h = (num / den[..., None]).reshape(b, 1, num_heads * hd)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return ((h.astype(x.dtype) * o) @ p["wo"]).astype(x.dtype), S, n


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory block with recurrent mixing — strictly sequential)
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], (d_model, d_model), dtype=dtype),
        "wi": dense_init(ks[1], (d_model, d_model), dtype=jnp.float32),
        "wf": dense_init(ks[2], (d_model, d_model), dtype=jnp.float32),
        "wo_gate": dense_init(ks[3], (d_model, d_model), dtype=dtype),
        "r": dense_init(ks[4], (d_model, d_model), dtype=dtype) * 0.1,
        "wo": dense_init(ks[5], (d_model, d_model), dtype=dtype),
    }


def slstm_forward(p: dict, x: jax.Array) -> jax.Array:
    """Sequential sLSTM over time (lax.scan). x: [B, S, D]."""
    b, s, d = x.shape

    def step(carry, xt):
        c, n, h = carry
        pre = h @ p["r"]
        z = jnp.tanh(xt @ p["wz"] + pre)
        i = jnp.exp(-jax.nn.softplus(-(xt.astype(jnp.float32) @ p["wi"])))
        f = jax.nn.sigmoid(xt.astype(jnp.float32) @ p["wf"])
        c = f * c + i * z.astype(jnp.float32)
        n = f * n + i
        o = jax.nn.sigmoid(xt @ p["wo_gate"]).astype(jnp.float32)
        h_new = (o * c / jnp.maximum(n, 1.0)).astype(x.dtype)
        return (c, n, h_new), h_new

    zeros = jnp.zeros((b, d), jnp.float32)
    h0 = jnp.zeros((b, d), x.dtype)
    (_, _, _), hs = jax.lax.scan(step, (zeros, zeros, h0), x.transpose(1, 0, 2))
    return (hs.transpose(1, 0, 2) @ p["wo"]).astype(x.dtype)


def slstm_decode_step(p: dict, x: jax.Array, c, n, h):
    """One-token sLSTM step; returns (out [B,1,D], c, n, h)."""
    xt = x[:, 0]
    pre = h @ p["r"]
    z = jnp.tanh(xt @ p["wz"] + pre)
    i = jnp.exp(-jax.nn.softplus(-(xt.astype(jnp.float32) @ p["wi"])))
    f = jax.nn.sigmoid(xt.astype(jnp.float32) @ p["wf"])
    c = f * c + i * z.astype(jnp.float32)
    n = f * n + i
    o = jax.nn.sigmoid(xt @ p["wo_gate"]).astype(jnp.float32)
    h_new = (o * c / jnp.maximum(n, 1.0)).astype(x.dtype)
    return (h_new @ p["wo"]).astype(x.dtype)[:, None], c, n, h_new


# ---------------------------------------------------------------------------
# Mamba-style selective-SSM head (for Hymba parallel heads)
# ---------------------------------------------------------------------------


def init_mamba_head(key, d_model: int, d_inner: int, state: int, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d_model, d_inner), dtype=dtype),
        "w_dt": dense_init(ks[1], (d_inner, 1), dtype=jnp.float32),
        "w_B": dense_init(ks[2], (d_inner, state), dtype=jnp.float32),
        "w_C": dense_init(ks[3], (d_inner, state), dtype=jnp.float32),
        "a_log": jnp.zeros((d_inner, state), jnp.float32),  # A = -exp(a_log)
        "w_out": dense_init(ks[4], (d_inner, d_model), dtype=dtype),
    }


def mamba_forward(p: dict, x: jax.Array, chunk: int = 256) -> jax.Array:
    """Chunk-scanned selective SSM. x: [B, S, D] → [B, S, D].

    Simplified S6: per-channel diagonal state (size N), input-dependent
    (dt, B, C); recurrence h = exp(A·dt)·h + dt·B·u computed with a
    sequential scan over CHUNKS and a parallel intra-chunk unroll.
    """
    b, s, d = x.shape
    u = x @ p["w_in"]                                   # [B, S, di]
    di = u.shape[-1]
    dt = jax.nn.softplus(u.astype(jnp.float32) @ p["w_dt"])        # [B,S,1]
    bmat = u.astype(jnp.float32) @ p["w_B"]             # [B,S,N]
    cmat = u.astype(jnp.float32) @ p["w_C"]             # [B,S,N]
    a = -jnp.exp(p["a_log"])                            # [di, N]

    # scan over time in fp32 (chunked to bound while-loop trip count)
    c = min(chunk, s)
    nchunks = s // c

    def chunk_step(h, inp):
        uc, dtc, bc, cc = inp                           # [c,B,...]
        def tstep(h, t_in):
            ut, dtt, bt, ct = t_in                      # [B,di],[B,1],[B,N],[B,N]
            da = jnp.exp(dtt[..., None] * a[None])      # [B,di,N]
            h = da * h + (dtt * ut.astype(jnp.float32))[..., None] * bt[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, ct)
            return h, y
        h, ys = jax.lax.scan(tstep, h, (uc, dtc, bc, cc))
        return h, ys

    u_t = u.transpose(1, 0, 2).reshape(nchunks, c, b, di)
    dt_t = dt.transpose(1, 0, 2).reshape(nchunks, c, b, 1)
    b_t = bmat.transpose(1, 0, 2).reshape(nchunks, c, b, -1)
    c_t = cmat.transpose(1, 0, 2).reshape(nchunks, c, b, -1)
    h0 = jnp.zeros((b, di, a.shape[1]), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (u_t, dt_t, b_t, c_t))
    y = ys.reshape(s, b, di).transpose(1, 0, 2)
    return (y.astype(x.dtype) * jax.nn.silu(u)) @ p["w_out"]


def mamba_decode_step(p: dict, x: jax.Array, h: jax.Array):
    """One-token step. x: [B,1,D]; h: [B, di, N]."""
    xt = x[:, 0]
    u = xt @ p["w_in"]
    dt = jax.nn.softplus(u.astype(jnp.float32) @ p["w_dt"])
    bmat = u.astype(jnp.float32) @ p["w_B"]
    cmat = u.astype(jnp.float32) @ p["w_C"]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[..., None] * a[None])
    h = da * h + (dt * u.astype(jnp.float32))[..., None] * bmat[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat)
    out = (y.astype(x.dtype) * jax.nn.silu(u)) @ p["w_out"]
    return out[:, None], h
