"""GQA/MQA attention with RoPE/M-RoPE, causal + sliding-window masks, and a
decode path over a preallocated KV cache. Pure jnp; sharding comes from the
callers' pjit in/out specs (heads live on the "model" mesh axis).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rope, rope_mrope

__all__ = ["init_attn", "attn_forward", "attn_decode", "DEFAULT_IMPL",
           "SEQ_PARALLEL_ATTN"]

# module-level defaults so perf experiments can flip implementations without
# threading flags through every config (see launch/roofline.py + §Perf).
DEFAULT_IMPL = "chunked"

# Sequence-parallel attention (§Perf iteration): when the KV-head count does
# not divide the "model" axis, GSPMD's fallback shards head_dim and inserts
# an all-reduce of every score tile INSIDE the flash inner loop (measured
# 470 MB × 127k executions on deepseek prefill_32k — EXPERIMENTS.md §Perf).
# Constraining q/k/v to be sharded over SEQUENCE on the model axis makes all
# attention arithmetic local: one all-gather of K/V per layer replaces the
# per-tile all-reduce.
SEQ_PARALLEL_ATTN = False


def _seq_shard(x, axis: int = 1):
    """Constrain x to be sequence-sharded on the 'model' mesh axis."""
    from jax.sharding import PartitionSpec as P

    try:
        spec = [None] * x.ndim
        spec[axis] = "model"
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):  # no mesh in scope (unit tests)
        return x


def _replicate_model(x):
    """Constrain x to be replicated over the 'model' axis (K/V gather once)."""
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*([None] * x.ndim)))
    except (ValueError, RuntimeError):
        return x


def init_attn(key, d_model: int, num_heads: int, num_kv: int, head_dim: int,
              *, qkv_bias: bool = False, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d_model, num_kv * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d_model, num_kv * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv * head_dim,), dtype)
    return p


def _project_qkv(p, x, num_heads, num_kv, head_dim):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(b, s, num_heads, head_dim),
        k.reshape(b, s, num_kv, head_dim),
        v.reshape(b, s, num_kv, head_dim),
    )


def _sdpa(q, k, v, mask, *, num_kv_groups: int):
    """q [B,S,H,hd]; k,v [B,T,Kv,hd]; GQA via head grouping. f32 softmax."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    q = q.reshape(b, s, kv, num_kv_groups, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)


def _sdpa_chunked(q, k, v, *, num_kv_groups: int, causal: bool,
                  window: int | None, q_chunk: int = 512, kv_chunk: int = 1024):
    """Flash-style chunked attention: online softmax over KV blocks.

    Scores exist only per (q_chunk × kv_chunk) tile — activation memory is
    O(S·d) instead of O(S²). Causality/windowing skip fully-masked KV chunks
    only via masking (shape-static; the scan is over all chunks).
    q [B,S,H,hd] → out [B,S,H,hd].
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    assert s % qc == 0 and t % kc == 0, (s, qc, t, kc)
    nq, nk = s // qc, t // kc
    g = num_kv_groups
    scale = 1.0 / np.sqrt(hd)

    # [nq, B, kv, g, qc, hd]
    qr = q.reshape(b, nq, qc, kv, g, hd).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(b, nk, kc, kv, hd).transpose(1, 0, 3, 2, 4)   # [nk,B,kv,kc,hd]
    vr = v.reshape(b, nk, kc, kv, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(nq) * qc
    k_pos_base = jnp.arange(nk) * kc

    def q_block(carry_qi, qi_inputs):
        qb, q0 = qi_inputs                           # [B,kv,g,qc,hd], scalar

        def kv_block(carry, ki_inputs):
            m, l, acc = carry                        # running max/denom/accum
            kb, vb, k0 = ki_inputs
            scores = jnp.einsum(
                "bkgqh,bkch->bkgqc", qb.astype(jnp.float32), kb.astype(jnp.float32)
            ) * scale                                # [B,kv,g,qc,kc]
            qpos = q0 + jnp.arange(qc)
            kpos = k0 + jnp.arange(kc)
            msk = jnp.ones((qc, kc), bool)
            if causal:
                msk = kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk = msk & (kpos[None, :] > qpos[:, None] - window)
            scores = jnp.where(msk[None, None, None], scores, -1e30)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kr, vr, k_pos_base))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry_qi, out

    _, outs = jax.lax.scan(q_block, 0, (qr, q_pos_base))  # [nq,B,kv,g,qc,hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def attn_forward(
    p: dict,
    x: jax.Array,                      # [B, S, D]
    positions: jax.Array,              # [S] or [B, S]
    *,
    num_heads: int,
    num_kv: int,
    head_dim: int,
    causal: bool = True,
    window: int | None = None,
    rope_kind: str = "standard",       # standard | mrope | none
    impl: str | None = None,           # chunked (flash-style) | naive
) -> jax.Array:
    impl = impl or DEFAULT_IMPL
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, num_heads, num_kv, head_dim)
    if rope_kind == "standard":
        q, k = rope(q, positions), rope(k, positions)
    elif rope_kind == "mrope":
        from .layers import mrope_positions

        pos3 = mrope_positions(positions)
        q, k = rope_mrope(q, pos3), rope_mrope(k, pos3)

    if SEQ_PARALLEL_ATTN and s > 512:
        # queries sharded over seq on 'model'; K/V gathered (replicated over
        # 'model') — all score/PV arithmetic becomes device-local.
        q = _seq_shard(q, 1)
        k = _replicate_model(k)
        v = _replicate_model(v)

    if impl == "chunked" and s > 512:
        out = _sdpa_chunked(q, k, v, num_kv_groups=num_heads // num_kv,
                            causal=causal, window=window)
    else:
        mask = None
        if causal:
            i = jnp.arange(s)[:, None]
            j = jnp.arange(s)[None, :]
            mask = j <= i
            if window is not None:
                mask = mask & (j > i - window)
            mask = mask[None, None, None]  # [1,1,1,S,T]
        out = _sdpa(q, k, v, mask, num_kv_groups=num_heads // num_kv)
    return out.reshape(b, s, num_heads * head_dim) @ p["wo"]


def attn_decode(
    p: dict,
    x: jax.Array,                      # [B, 1, D] — one new token
    cache_k: jax.Array,                # [B, S, Kv, hd] preallocated
    cache_v: jax.Array,
    pos: jax.Array,                    # scalar int32: write index
    *,
    num_heads: int,
    num_kv: int,
    head_dim: int,
    window: int | None = None,
    rope_kind: str = "standard",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against the KV cache; returns (out, new_k, new_v)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, num_heads, num_kv, head_dim)
    posv = jnp.full((1,), pos, jnp.int32)
    if rope_kind == "standard":
        q, k = rope(q, posv), rope(k, posv)
    elif rope_kind == "mrope":
        from .layers import mrope_positions

        pos3 = mrope_positions(posv)
        q, k = rope_mrope(q, pos3), rope_mrope(k, pos3)

    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))

    t = cache_k.shape[1]
    j = jnp.arange(t)[None, None, None, None, :]  # [1,1,1,1,T]
    mask = j <= pos
    if window is not None:
        mask = mask & (j > pos - window)
    out = _sdpa(q, cache_k, cache_v, mask, num_kv_groups=num_heads // num_kv)
    out = out.reshape(b, 1, num_heads * head_dim) @ p["wo"]
    return out, cache_k, cache_v
