"""Top-k MoE FFN with capacity-based one-hot dispatch (Switch/GShard style).

Dense dispatch einsums compile cleanly under GSPMD: with experts sharded on
the "model" mesh axis and tokens on ("pod","data"), XLA inserts the
all-to-all pair around the expert computation — the standard expert-parallel
schedule. Capacity bounds the dispatch tensor so memory stays shape-static.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import activation, dense_init

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, d_model: int, d_ff: int, num_experts: int, *, gated: bool = True,
             dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d_model, num_experts), dtype=jnp.float32),
        "w_in": dense_init(ks[1], (num_experts, d_model, d_ff), in_axis=1, dtype=dtype),
        "w_out": dense_init(ks[2], (num_experts, d_ff, d_model), in_axis=1, dtype=dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[3], (num_experts, d_model, d_ff), in_axis=1, dtype=dtype)
    return p


def moe_forward(
    p: dict,
    x: jax.Array,                 # [B, S, D]
    *,
    top_k: int,
    act: str = "silu",
    capacity_factor: float = 1.25,
    group_size: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,D], aux_loss scalar).

    GROUPED dispatch (GShard/MaxText style): tokens are split into groups of
    ``group_size``; routing positions + one-hot dispatch tensors are per
    group, so dispatch memory/flops are O(T·E·C_g) with C_g ∝ group_size/E
    instead of O(T·E·C) with C ∝ T/E — a global-capacity one-hot would be
    QUADRATIC in tokens (the 31 TiB/device baseline failure recorded in
    EXPERIMENTS.md §Perf iteration 1).
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    g = min(group_size, t)
    assert t % g == 0, (t, g)
    ng = t // g
    xt = x.reshape(ng, g, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [G, g, E]
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)                    # [G, g, k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    capacity = int(np.ceil(g * top_k / e * capacity_factor))
    capacity = max(capacity, top_k)

    # position of each (token, choice) within its expert via per-group cumsum
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)                # [G, g, k, E]
    flatoh = onehot.reshape(ng, g * top_k, e)
    pos_in_expert = (jnp.cumsum(flatoh, axis=1) - flatoh).reshape(ng, g, top_k, e)
    pos_in_expert = (pos_in_expert * onehot).sum(-1)                     # [G, g, k]
    keep = pos_in_expert < capacity

    disp = (
        jax.nn.one_hot(gate_idx, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(pos_in_expert, capacity, dtype=x.dtype)[..., None, :]
        * keep[..., None, None].astype(x.dtype)
    ).sum(2)                                                             # [G, g, E, C]
    comb = (
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(pos_in_expert, capacity, dtype=jnp.float32)[..., None, :]
        * (gate_vals * keep.astype(jnp.float32))[..., None, None]
    ).sum(2)                                                             # [G, g, E, C]

    xe = jnp.einsum("Ngd,Ngec->Necd", xt, disp)       # all-to-all in (per group)
    act_fn = activation(act)
    if "w_gate" in p:
        h = act_fn(jnp.einsum("Necd,edf->Necf", xe, p["w_gate"])) * jnp.einsum(
            "Necd,edf->Necf", xe, p["w_in"]
        )
    else:
        h = act_fn(jnp.einsum("Necd,edf->Necf", xe, p["w_in"]))
    ye = jnp.einsum("Necf,efd->Necd", h, p["w_out"])                     # expert FFN
    yt = jnp.einsum("Necd,Ngec->Ngd", ye.astype(jnp.float32), comb)      # all-to-all out

    # load-balancing aux loss (Switch): E * Σ_e f_e · P_e
    dens = onehot.sum(2).astype(jnp.float32).mean((0, 1))
    aux = e * jnp.sum(dens * probs.mean((0, 1)))
    return yt.reshape(b, s, d).astype(x.dtype), aux
