"""The LM stack: one composable decoder/enc-dec covering all 10 assigned
architectures (dense / MoE / SSM / hybrid / enc-dec / VLM-backbone).

Structure notes (these drive compile time and the dry-run):
  * Layer parameters are STACKED over depth ([L, ...] leading axis) and the
    stack runs under ``jax.lax.scan`` — HLO size is constant in depth.
  * Each scan body is ``jax.checkpoint``-wrapped (remat policy configurable).
  * Decode runs one token against preallocated caches/states, also scanned.
  * Families plug different ``layer_fn``s into the same scan harness.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchConfig
from .attention import attn_decode, attn_forward, init_attn
from .layers import activation, dense_init, rms_norm
from .moe import init_moe, moe_forward
from .ssm import (
    init_mamba_head, init_mlstm, init_slstm,
    mamba_decode_step, mamba_forward,
    mlstm_decode_step, mlstm_forward,
    slstm_forward, slstm_decode_step,
)

__all__ = ["init_model", "forward_train", "init_decode_state", "decode_step",
           "padded_vocab", "lm_loss", "LAYER_SEQ_SHARD"]

# §Perf knob (decode): shard the residual stream's FEATURE dim over 'data'
# during decode — with weights 2D-sharded [D/data, F/model], every matmul
# contracts locally and all-reduces only the [B,1,F/16] output, replacing
# the per-step 42.5 GB/device weight all-gather (ZeRO-gather is the wrong
# schedule for decode; weight-stationary 2D TP is the right one).
DECODE_FEATURE_SHARD = False


def _maybe_feat_shard(x):
    if not DECODE_FEATURE_SHARD:
        return x
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(None, None, "data"))
    except (ValueError, RuntimeError):
        return x


# §Perf knob: keep activations SEQUENCE-sharded on the 'model' axis at layer
# boundaries (Megatron-SP style). Without it, seq-parallel attention reshards
# [B,S,D] activations between attention (seq-sharded) and FFN (TP) layouts —
# an all-gather of the full residual stream per layer.
LAYER_SEQ_SHARD = False


def _maybe_seq_shard(x):
    if not LAYER_SEQ_SHARD or x.ndim != 3 or x.shape[1] < 1024:
        return x
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(None, "model", None))
    except (ValueError, RuntimeError):
        return x


def padded_vocab(cfg: ArchConfig) -> int:
    """Vocab rounded to a multiple of 256 so it shards on any mesh axis."""
    return int(np.ceil(cfg.vocab_size / 256)) * 256


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_ffn(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    gated = cfg.is_gated_ffn
    p = {
        "w_in": dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype=dtype),
        "w_out": dense_init(ks[1], (cfg.d_ff, cfg.d_model), dtype=dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (cfg.d_model, cfg.d_ff), dtype=dtype)
    return p


def _init_layer(key, cfg: ArchConfig, dtype, *, cross: bool = False) -> dict:
    """One decoder layer's params (family-dependent)."""
    ks = jax.random.split(key, 6)
    hd = cfg.resolved_head_dim
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.family == "ssm":
        # xLSTM super-layer: mLSTM + sLSTM
        p["mlstm"] = init_mlstm(ks[0], cfg.d_model, cfg.num_heads, hd, dtype)
        p["ln_s"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["slstm"] = init_slstm(ks[1], cfg.d_model, dtype)
        return p
    p["attn"] = init_attn(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd,
                          qkv_bias=cfg.qkv_bias, dtype=dtype)
    if cfg.family == "hybrid":
        p["mamba"] = init_mamba_head(ks[1], cfg.d_model, 2 * cfg.d_model,
                                     cfg.ssm_state, dtype)
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["xattn"] = init_attn(ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                               hd, dtype=dtype)
    p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[3], cfg.d_model, cfg.d_ff, cfg.num_experts,
                            gated=cfg.is_gated_ffn, dtype=dtype)
    elif cfg.d_ff:
        p["ffn"] = _init_ffn(ks[3], cfg, dtype)
    return p


def init_model(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    """Full parameter pytree; per-layer params stacked over depth."""
    ks = jax.random.split(key, 6)
    v = padded_vocab(cfg)
    params: dict[str, Any] = {
        "embed": dense_init(ks[0], (v, cfg.d_model), dtype=dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, v), dtype=dtype)

    layer_keys = jax.random.split(ks[2], cfg.num_layers)
    cross = cfg.encoder_layers > 0
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype, cross=cross)
    )(layer_keys)

    if cfg.encoder_layers:
        enc_keys = jax.random.split(ks[3], cfg.encoder_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype, cross=False)
        )(enc_keys)
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# layer forwards (full-sequence)
# ---------------------------------------------------------------------------


def _layer_forward(cfg: ArchConfig, p, x, positions, *, causal, enc_out=None):
    """One layer, full sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        x = x + mlstm_forward(p["mlstm"], rms_norm(x, p["ln1"]),
                              num_heads=cfg.num_heads, head_dim=hd)
        x = x + slstm_forward(p["slstm"], rms_norm(x, p["ln_s"]))
        return x, aux

    h = rms_norm(x, p["ln1"])
    attn_out = attn_forward(
        p["attn"], h, positions,
        num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads, head_dim=hd,
        causal=causal, window=cfg.window or None, rope_kind=cfg.rope_kind,
    )
    if cfg.family == "hybrid":
        attn_out = attn_out + mamba_forward(p["mamba"], h)
    x = x + attn_out

    if enc_out is not None:
        hx = rms_norm(x, p["ln_x"])
        x = x + _cross_attn(cfg, p["xattn"], hx, enc_out)

    h2 = rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        ffn_out, aux = moe_forward(p["moe"], h2, top_k=cfg.top_k, act=cfg.act)
    elif cfg.d_ff:
        ffn_out = _ffn(cfg, p["ffn"], h2)
    else:
        return x, aux
    return x + ffn_out, aux


def _ffn(cfg: ArchConfig, p, x):
    act = activation(cfg.act)
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_in"])
    else:
        h = act(x @ p["w_in"])
    return h @ p["w_out"]


def _cross_attn(cfg: ArchConfig, p, q_in, enc_out):
    """Whisper-style cross attention (no rope, keys from encoder output)."""
    b, s, d = q_in.shape
    hd = cfg.resolved_head_dim
    t = enc_out.shape[1]
    q = (q_in @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (enc_out @ p["wk"]).reshape(b, t, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, t, cfg.num_kv_heads, hd)
    from .attention import _sdpa

    out = _sdpa(q, k, v, None, num_kv_groups=cfg.num_heads // cfg.num_kv_heads)
    return out.reshape(b, s, cfg.num_heads * hd) @ p["wo"]


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# full-model forward (training / prefill)
# ---------------------------------------------------------------------------


def _scan_layers(cfg, stacked, x, positions, *, causal, enc_out=None,
                 remat_policy: str = "nothing"):
    def body(carry, p_layer):
        h, aux = carry
        h, a = _layer_forward(cfg, p_layer, h, positions, causal=causal,
                              enc_out=enc_out)
        h = _maybe_seq_shard(h)
        return (h, aux + a), None

    if remat_policy == "nothing":
        policy = jax.checkpoint_policies.nothing_saveable
    elif remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = None
    body_ck = jax.checkpoint(body, policy=policy) if policy else body
    (x, aux), _ = jax.lax.scan(body_ck, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


def forward_train(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    remat_policy: str = "nothing",
    last_only: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], moe_aux). ``batch`` carries ``tokens`` or
    (stub frontends) ``embeds``; enc-dec additionally ``dec_tokens``."""
    if cfg.encoder_layers:
        # whisper: encoder over frame embeddings, decoder over text tokens
        enc_x = batch["embeds"].astype(params["embed"].dtype)
        s_enc = enc_x.shape[1]
        enc_x = enc_x + _sinusoid(jnp.arange(s_enc), cfg.d_model).astype(enc_x.dtype)
        enc_x, _ = _scan_layers(cfg, params["enc_layers"], enc_x,
                                jnp.arange(s_enc), causal=False,
                                remat_policy=remat_policy)
        enc_out = rms_norm(enc_x, params["enc_ln_f"])

        dec_tokens = batch["dec_tokens"]
        s_dec = dec_tokens.shape[1]
        x = params["embed"][dec_tokens] + _sinusoid(
            jnp.arange(s_dec), cfg.d_model
        ).astype(params["embed"].dtype)
        x, aux = _scan_layers(cfg, params["layers"], x, jnp.arange(s_dec),
                              causal=True, enc_out=enc_out,
                              remat_policy=remat_policy)
    else:
        if "embeds" in batch:           # vlm stub frontend
            x = batch["embeds"].astype(params["embed"].dtype)
        else:
            x = params["embed"][batch["tokens"]]
        s = x.shape[1]
        x, aux = _scan_layers(cfg, params["layers"], x, jnp.arange(s),
                              causal=True, remat_policy=remat_policy)

    if last_only:
        x = x[:, -1:]          # prefill serving: only the last position's
                               # logits are consumed — slicing BEFORE the LM
                               # head kills the [B,S,V] matmul + its gather
    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, aux


def lm_loss(cfg: ArchConfig, params: dict, batch: dict, *,
            remat_policy: str = "nothing", z_loss: float = 1e-4,
            aux_weight: float = 1e-2) -> jax.Array:
    logits, aux = forward_train(cfg, params, batch, remat_policy=remat_policy)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    logp = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    loss = -logp.mean() + z_loss * jnp.square(logz).mean() + aux_weight * aux
    return loss


# ---------------------------------------------------------------------------
# decode (one token against caches/states)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ArchConfig, batch: int, kv_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Preallocated per-layer caches/states, stacked over depth."""
    l = cfg.num_layers
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    st: dict[str, Any] = {}
    if cfg.family == "ssm":
        st["mlstm_S"] = jnp.zeros((l, batch, cfg.num_heads, hd, hd), jnp.float32)
        st["mlstm_n"] = jnp.zeros((l, batch, cfg.num_heads, hd), jnp.float32)
        st["slstm_c"] = jnp.zeros((l, batch, cfg.d_model), jnp.float32)
        st["slstm_n"] = jnp.zeros((l, batch, cfg.d_model), jnp.float32)
        st["slstm_h"] = jnp.zeros((l, batch, cfg.d_model), dtype)
        return st
    cache_len = min(kv_len, cfg.window) if cfg.window else kv_len
    if cfg.encoder_layers:
        cache_len = min(kv_len, cfg.max_decoder_len)
    st["cache_k"] = jnp.zeros((l, batch, cache_len, kv, hd), dtype)
    st["cache_v"] = jnp.zeros((l, batch, cache_len, kv, hd), dtype)
    if cfg.family == "hybrid":
        st["mamba_h"] = jnp.zeros((l, batch, 2 * cfg.d_model, cfg.ssm_state), jnp.float32)
    return st


def decode_step(
    cfg: ArchConfig,
    params: dict,
    state: dict,
    tokens: jax.Array,              # [B, 1] int32
    pos: jax.Array,                 # scalar int32 — absolute position
    *,
    enc_out: jax.Array | None = None,   # enc-dec: encoder output [B,T,D]
) -> tuple[jax.Array, dict]:
    """One decode step. Returns (logits [B, V], new_state)."""
    hd = cfg.resolved_head_dim
    x = params["embed"][tokens]     # [B, 1, D]
    if cfg.encoder_layers:
        x = x + _sinusoid(pos[None] if pos.ndim == 0 else pos, cfg.d_model).astype(x.dtype)[None]

    if cfg.family == "ssm":
        def body(h, inp):
            p, S, n, c, ns, hs = inp
            out, S, n = mlstm_decode_step(p["mlstm"], rms_norm(h, p["ln1"]), S, n,
                                          num_heads=cfg.num_heads, head_dim=hd)
            h = h + out
            out, c, ns, hs = slstm_decode_step(p["slstm"], rms_norm(h, p["ln_s"]), c, ns, hs)
            h = h + out
            return h, (S, n, c, ns, hs)

        x, (S, n, c, ns, hs) = jax.lax.scan(
            body, x,
            (params["layers"], state["mlstm_S"], state["mlstm_n"],
             state["slstm_c"], state["slstm_n"], state["slstm_h"]),
        )
        new_state = dict(mlstm_S=S, mlstm_n=n, slstm_c=c, slstm_n=ns, slstm_h=hs)
    else:
        cache_len = state["cache_k"].shape[2]
        write_pos = jnp.mod(pos, cache_len) if (cfg.window or cfg.encoder_layers) else pos

        def body(h, inp):
            p = inp[0]
            ck, cv = inp[1], inp[2]
            h = _maybe_feat_shard(h)
            hn = rms_norm(h, p["ln1"])
            out, ck, cv = attn_decode(
                p["attn"], hn, ck, cv, write_pos,
                num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads, head_dim=hd,
                window=None,  # ring buffer already bounds the window
                rope_kind=cfg.rope_kind,
            )
            extra = ()
            if cfg.family == "hybrid":
                mo, mh = mamba_decode_step(p["mamba"], hn, inp[3])
                out = out + mo
                extra = (mh,)
            h = h + out
            if enc_out is not None:
                h = h + _cross_attn(cfg, p["xattn"], rms_norm(h, p["ln_x"]), enc_out)
            h2 = rms_norm(h, p["ln2"])
            if cfg.family == "moe":
                f, _ = moe_forward(p["moe"], h2, top_k=cfg.top_k, act=cfg.act)
                h = h + f
            elif cfg.d_ff:
                h = h + _ffn(cfg, p["ffn"], h2)
            return h, (ck, cv) + extra

        ins = (params["layers"], state["cache_k"], state["cache_v"])
        if cfg.family == "hybrid":
            ins = ins + (state["mamba_h"],)
        x, outs = jax.lax.scan(body, x, ins)
        new_state = dict(cache_k=outs[0], cache_v=outs[1])
        if cfg.family == "hybrid":
            new_state["mamba_h"] = outs[2]

    x = rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x[:, 0] @ head).astype(jnp.float32), new_state
