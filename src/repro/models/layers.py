"""Shared building blocks for the LM stack: norms, RoPE/M-RoPE, activations,
init helpers. Everything is plain-jnp + dict params (stacked over layers for
lax.scan), bf16 weights / f32 accumulation by default.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope", "mrope_positions", "activation", "dense_init"]


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def _rope_angles(positions: jax.Array, head_dim: int, theta: float = 1e4) -> tuple[jax.Array, jax.Array]:
    """positions [..., S] → cos/sin [..., S, head_dim/2] (f32)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(positions, hd, theta)  # [B, S, hd/2] or [S, hd/2]
    # add the head axis once; leading axes broadcast ([S,1,hd/2] vs [B,S,H,hd/2])
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(positions: jax.Array, sections: tuple[int, int, int] = (1, 1, 2)):
    """Qwen2-VL M-RoPE stub: (t, h, w) position components.

    The modality frontend is a stub (input_specs provides patch embeddings),
    so all three components collapse to the text position stream — but the
    M-RoPE *structure* (sectioned rotary dims) is preserved so real (t,h,w)
    streams drop in without touching the attention code.
    Returns [3, ...] stacked position components.
    """
    return jnp.stack([positions, positions, positions], axis=0)


def rope_mrope(x: jax.Array, positions3: jax.Array, sections=(2, 1, 1), theta: float = 1e4) -> jax.Array:
    """Sectioned M-RoPE: head_dim/2 frequency slots split across (t,h,w)."""
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    sizes = [half * s // total for s in sections]
    sizes[-1] = half - sum(sizes[:-1])
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    # component index per frequency slot
    comp = jnp.concatenate([jnp.full((sz,), i, jnp.int32) for i, sz in enumerate(sizes)])
    pos = positions3.astype(jnp.float32)  # [3, B, S] or [3, S]
    pos_per_slot = jnp.take(pos, comp, axis=0)  # [half, ...]→ moveaxis
    pos_per_slot = jnp.moveaxis(pos_per_slot, 0, -1)  # [..., half]
    ang = pos_per_slot * freqs  # [..., half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos, sin = cos[..., None, :], sin[..., None, :]  # head axis (leading bcast)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "sq_relu":  # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> jax.Array:
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)
