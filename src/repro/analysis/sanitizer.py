"""Runtime concurrency sanitizer: lock-order + thread-affinity checks.

The static lint (:mod:`repro.analysis.lint`) sees syntactic nesting inside
one module; this module catches what it cannot — lock orders composed
ACROSS call boundaries at runtime, and code running on the wrong thread.
Enabled by ``PEGASUS_SANITIZE=1`` (read at lock construction, i.e. server
construction — setting it for a test session is enough); disabled, the
factories return plain stdlib primitives with zero overhead.

``make_lock(name)`` is the drop-in the serving stack uses instead of
``threading.Lock()``/``RLock()``. Under the sanitizer it returns an
:class:`InstrumentedLock` that

* records the process-wide acquisition graph (edge ``A -> B`` whenever a
  thread acquires B while holding A) and raises :class:`LockOrderError`
  the moment an edge would close a cycle — the canonical deadlock shape
  (thread 1: A then B, thread 2: B then A) is reported on the SECOND
  acquisition, deterministically, whether or not the schedules actually
  interleave into a deadlock this run;
* checks every new edge against the declared hierarchy
  (:data:`repro.analysis.rules.LOCK_RANKS`) and raises on an inversion;
* raises on re-entry of a lock created with ``reentrant=False`` instead of
  deadlocking on it (the instrumented lock is internally an RLock, so
  silent re-entry would otherwise change semantics).

The lock implements the full ``threading.Condition`` owner protocol
(``_is_owned`` / ``_release_save`` / ``_acquire_restore``), so
``threading.Condition(make_lock(...))`` works unchanged — including the
held-stack bookkeeping across a ``wait()``'s release/reacquire.

:class:`ThreadAffinity` asserts "this code runs only on thread X": the
owning thread calls ``bind()``, any checkpoint calls ``assert_here()``.
Unbound (or sanitizer off) it never fires, so the assertions are free in
production. ``AsyncMultiModelServer``'s drain loop binds the dispatch
affinity; ``DeviceStreamPool`` binds one per worker and exposes
``assert_worker()``.
"""

from __future__ import annotations

import os
import threading

from .rules import LOCK_RANKS

__all__ = [
    "enabled", "make_lock", "InstrumentedLock", "LockOrderError",
    "ThreadAffinity", "ThreadAffinityError", "reset_lock_graph",
]


def enabled() -> bool:
    """True when ``PEGASUS_SANITIZE`` is set to anything but ''/0."""
    return os.environ.get("PEGASUS_SANITIZE", "") not in ("", "0")


class LockOrderError(RuntimeError):
    """An acquisition closed a cycle or inverted the declared hierarchy."""


class ThreadAffinityError(RuntimeError):
    """Code bound to one thread executed on another."""


# process-wide acquisition graph: {held lock name: {acquired-next names}}.
# Guarded by a PLAIN lock — it must not instrument itself.
_graph: dict[str, set] = {}
_graph_lock = threading.Lock()
_tls = threading.local()


def reset_lock_graph() -> None:
    """Forget every recorded edge (test isolation: a fixture-built A->B
    edge must not poison later tests' graphs)."""
    with _graph_lock:
        _graph.clear()


def _held() -> list:
    stack = getattr(_tls, "held", None)
    if stack is None:
        stack = _tls.held = []
    return stack


def _find_path(src: str, dst: str) -> list | None:
    """DFS path src -> ... -> dst through the edge graph (caller holds
    _graph_lock); None if unreachable."""
    stack, seen = [(src, [src])], {src}
    while stack:
        node, path = stack.pop()
        if node == dst:
            return path
        for nxt in _graph.get(node, ()):
            if nxt not in seen:
                seen.add(nxt)
                stack.append((nxt, path + [nxt]))
    return None


class InstrumentedLock:
    """RLock-backed lock that validates every acquisition's ordering."""

    def __init__(self, name: str, *, reentrant: bool = False):
        self.name = name
        self.reentrant = reentrant
        self._inner = threading.RLock()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        kind = "RLock" if self.reentrant else "Lock"
        return f"<InstrumentedLock {self.name} ({kind})>"

    # -- ordering checks ----------------------------------------------------

    def _check_order(self, held: list) -> None:
        distinct = [n for n in dict.fromkeys(held) if n != self.name]
        if not distinct:
            return
        with _graph_lock:
            # cycle first: does the graph already know a path name -> held?
            for h in distinct:
                path = _find_path(self.name, h)
                if path is not None:
                    raise LockOrderError(
                        f"lock-order cycle: acquiring {self.name!r} while "
                        f"holding {h!r}, but the recorded order is "
                        f"{' -> '.join(path)} (a thread that interleaves "
                        "these acquisitions deadlocks)")
            my_rank = LOCK_RANKS.get(self.name)
            for h in distinct:
                _graph.setdefault(h, set()).add(self.name)
                h_rank = LOCK_RANKS.get(h)
                if (my_rank is not None and h_rank is not None
                        and h_rank > my_rank):
                    raise LockOrderError(
                        f"hierarchy inversion: {self.name!r} (rank "
                        f"{my_rank}) acquired while holding {h!r} (rank "
                        f"{h_rank}); declared order is outer->inner by "
                        "ascending rank (rules.LOCK_RANKS)")

    # -- lock protocol ------------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        held = _held()
        if self.name in held:
            if not self.reentrant:
                raise LockOrderError(
                    f"non-reentrant lock {self.name!r} re-acquired by its "
                    "owning thread (this deadlocks a plain threading.Lock)")
            ok = self._inner.acquire(blocking, timeout)
        else:
            self._check_order(held)
            ok = self._inner.acquire(blocking, timeout)
        if ok:
            held.append(self.name)
        return ok

    def release(self) -> None:
        self._inner.release()
        held = _held()
        # drop the most recent entry for this lock
        for i in range(len(held) - 1, -1, -1):
            if held[i] == self.name:
                del held[i]
                break

    __enter__ = acquire

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:  # pragma: no cover - parity with Lock API
        if self._inner.acquire(blocking=False):
            self._inner.release()
            return False
        return True

    # -- Condition owner protocol -------------------------------------------

    def _is_owned(self) -> bool:
        return self._inner._is_owned()

    def _release_save(self):
        # Condition.wait: fully release (all recursion levels) while parked
        state = self._inner._release_save()
        held = _held()
        count = held.count(self.name)
        _tls.held = [n for n in held if n != self.name]
        return (state, count)

    def _acquire_restore(self, saved) -> None:
        state, count = saved
        self._inner._acquire_restore(state)
        _held().extend([self.name] * count)


def make_lock(name: str, *, reentrant: bool = False):
    """The serving stack's lock factory: a plain ``Lock``/``RLock`` in
    production, an :class:`InstrumentedLock` under ``PEGASUS_SANITIZE=1``.

    ``name`` is the qualified name ranked in ``rules.LOCK_RANKS``
    (e.g. ``"scheduler._lock"``) — unranked names still get cycle
    detection, just not hierarchy checks."""
    if enabled():
        return InstrumentedLock(name, reentrant=reentrant)
    return threading.RLock() if reentrant else threading.Lock()


class ThreadAffinity:
    """Assert that checkpointed code runs only on the bound thread."""

    def __init__(self, name: str):
        self.name = name
        self._ident: int | None = None

    def bind(self) -> None:
        """Claim the current thread as the owner (no-op when the sanitizer
        is off, so production binds cost one env check)."""
        if not enabled():
            return
        self._ident = threading.get_ident()

    def release(self) -> None:
        self._ident = None

    @property
    def bound_ident(self) -> int | None:
        return self._ident

    def assert_here(self) -> None:
        """Raise unless on the bound thread (never fires while unbound)."""
        if self._ident is not None and threading.get_ident() != self._ident:
            raise ThreadAffinityError(
                f"{self.name}: expected thread {self._ident}, running on "
                f"{threading.get_ident()} ({threading.current_thread().name})")
