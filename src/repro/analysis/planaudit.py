"""Plan auditor: static numerics / VMEM / dataplane analysis of compiled plans.

The third analysis layer (PGA1xx, after the PG0xx AST lint and the runtime
sanitizer): :func:`audit_plan` walks a built ``ExecutionPlan`` — banks,
fused stacks, bucket ladder, backend/strategy, q8 tables — WITHOUT
dispatching any jax computation, and proves (or refutes) the invariants the
paper's compiler enforces on the P4 target before deployment:

* **PGA101** — fixed-point overflow: worst-case int32 accumulator bound of
  each bank's q8 tables, all groups rescaled to the finest group scale (the
  common fixed-point grid an integer dataplane would accumulate in). The
  bound is exact: per output column, each group independently contributes
  its most extreme row, so ``Σ_k max_c`` / ``Σ_k min_c`` IS the reachable
  worst case (validated against brute-force enumeration in the tests).
* **PGA102** — quantization fidelity: worst-case per-group dequantization
  error of the q8 table vs the f32 LUT it claims to quantize. Symmetric
  round-to-nearest guarantees ``err ≤ scale/2`` (~0.4% of the group amax);
  a violation means the q8 table is stale or tampered.
* **PGA103** — VMEM footprint per ``pallas_call``: operand blocks + stacked
  tables at the worst-case batch tile, against a per-target budget — the
  build-time version of the kernel docstring's working-set math.
* **PGA104** — tile alignment: ladder buckets that silently dispatch hidden
  pad rows (the kernel pads the batch up to its tile multiple, uncounted by
  ``pad_waste``), and mxu-strategy LUT widths missing 128-lane alignment.
* **PGA105** — fusion-rejection explanations: why each adjacent chained
  bank pair is NOT inside one :class:`FusedBankStack` (v/C mismatch,
  chaining break, ``nmax_cap`` split, ``fuse=False``, or a family builder
  that never runs the fusion pass — the CNN-L b1→b2 pair ROADMAP names).
  Info severity: explanations, not defects.
* **PGA106** — dataplane resource fit: the plan's banks lowered through
  ``repro.dataplane.compile`` to a MAT pipeline, charged against a declared
  :class:`SwitchBudget` (``AuditConfig.target``). Off unless a target is
  declared — serving on CPUs/TPUs carries no switch budget.

Everything here is host-side numpy over tensors the plan already
materialized at build time; no new XLA computation is traced or executed.

Lifecycle wiring: ``build_plan(..., audit="warn"|"error"|"off")`` runs this
at build, ``plan.audit_report`` / ``compile_stats()["audit"]`` carry the
result into every server ``stats()`` surface, and
``python -m repro.analysis plan [--json]`` audits the in-tree model zoo
(the static-analysis CI lane's zero-findings gate).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

from . import rules as R

__all__ = [
    "AuditConfig", "AuditFinding", "AuditReport", "PlanAuditError",
    "audit_plan", "main",
]


class PlanAuditError(ValueError):
    """Raised by ``build_plan(..., audit="error")`` on error-severity
    findings; carries the full report as ``.report``."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        bad = [f for f in report.findings if f.severity == "error"]
        super().__init__(
            f"plan audit failed with {len(bad)} error finding"
            f"{'s' if len(bad) != 1 else ''}:\n"
            + "\n".join(f"  {f}" for f in bad))


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One typed finding: ``rule`` is a PGA1xx id, ``severity`` one of
    error/warning/info, ``site`` names the plan element (bank[i], stack[g],
    bucket, plan), ``metrics`` the numbers behind the verdict."""

    rule: str
    severity: str
    site: str
    message: str
    metrics: dict = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        return f"{self.severity.upper():7s} {self.rule} {self.site}: {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "site": self.site, "message": self.message,
                "metrics": self.metrics}


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    """Audit policy knobs. Defaults come from :mod:`repro.analysis.rules`
    so the thresholds a finding enforces are reviewable as data."""

    q8_rel_tol: float = R.PGA102_REL_TOL
    vmem_budget_bytes: int = R.PGA103_VMEM_BUDGET
    vmem_margin: float = R.PGA103_MARGIN
    overflow_margin: float = R.PGA101_MARGIN
    # dataplane target for PGA106: None (off), "tofino2", or a SwitchBudget
    target: Any = None
    # PGA rule ids to drop entirely (CLI --suppress)
    suppress: tuple = ()


class AuditReport:
    """Findings + plan summary; the object ``plan.audit_report`` caches."""

    def __init__(self, findings: list[AuditFinding], summary: dict):
        self.findings = list(findings)
        self.summary = dict(summary)

    @property
    def counts(self) -> dict:
        c = {"error": 0, "warning": 0, "info": 0}
        for f in self.findings:
            c[f.severity] += 1
        return c

    @property
    def ok(self) -> bool:
        """No error- or warning-severity findings (info is explanatory)."""
        c = self.counts
        return c["error"] == 0 and c["warning"] == 0

    def to_dict(self) -> dict:
        return {"summary": self.summary, "counts": self.counts,
                "ok": self.ok,
                "findings": [f.to_dict() for f in self.findings]}

    def __str__(self) -> str:
        c = self.counts
        head = (f"plan audit [{self.summary.get('family')}] "
                f"{c['error']} error(s), {c['warning']} warning(s), "
                f"{c['info']} note(s)")
        return "\n".join([head] + [f"  {f}" for f in self.findings])


# ---------------------------------------------------------------------------
# Per-rule checks. Each takes the plan (duck-typed; engine imports stay
# lazy to keep repro.analysis import-light and cycle-free) and a config,
# and yields AuditFinding objects.
# ---------------------------------------------------------------------------


def _true_tables(bank) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(f32 LUT, q8 LUT, scales) sliced back to the bank's TRUE (K, C, N)
    — the block-padded rows are zeros/inf filler with no numeric content."""
    layer = bank.layer
    k, n = layer.num_groups, layer.out_features
    lut = np.asarray(bank.lut_p, np.float64)[:k, :, :n]
    q8 = np.asarray(bank.lut_q8_p, np.int64)[:k, :, :n]
    scales = np.asarray(bank.scales, np.float64)[:k]
    return lut, q8, scales


def accumulation_grid(scales: np.ndarray) -> float:
    """The coarsest fixed-point grid step that loses no representable
    signal: the finest scale among SIGNIFICANT groups. A group whose whole
    amplitude (``amax ≈ 127·scale``) sits below half a step of a coarser
    grid rounds to zero in that grid anyway — quantization already
    discarded it — so it cannot force the grid finer. Without this flush
    rule a dead group (all-zero LUT, scale floored at ``1e-8/127``) would
    drag the grid ~1e7x below the live groups' scales and every healthy
    bank would "overflow" on paper.

    Formally: the largest candidate ``s ∈ scales`` such that every group
    is either representable (``scale_g ≥ s``) or flushable
    (``127·scale_g ≤ s/2``)."""
    if scales.size == 0:
        return 1.0
    ss = np.sort(scales.astype(np.float64))
    prefix = np.maximum.accumulate(ss)                  # coarsest so far
    for i in range(ss.size - 1, -1, -1):
        if i == 0 or prefix[i - 1] * 254.0 <= ss[i]:
            return max(float(ss[i]), 1e-30)
    return max(float(ss[0]), 1e-30)


def overflow_bound(q8: np.ndarray, scales: np.ndarray,
                   bias: np.ndarray | None = None) -> float:
    """Worst-case |int32 accumulator| for one bank's SumReduce, in units of
    the bank's accumulation grid (:func:`accumulation_grid` — the shared
    fixed-point step an integer dataplane accumulates in; groups finer
    than the grid flush to zero under ``rint``, exactly as the rescale
    hardware would).

    Exact, not just an upper bound: per output column the K groups choose
    leaves independently, so the extreme sum is separable —
    ``Σ_k max_c`` (and ``Σ_k min_c`` for the negative side).
    """
    smin = accumulation_grid(scales)
    contrib = np.rint(q8 * (scales[:, None, None] / smin))      # [K, C, N]
    pos = contrib.max(axis=1).sum(axis=0)                       # [N]
    neg = contrib.min(axis=1).sum(axis=0)
    if bias is not None:
        b = np.rint(np.asarray(bias, np.float64) / smin)
        pos = pos + b
        neg = neg + b
    if pos.size == 0:
        return 0.0
    return float(max(pos.max(), -neg.min(), 0.0))


def _check_overflow(plan, cfg: AuditConfig):
    for i, bank in enumerate(plan.banks):
        _, q8, scales = _true_tables(bank)
        bias = None if bank.layer.bias is None else np.asarray(bank.layer.bias)
        bound = overflow_bound(q8, scales, bias)
        grid = accumulation_grid(scales)
        metrics = {"bound": bound, "int32_max": R.INT32_MAX,
                   "k": bank.layer.num_groups, "grid": grid,
                   "scale_spread": float(scales.max() / grid)
                   if scales.size else 1.0}
        site = f"bank[{i}]"
        if bound > R.INT32_MAX:
            yield AuditFinding(
                "PGA101", "error", site,
                f"worst-case accumulator {bound:.3e} exceeds int32 "
                f"({R.INT32_MAX}) in the finest-scale fixed-point grid "
                f"(group scale spread {metrics['scale_spread']:.1e})",
                metrics)
        elif bound * cfg.overflow_margin > R.INT32_MAX:
            yield AuditFinding(
                "PGA101", "warning", site,
                f"worst-case accumulator {bound:.3e} is within "
                f"{cfg.overflow_margin:g}x of int32", metrics)


def _check_fidelity(plan, cfg: AuditConfig):
    for i, bank in enumerate(plan.banks):
        lut, q8, scales = _true_tables(bank)
        if lut.size == 0:
            continue
        dq = q8 * scales[:, None, None]
        amax = np.abs(lut).max(axis=(1, 2))                     # [K]
        rel = np.abs(lut - dq).max(axis=(1, 2)) / np.maximum(amax, 1e-8)
        worst = float(rel.max())
        if worst > cfg.q8_rel_tol:
            g = int(rel.argmax())
            yield AuditFinding(
                "PGA102", "error", f"bank[{i}]",
                f"q8 dequant error {worst:.4f} of group {g}'s amax exceeds "
                f"tol {cfg.q8_rel_tol:g} — the int8 table does not match "
                "the f32 LUT (stale or tampered quantization)",
                {"rel_err": worst, "group": g, "tol": cfg.q8_rel_tol})


def _single_vmem_bytes(bank) -> int:
    """Worst-case per-program VMEM working set of the single-bank kernel
    (see the kernel.py module docstring): x block + one-hot/threshold
    blocks + LUT block + out block, f32, plus the q8 path's int8 table
    copy that is dequantized in-register."""
    l = bank.layer
    v, c = l.group_size, l.num_centroids
    i = c - 1
    bt, bk, bn = bank.block_t, bank.block_k, bank.block_n
    floats = bt * bk * v + bk * i * v + bk * i + bk * c * bn + bt * bn
    return 4 * floats + bk * c * bn          # + int8 LUT block (q8 path)


def _stack_vmem_bytes(stack, max_bucket: int) -> int:
    """Worst-case VMEM working set of one stacked pallas_call: the batch
    tile's x + repartition buffer, plus EVERY per-layer operand riding
    whole (that is the point of the fusion — the activation never leaves
    VMEM)."""
    ll = len(stack.banks)
    kmax = max(stack.ks)
    c = stack.banks[0].layer.num_centroids
    i = c - 1
    nmax = int(stack.lut.shape[-1])
    v = stack.v
    bt = min(stack.block_t, max(max_bucket, 1))
    floats = (bt * stack.ks[0] * v + bt * kmax * v          # x + repartition
              + ll * kmax * i * (v + 1)                     # feat_oh + thr
              + ll * kmax * c * nmax                        # f32 LUT stack
              + ll * nmax + bt * nmax + bt * stack.n_out)   # bias + y + out
    return 4 * floats + ll * kmax * c * nmax                # + int8 stack


def _iter_steps(plan):
    """(site, step) over the plan's forward steps: fused stacks once each,
    banks not inside any stack individually."""
    fused_members = {id(b) for s in plan.fused_stacks for b in s.banks}
    for g, s in enumerate(plan.fused_stacks):
        lo = plan.banks.index(s.banks[0])
        yield f"stack[{g}]=banks[{lo}:{lo + len(s.banks)}]", s
    for i, b in enumerate(plan.banks):
        if id(b) not in fused_members:
            yield f"bank[{i}]", b


def _check_vmem(plan, cfg: AuditConfig):
    budget = cfg.vmem_budget_bytes
    max_bucket = max(plan.buckets)
    for site, step in _iter_steps(plan):
        if hasattr(step, "ks"):                      # FusedBankStack
            need = _stack_vmem_bytes(step, max_bucket)
            shape = (f"L={len(step.banks)}, Kmax={max(step.ks)}, "
                     f"Nmax={int(step.lut.shape[-1])}")
        else:
            need = _single_vmem_bytes(step)
            shape = (f"bt={step.block_t}, bk={step.block_k}, "
                     f"bn={step.block_n}")
        metrics = {"bytes": need, "budget": budget, "shape": shape}
        if need > budget:
            yield AuditFinding(
                "PGA103", "error", site,
                f"pallas_call working set ~{need / 2**20:.2f} MiB ({shape}) "
                f"exceeds the VMEM budget {budget / 2**20:.2f} MiB — the "
                "kernel would fail (or thrash) at runtime; shrink block_t "
                "or split the fused run (fuse_nmax_cap)", metrics)
        elif need * cfg.vmem_margin > budget:
            yield AuditFinding(
                "PGA103", "warning", site,
                f"pallas_call working set ~{need / 2**20:.2f} MiB ({shape}) "
                f"is within {cfg.vmem_margin:g}x of the VMEM budget "
                f"{budget / 2**20:.2f} MiB", metrics)


def _check_alignment(plan, cfg: AuditConfig):
    # hidden batch padding: __call__ pads up to the bucket, then the kernel
    # path pads AGAIN up to its batch-tile multiple — rows pad_waste never
    # sees. Flag every (bucket, tile) pair that re-pads.
    tiles = {}                          # (bt_limit, kind) -> example site
    for site, step in _iter_steps(plan):
        if hasattr(step, "ks"):
            tiles.setdefault((step.block_t, "stack", False), site)
        else:
            tiles.setdefault((step.block_t, "bank", True), site)
    for (limit, kind, floor8), site in sorted(tiles.items()):
        for bucket in plan.buckets:
            bt = min(limit, max(8, bucket) if floor8 else bucket)
            hidden = (-bucket) % bt
            if hidden:
                yield AuditFinding(
                    "PGA104", "warning", f"bucket {bucket}",
                    f"bucket {bucket} is not a multiple of the {kind} batch "
                    f"tile {bt} ({site}): the kernel path silently pads "
                    f"{hidden} extra rows per call, uncounted by pad_waste",
                    {"bucket": bucket, "tile": bt, "hidden_rows": hidden})
    # MXU lane alignment: the mxu strategy's matmul wants the LUT column
    # tile 128-lane aligned; misalignment wastes systolic-array lanes.
    for site, step in _iter_steps(plan):
        if step.strategy != "mxu":
            continue
        width = int(step.lut.shape[-1]) if hasattr(step, "ks") else step.block_n
        what = "Nmax" if hasattr(step, "ks") else "block_n"
        if width % R.MXU_LANES:
            yield AuditFinding(
                "PGA104", "warning", site,
                f"mxu strategy with {what}={width} not {R.MXU_LANES}-lane "
                "aligned — MXU tiles run partially empty",
                {"width": width, "lanes": R.MXU_LANES})


def _unfused_reasons(a, b) -> list[str]:
    """Why ``_fusable(a, b)`` says no — one string per failed conjunct."""
    la, lb = a.layer, b.layer
    r = []
    if la.group_size != lb.group_size:
        r.append(f"partition width v {la.group_size} != {lb.group_size}")
    if la.num_centroids != lb.num_centroids:
        r.append(f"centroid count C {la.num_centroids} != {lb.num_centroids}")
    if la.out_features != lb.in_features:
        r.append(f"chaining break: out {la.out_features} != in {lb.in_features}")
    if a.interpret != b.interpret:
        r.append("interpret-mode mismatch")
    if a.strategy != b.strategy:
        r.append(f"strategy mismatch {a.strategy} != {b.strategy}")
    return r


def _chain_boundaries(plan):
    """Adjacent chained (tail bank, head bank, structural note) triples the
    forward actually executes back-to-back, by family."""
    st = plan._state
    fam = plan.family
    chains: list[tuple[list, str | None]] = []
    if fam == "sequential":
        chains.append((list(st["steps"]), None))
    elif fam == "cnn":
        heads = list(st["heads"])
        if heads:
            # window → first head crosses the per-window SumReduce/mean —
            # a structural break no fusion pass can cross
            chains.append(([st["window"], heads[0]],
                           "structural: the per-window SumReduce/mean "
                           "separates the pair"))
            chains.append((heads, None))
    elif fam == "cnn_l":
        chains.append(([st["b1"], st["b2"]],
                       "the cnn_l builder compiles banks individually "
                       "(no fusion pass over the b1→b2 chain)"))
    # rnn: recurrent structure — no two banks chain unconditionally
    for steps, note in chains:
        for prev, nxt in zip(steps, steps[1:]):
            same_stack = prev is nxt
            if same_stack:
                continue
            tail = prev.banks[-1] if hasattr(prev, "ks") else prev
            head = nxt.banks[0] if hasattr(nxt, "ks") else nxt
            yield tail, head, note


def _check_fusion(plan, cfg: AuditConfig):
    cap = plan.fuse_cfg.get("nmax_cap")
    fuse_on = plan.fuse_cfg.get("fuse", True)
    for tail, head, note in _chain_boundaries(plan):
        ti = plan.banks.index(tail)
        hi = plan.banks.index(head)
        site = f"bank[{ti}]→bank[{hi}]"
        reasons = _unfused_reasons(tail, head)
        if note is not None and "structural" in note:
            reasons = [note] + reasons
        elif not reasons:
            if not fuse_on:
                reasons = ["pair is shape-compatible but fusion is disabled "
                           "(fuse=False)"]
            elif note is not None:
                reasons = [note + " — pair is shape-compatible (fusion "
                           "ratchet candidate, see ROADMAP)"]
            else:
                widths = (tail.layer.out_features, head.layer.out_features)
                reasons = [
                    f"pair is shape-compatible but split by the "
                    f"fuse_nmax_cap={cap} balloon guard (member widths "
                    f"{widths} would pad a narrow stack to the run's Nmax)"]
        yield AuditFinding(
            "PGA105", "info", site,
            "unfused adjacent pair: " + "; ".join(reasons),
            {"tail": ti, "head": hi})


def _resolve_target(target):
    from repro.dataplane.resources import TOFINO2, SwitchBudget
    if target is None:
        return None, None
    if isinstance(target, SwitchBudget):
        return target, "custom"
    name = str(target).lower()
    if name in ("", "none", "off"):
        return None, None
    if name == "tofino2":
        return TOFINO2, "tofino2"
    raise ValueError(f"unknown dataplane target {target!r} (know: tofino2)")


def _check_dataplane(plan, cfg: AuditConfig):
    budget, name = _resolve_target(cfg.target)
    if budget is None:
        return
    from repro.dataplane.compile import compile_model
    pipe = compile_model([b.layer for b in plan.banks], budget=budget)
    rep = pipe.report()
    metrics = {"target": name, "stages_used": rep.stages_used,
               "sram_pct": round(rep.sram_pct, 2),
               "tcam_pct": round(rep.tcam_pct, 2),
               "bus_pct": round(rep.bus_pct, 2),
               "phv_bits_peak": rep.phv_bits_peak,
               "recirculations": rep.recirculations}
    for err in rep.validate():
        yield AuditFinding(
            "PGA106", "error", "plan",
            f"dataplane target '{name}' exceeded: {err}", metrics)
    if rep.recirculations:
        yield AuditFinding(
            "PGA106", "warning", "plan",
            f"{rep.stages_used} physical stages need "
            f"{rep.recirculations} recirculation pass(es) on '{name}' "
            f"({budget.stages} stages/pipeline) — line rate divides "
            "accordingly", metrics)
    yield AuditFinding(
        "PGA106", "info", "plan",
        f"dataplane fit on '{name}': {rep.stages_used} stages, "
        f"SRAM {rep.sram_pct:.2f}%, TCAM {rep.tcam_pct:.2f}%, "
        f"bus {rep.bus_pct:.2f}%", metrics)


_CHECKS = (_check_overflow, _check_fidelity, _check_vmem, _check_alignment,
           _check_fusion, _check_dataplane)


def audit_plan(plan, config: AuditConfig | None = None) -> AuditReport:
    """Statically audit a built ExecutionPlan (PGA101–PGA106).

    Pure host-side analysis: walks the plan structure and the numpy views
    of tensors the build already materialized; never traces or dispatches
    a jax computation. Returns an :class:`AuditReport`; attach it yourself
    or let ``build_plan(..., audit=...)`` do both.
    """
    cfg = config or AuditConfig()
    suppress = set(cfg.suppress)
    findings: list[AuditFinding] = []
    for check in _CHECKS:
        for f in check(plan, cfg):
            if f.rule not in suppress:
                findings.append(f)
    order = {"error": 0, "warning": 1, "info": 2}
    findings.sort(key=lambda f: (order[f.severity], f.rule, f.site))
    summary = {
        "family": plan.family,
        "backend": plan.backend,
        "num_banks": len(plan.banks),
        "fused_groups": len(plan.fused_stacks),
        "buckets": list(plan.buckets),
        "devices": 1 if plan.devices is None else len(plan.devices),
        "table_bytes": plan.table_bytes(),
    }
    return AuditReport(findings, summary)


# ---------------------------------------------------------------------------
# CLI: python -m repro.analysis plan [--json] — audits the in-tree zoo.
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis plan",
        description="Static plan audit (PGA101-PGA106) over the in-tree "
                    "model families; exit 1 on any unsuppressed "
                    "error/warning finding")
    ap.add_argument("--families", default="mlp,rnn,cnn,cnn_l,ae",
                    help="comma-separated families to build and audit")
    ap.add_argument("--backends", default="gather,kernel_q8",
                    help="comma-separated default backends to build per family")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON instead of text")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    ap.add_argument("--target", default=None,
                    help="dataplane target for PGA106 (e.g. tofino2); "
                         "default: no target declared")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="override the PGA103 VMEM budget (bytes)")
    ap.add_argument("--suppress", default="",
                    help="comma-separated PGA rule ids to suppress")
    ap.add_argument("--flows", type=int, default=48,
                    help="synthetic dataset flows per class (zoo size)")
    ap.add_argument("--steps", type=int, default=5,
                    help="training steps per zoo model")
    args = ap.parse_args(argv)

    cfg = AuditConfig(
        target=args.target,
        vmem_budget_bytes=args.vmem_budget or R.PGA103_VMEM_BUDGET,
        suppress=tuple(s for s in args.suppress.split(",") if s))

    from repro.engine import build_plan

    from .zoo import build_family

    reports: dict[str, AuditReport] = {}
    families = [f for f in args.families.split(",") if f]
    backends = [b for b in args.backends.split(",") if b]
    for fam in families:
        model = build_family(fam, flows=args.flows, steps=args.steps)
        for be in backends:
            plan = build_plan(model, backend=be, audit="off")
            reports[f"{fam}:{be}"] = audit_plan(plan, cfg)

    totals = {"error": 0, "warning": 0, "info": 0}
    for rep in reports.values():
        for sev, n in rep.counts.items():
            totals[sev] += n
    doc = {
        "config": {"target": args.target, "suppress": cfg.suppress,
                   "vmem_budget_bytes": cfg.vmem_budget_bytes,
                   "families": families, "backends": backends},
        "totals": totals,
        "plans": {name: rep.to_dict() for name, rep in reports.items()},
        "rules": R.PGA_RULES,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(doc, fh, indent=2, default=str)
    if args.json:
        print(json.dumps(doc, indent=2, default=str))
    else:
        for name, rep in reports.items():
            print(f"== {name} ==")
            print(rep)
        print(f"plan-audit: {totals['error']} error(s), "
              f"{totals['warning']} warning(s), {totals['info']} note(s) "
              f"over {len(reports)} plan(s)")
    return 1 if (totals["error"] or totals["warning"]) else 0
