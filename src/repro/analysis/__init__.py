"""Machine-checked concurrency invariants for the serving stack.

Two halves over one policy (:mod:`repro.analysis.rules`):

* :mod:`repro.analysis.lint` — the AST pass behind
  ``python -m repro.analysis src/`` (PG001-PG004, run as the
  ``static-analysis`` CI lane);
* :mod:`repro.analysis.sanitizer` — the ``PEGASUS_SANITIZE=1`` runtime
  half: ``make_lock`` (lock-order cycle + hierarchy detection) and
  ``ThreadAffinity`` assertions.
"""

from .lint import Finding, lint_file, lint_paths, lint_source, main
from .rules import RULES
from .sanitizer import (InstrumentedLock, LockOrderError, ThreadAffinity,
                        ThreadAffinityError, enabled, make_lock,
                        reset_lock_graph)

__all__ = [
    "Finding", "lint_file", "lint_paths", "lint_source", "main", "RULES",
    "InstrumentedLock", "LockOrderError", "ThreadAffinity",
    "ThreadAffinityError", "enabled", "make_lock", "reset_lock_graph",
]
