"""Machine-checked invariants for the serving stack.

Three layers over one policy module (:mod:`repro.analysis.rules`):

* :mod:`repro.analysis.lint` — the AST pass behind
  ``python -m repro.analysis src/`` (PG001-PG004, run as the
  ``static-analysis`` CI lane);
* :mod:`repro.analysis.sanitizer` — the ``PEGASUS_SANITIZE=1`` runtime
  half: ``make_lock`` (lock-order cycle + hierarchy detection) and
  ``ThreadAffinity`` assertions;
* :mod:`repro.analysis.planaudit` — the plan auditor behind
  ``python -m repro.analysis plan`` (PGA101-PGA106): static numerics,
  VMEM, and dataplane-resource analysis of compiled ExecutionPlans,
  wired into ``build_plan(..., audit=...)`` and every server
  ``stats()`` surface.

See ``docs/ANALYSIS.md`` for the rule → invariant map across all three.
"""

from .lint import Finding, lint_file, lint_paths, lint_source, main
from .planaudit import (AuditConfig, AuditFinding, AuditReport,
                        PlanAuditError, audit_plan)
from .rules import PGA_RULES, RULES
from .sanitizer import (InstrumentedLock, LockOrderError, ThreadAffinity,
                        ThreadAffinityError, enabled, make_lock,
                        reset_lock_graph)

__all__ = [
    "Finding", "lint_file", "lint_paths", "lint_source", "main", "RULES",
    "PGA_RULES", "AuditConfig", "AuditFinding", "AuditReport",
    "PlanAuditError", "audit_plan",
    "InstrumentedLock", "LockOrderError", "ThreadAffinity",
    "ThreadAffinityError", "enabled", "make_lock", "reset_lock_graph",
]
