"""Tiny in-tree model zoo for ``python -m repro.analysis plan``.

One builder per model family (MLP / RNN / CNN / CNN-L / AE), trained on the
synthetic traffic dataset at fixture scale — the same recipe the engine
tests use: the audit needs real bank geometry and real q8 tables, not an
accurate classifier. Kept out of ``repro.analysis.__init__`` on purpose:
importing the analysis package must stay jax-free (the lint and sanitizer
run in contexts with no accelerator stack warmed up).
"""

from __future__ import annotations

import functools

FAMILY_NAMES = ("mlp", "rnn", "cnn", "cnn_l", "ae")


@functools.lru_cache(maxsize=None)
def _dataset(flows: int):
    from repro.data.synthetic_traffic import make_dataset

    return make_dataset("peerrush", flows_per_class=flows)


def build_family(family: str, *, flows: int = 48, steps: int = 5):
    """Train + pegasusify one model family at fixture scale; returns the
    model object ``build_plan`` accepts."""
    import numpy as np

    ds = _dataset(flows)
    if family == "mlp":
        from repro.nets.mlp import pegasusify_mlp, train_mlp

        m = train_mlp(ds.train["stats"], ds.train["label"], ds.num_classes,
                      steps=steps)
        return pegasusify_mlp(m, ds.train["stats"].astype(np.float32),
                              depth=3, refine_steps=0)
    if family == "rnn":
        from repro.nets.rnn import pegasusify_rnn, train_rnn

        m = train_rnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                      steps=steps)
        return pegasusify_rnn(m, ds.train["seq"], depth=4)
    if family == "cnn":
        from repro.nets.cnn import pegasusify_cnn, train_cnn

        m = train_cnn(ds.train["seq"], ds.train["label"], ds.num_classes,
                      size="B", steps=steps)
        return pegasusify_cnn(m, ds.train["seq"], depth=5)
    if family == "cnn_l":
        from repro.nets.cnn import pegasusify_cnn_l, train_cnn_l

        m = train_cnn_l(ds.train["seq"], ds.train["bytes"],
                        ds.train["label"], ds.num_classes, steps=steps)
        return pegasusify_cnn_l(m, ds.train["seq"], ds.train["bytes"],
                                enc_depth=4, index_bits=3)
    if family == "ae":
        from repro.nets.autoencoder import pegasusify_ae, train_autoencoder

        x = ds.train["seq"].reshape(len(ds.train["label"]), -1)
        m = train_autoencoder(x, steps=steps)
        return pegasusify_ae(m, x.astype(np.float32), depth=4)
    raise ValueError(f"unknown family {family!r}; know {FAMILY_NAMES}")
